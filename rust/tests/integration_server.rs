//! TCP server round-trips: boots the JSON-lines server on an ephemeral port
//! against real artifacts, drives it with clients, and checks generation
//! responses, control commands, deadline cancellation, and — the point of
//! the concurrent-scheduler refactor — that many simultaneous connections
//! each receive exactly *their own* completion while the batch fills.
//!
//! One `#[test]` boots one server: xla_extension tolerates exactly one PJRT
//! client per process, so all phases share the engine. Skips when artifacts
//! are absent (run `make artifacts`).

mod common;

use std::net::SocketAddr;

use common::artifacts_root;
use quasar::coordinator::{ClusterConfig, ClusterHandle, EngineConfig};
use quasar::server::Client;
use quasar::tokenizer::Tokenizer;
use quasar::util::json::Json;

const CLIENTS: usize = 8;
const ROUNDS: usize = 3;

#[test]
fn server_round_trip_and_concurrent_delivery() {
    quasar::util::bigstack::run(server_inner)
}

fn server_inner() {
    let Some(root) = artifacts_root() else { return };
    let manifest = quasar::runtime::Manifest::load(&root).unwrap();
    let model = manifest.models.keys().next().unwrap().clone();
    let tok = Tokenizer::load(&manifest.tokenizer_path).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Batch bucket 4 so the continuous batcher can multiplex connections.
    // Served through a 1-replica cluster: the dispatcher's degenerate case
    // must preserve every bare-engine behavior this test asserts
    // (determinism, correlated delivery, cancellation, stats counters).
    let handle = ClusterHandle::spawn(
        root, model, EngineConfig::quasar(4, 4), ClusterConfig::default(), 64,
    )
    .unwrap();
    let server = std::thread::spawn(move || {
        quasar::server::serve(listener, handle, tok, CLIENTS + 4).unwrap()
    });

    round_trip_phase(addr);
    concurrent_phase(addr);

    let mut ctl = Client::connect(&addr.to_string()).unwrap();
    ctl.shutdown().unwrap();
    let served = server.join().unwrap();
    assert!(served as usize >= 5 + CLIENTS * ROUNDS, "served {served}");
}

/// Control plane, single-connection generation, determinism, deadline
/// cancellation, and the stats endpoint.
fn round_trip_phase(addr: SocketAddr) {
    let mut client = Client::connect(&addr.to_string()).unwrap();

    // control plane
    let pong = client
        .roundtrip(&Json::obj(vec![("cmd", Json::str("ping"))]))
        .unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool().unwrap(), true);

    // malformed request -> error response, connection stays usable
    let err = client.roundtrip(&Json::obj(vec![("nope", Json::num(1.0))])).unwrap();
    assert!(err.opt("error").is_some(), "expected error field: {err}");

    // generation
    let resp = client
        .generate("question : tom has 2 4 apples . how many apples now ?", 24, 0.0)
        .unwrap();
    assert!(resp.opt("error").is_none(), "unexpected error: {resp}");
    let text = resp.get("text").unwrap().as_str().unwrap();
    assert!(!text.is_empty(), "empty generation");
    let steps = resp.get("steps").unwrap().as_i64().unwrap();
    let l = resp.get("accept_len").unwrap().as_f64().unwrap();
    assert!(steps > 0 && l >= 1.0, "steps={steps} L={l}");
    assert!(resp.get("latency_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(resp.get("sched_delay_s").unwrap().as_f64().unwrap() >= 0.0);
    let tokens = resp.get("tokens").unwrap().as_i32_vec().unwrap();
    assert!(!tokens.is_empty() && tokens.len() <= 24);

    // determinism: same prompt + greedy -> same tokens (priority field is
    // parsed but must not perturb generation)
    let resp2 = client
        .roundtrip(&Json::obj(vec![
            ("prompt", Json::str("question : tom has 2 4 apples . how many apples now ?")),
            ("max_new", Json::num(24.0)),
            ("temp", Json::num(0.0)),
            ("priority", Json::str("high")),
        ]))
        .unwrap();
    assert_eq!(
        resp2.get("tokens").unwrap().as_i32_vec().unwrap(),
        tokens,
        "greedy generation must be deterministic"
    );

    // an already-expired deadline is cancelled before costing a prefill
    let cancelled = client
        .roundtrip(&Json::obj(vec![
            ("prompt", Json::str("question : tom has 2 apples .")),
            ("max_new", Json::num(8.0)),
            ("deadline_ms", Json::num(0.0)),
        ]))
        .unwrap();
    assert_eq!(
        cancelled.get("finish").unwrap().as_str().unwrap(),
        "cancelled",
        "zero deadline must cancel: {cancelled}"
    );
    assert!(cancelled.get("tokens").unwrap().as_i32_vec().unwrap().is_empty());

    // stats endpoint reports the scheduler's counters
    let stats = client.stats().unwrap();
    assert!(stats.get("completed").unwrap().as_i64().unwrap() >= 2, "{stats}");
    assert!(stats.get("cancelled").unwrap().as_i64().unwrap() >= 1, "{stats}");
    assert_eq!(stats.get("batch").unwrap().as_i64().unwrap(), 4);
    assert!(stats.get("queue_depth").unwrap().as_i64().unwrap() >= 0);
    assert!(stats.get("batch_occupancy").unwrap().as_f64().unwrap() >= 0.0);
    // Fleet serving: the flat keys above stay bare-engine-shaped while the
    // per-replica breakdown and dispatch counters ride alongside.
    let reps = stats.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 1, "{stats}");
    assert_eq!(reps[0].get("replica").unwrap().as_i64().unwrap(), 0);
    let dispatch = stats.get("dispatch").unwrap();
    assert_eq!(dispatch.get("policy").unwrap().as_str().unwrap(), "locality");
    assert_eq!(dispatch.get("steals").unwrap().as_i64().unwrap(), 0,
               "a 1-replica fleet can never steal: {stats}");
    // Provenance block: uptime/version/config echo ride on every snapshot.
    assert!(stats.get("uptime_s").unwrap().as_f64().unwrap() > 0.0, "{stats}");
    assert_eq!(
        stats.get("version").unwrap().as_str().unwrap(),
        env!("CARGO_PKG_VERSION"),
        "{stats}"
    );
    let config = stats.get("config").unwrap();
    assert_eq!(config.get("batch").unwrap().as_i64().unwrap(), 4, "{stats}");
    assert_eq!(config.get("replicas").unwrap().as_i64().unwrap(), 1, "{stats}");
    assert_eq!(config.get("dispatch").unwrap().as_str().unwrap(), "locality");
    assert_eq!(config.get("trace").unwrap().as_bool().unwrap(), false);
    assert!(!config.get("method").unwrap().as_str().unwrap().is_empty());

    // Prometheus exposition: the metrics command wraps the text format in a
    // one-field JSON envelope; spot-check the scrape contract.
    let metrics = client
        .roundtrip(&Json::obj(vec![("cmd", Json::str("metrics"))]))
        .unwrap();
    let text = metrics.get("metrics").unwrap().as_str().unwrap().to_string();
    assert!(text.contains("# TYPE"), "no TYPE lines in exposition:\n{text}");
    assert!(
        text.lines().any(|l| l.contains("_bucket{") && l.contains("le=")),
        "no histogram bucket lines in exposition:\n{text}"
    );

    // Trace export: with the recorder unarmed (EngineConfig::quasar defaults
    // trace off) the endpoint still answers with a valid, empty trace.
    let trace = client
        .roundtrip(&Json::obj(vec![("cmd", Json::str("trace"))]))
        .unwrap();
    assert!(trace.opt("error").is_none(), "trace endpoint errored: {trace}");
    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        events.iter().all(|e| e.opt("ph").is_some()),
        "malformed trace event: {trace}"
    );
}

/// The acceptance test for the concurrent scheduler: >= 8 connections in
/// flight at once, each must get back exactly its own completion (the task
/// tag echoes the request), ids must never be delivered twice, and the
/// engine's batch must actually fill (mean occupancy > 1 row/step).
fn concurrent_phase(addr: SocketAddr) {
    let mut joins = Vec::new();
    for i in 0..CLIENTS {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || -> Vec<u64> {
            let mut client = Client::connect(&addr).unwrap();
            let tag = format!("client-{i}");
            let mut ids = Vec::new();
            for r in 0..ROUNDS {
                // Distinct prompt per (client, round); single digits are in
                // the closed lexicon.
                let prompt =
                    format!("question : tom has {i} {r} apples . how many apples now ?");
                let resp = client
                    .roundtrip(&Json::obj(vec![
                        ("prompt", Json::str(prompt)),
                        ("max_new", Json::num(16.0)),
                        ("temp", Json::num(0.0)),
                        ("task", Json::str(tag.clone())),
                    ]))
                    .unwrap();
                assert!(resp.opt("error").is_none(), "client {i}: {resp}");
                // Correlated delivery: the echoed task tag proves this
                // worker got its own completion, not another connection's.
                assert_eq!(
                    resp.get("task").unwrap().as_str().unwrap(),
                    tag,
                    "cross-delivered completion"
                );
                ids.push(resp.get("id").unwrap().as_i64().unwrap() as u64);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for j in joins {
        all_ids.extend(j.join().unwrap());
    }
    assert_eq!(all_ids.len(), CLIENTS * ROUNDS);
    all_ids.sort_unstable();
    let before = all_ids.len();
    all_ids.dedup();
    assert_eq!(all_ids.len(), before, "a completion id was delivered twice");

    let mut ctl = Client::connect(&addr.to_string()).unwrap();
    let stats = ctl.stats().unwrap();
    let occupancy = stats.get("batch_occupancy").unwrap().as_f64().unwrap();
    assert!(
        occupancy > 1.0,
        "batch never filled under {CLIENTS} concurrent clients: {stats}"
    );
    assert!(
        stats.get("completed").unwrap().as_i64().unwrap() as usize >= CLIENTS * ROUNDS,
        "{stats}"
    );
    assert_eq!(stats.get("in_flight").unwrap().as_i64().unwrap(), 0, "{stats}");
}

//! TCP server round-trip: boots the JSON-lines server on an ephemeral port
//! against real artifacts, drives it with the client, and checks the
//! generation responses and control commands. Skips when artifacts are
//! absent (run `make artifacts`).

use std::net::TcpListener;
use std::path::PathBuf;

use quasar::coordinator::{EngineConfig, EngineHandle};
use quasar::server::{serve, Client};
use quasar::tokenizer::Tokenizer;
use quasar::util::json::Json;

fn artifacts_root() -> Option<PathBuf> {
    let root = std::env::var("QUASAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("[skip] no artifacts at {root:?} — run `make artifacts`");
        None
    }
}

#[test]
fn server_round_trip() {
    quasar::util::bigstack::run(server_round_trip_inner)
}

fn server_round_trip_inner() {
    let Some(root) = artifacts_root() else { return };
    let manifest = quasar::runtime::Manifest::load(&root).unwrap();
    let model = manifest.models.keys().next().unwrap().clone();
    let tok = Tokenizer::load(&manifest.tokenizer_path).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = EngineHandle::spawn(root, model, EngineConfig::quasar(1, 4), 16).unwrap();

    let server = std::thread::spawn(move || serve(listener, handle, tok, 2).unwrap());

    let mut client = Client::connect(&addr.to_string()).unwrap();

    // control plane
    let pong = client
        .roundtrip(&Json::obj(vec![("cmd", Json::str("ping"))]))
        .unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool().unwrap(), true);

    // malformed request -> error response, connection stays usable
    let err = client.roundtrip(&Json::obj(vec![("nope", Json::num(1.0))])).unwrap();
    assert!(err.opt("error").is_some(), "expected error field: {err}");

    // generation
    let resp = client
        .generate("question : tom has 2 4 apples . how many apples now ?", 24, 0.0)
        .unwrap();
    assert!(resp.opt("error").is_none(), "unexpected error: {resp}");
    let text = resp.get("text").unwrap().as_str().unwrap();
    assert!(!text.is_empty(), "empty generation");
    let steps = resp.get("steps").unwrap().as_i64().unwrap();
    let l = resp.get("accept_len").unwrap().as_f64().unwrap();
    assert!(steps > 0 && l >= 1.0, "steps={steps} L={l}");
    assert!(resp.get("latency_s").unwrap().as_f64().unwrap() > 0.0);
    let tokens = resp.get("tokens").unwrap().as_i32_vec().unwrap();
    assert!(!tokens.is_empty() && tokens.len() <= 24);

    // determinism: same prompt + greedy -> same tokens
    let resp2 = client
        .generate("question : tom has 2 4 apples . how many apples now ?", 24, 0.0)
        .unwrap();
    assert_eq!(
        resp2.get("tokens").unwrap().as_i32_vec().unwrap(),
        tokens,
        "greedy generation must be deterministic"
    );

    client.shutdown().unwrap();
    let served = server.join().unwrap();
    assert!(served >= 4, "served {served}");
}

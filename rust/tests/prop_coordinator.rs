//! Property tests over coordinator/spec invariants (pure logic — no PJRT),
//! using the in-repo `util::prop` micro-framework.

use std::collections::BTreeMap;

use quasar::coordinator::{
    plan_step, BatchGroup, CallLog, CallRecord, FnKind, GenParams, Governor, GovernorConfig,
    Lease, PlanCtx, PlanRow, PrefixCache, PrefixCacheConfig, Priority, Request, Route,
    SchedPolicy, Scheduler, Transition, VariantCtx,
};
use quasar::perfmodel::PerfModel;
use quasar::prop_assert;
use quasar::runtime::{CostModelCfg, ModelCfg, Tensor};
use quasar::spec::{verify_draft, Draft, NgramIndex};
use quasar::util::prop::{ok, prop_check};
use quasar::util::rng::Pcg;

#[test]
fn batch_group_never_loses_or_duplicates_rows() {
    // Random join/leave sequences: every leased slot is unique, frees are
    // exact, and capacity is respected.
    prop_check(
        "batch group lease discipline",
        300,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(40)).map(|_| rng.below(100)).collect();
            ops
        },
        |ops| {
            let batch = 4;
            let mut g = BatchGroup::new(2, batch, 2, 8, 4);
            let k1 = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
            let mut next_slot = 0usize;
            let mut leased: Vec<(usize, usize)> = Vec::new(); // (row, slot)
            for &op in ops {
                if op % 2 == 0 {
                    // join
                    let r = g.join(next_slot, &k1, &k1);
                    if leased.len() < batch {
                        let row = match r {
                            Ok(row) => row,
                            Err(e) => return Err(format!("join failed with space: {e}")),
                        };
                        prop_assert!(
                            !leased.iter().any(|(rw, _)| *rw == row),
                            "row {row} double-leased"
                        );
                        leased.push((row, next_slot));
                        next_slot += 1;
                    } else {
                        prop_assert!(r.is_err(), "join succeeded on full group");
                    }
                } else if !leased.is_empty() {
                    let idx = (op as usize / 2) % leased.len();
                    let (row, slot) = leased.remove(idx);
                    match g.leave(row) {
                        Ok(s) => prop_assert!(s == slot, "leave returned wrong slot"),
                        Err(e) => return Err(format!("leave failed: {e}")),
                    }
                }
                // invariant: active rows equals our model
                let mut active = g.active_rows();
                active.sort_unstable();
                let mut expect = leased.clone();
                expect.sort_unstable();
                prop_assert!(active == expect, "active rows diverged");
                prop_assert!(
                    g.free_rows() == batch - leased.len(),
                    "free row count diverged"
                );
            }
            ok()
        },
    );
}

#[test]
fn scheduler_pop_order_matches_policy() {
    // For any mix of priorities and prompt lengths, draining the scheduler
    // yields a sequence sorted by the policy's key with arrival order as
    // the tiebreak — and never loses or duplicates a request.
    prop_check(
        "scheduler drains in policy order",
        300,
        |rng| {
            (0..rng.usize_below(24))
                .map(|_| (rng.below(3), 1 + rng.usize_below(9)))
                .collect::<Vec<(u64, usize)>>()
        },
        |items| {
            for policy in [
                SchedPolicy::Fifo,
                SchedPolicy::ShortestPromptFirst,
                SchedPolicy::Priority,
            ] {
                let mut s = Scheduler::new(policy);
                for (i, (pr, plen)) in items.iter().enumerate() {
                    let params = GenParams {
                        priority: match *pr {
                            0 => Priority::High,
                            1 => Priority::Normal,
                            _ => Priority::Low,
                        },
                        ..GenParams::default()
                    };
                    // id == arrival order + 1, so it doubles as the seq key
                    s.push(Request::new(i as u64 + 1, vec![1; *plen], params));
                }
                let mut popped: Vec<Request> = Vec::new();
                while let Some(r) = s.pop() {
                    popped.push(r);
                }
                prop_assert!(popped.len() == items.len(), "scheduler lost requests");
                for w in popped.windows(2) {
                    let ordered = match policy {
                        SchedPolicy::Fifo => w[0].id < w[1].id,
                        SchedPolicy::ShortestPromptFirst => {
                            (w[0].prompt.len(), w[0].id) < (w[1].prompt.len(), w[1].id)
                        }
                        SchedPolicy::Priority => {
                            (w[0].params.priority, w[0].id) < (w[1].params.priority, w[1].id)
                        }
                    };
                    prop_assert!(ordered, "out of order under {policy:?}");
                }
            }
            ok()
        },
    );
}

#[test]
fn verify_outcome_always_commits_accepted_plus_one() {
    // For any draft and any logits, the outcome accepts a prefix (0..=g) and
    // emits exactly one extra token; at T=0 the accepted prefix must match
    // argmax at every accepted position and mismatch at the rejection point.
    prop_check(
        "rejection sampler commits prefix + 1",
        400,
        |rng| {
            let v = 8usize;
            let g = rng.usize_below(5);
            let logits: Vec<Vec<f64>> = (0..=g)
                .map(|_| (0..v).map(|_| rng.f64() * 8.0 - 4.0).collect())
                .collect();
            let draft: Vec<i64> = (0..g).map(|_| rng.below(v as u64) as i64).collect();
            let temp_sel = rng.below(2);
            (logits, draft, temp_sel)
        },
        |(logits, draft, temp_sel)| {
            let rows: Vec<Vec<f32>> = logits
                .iter()
                .map(|r| r.iter().map(|&x| x as f32).collect())
                .collect();
            let d = Draft::point_mass(draft.iter().map(|&t| t as i32).collect());
            let temp = if *temp_sel == 0 { 0.0 } else { 1.0 };
            let mut rng = Pcg::seeded(42);
            let out = verify_draft(&d, |i| rows[i].as_slice(), temp, &mut rng);
            prop_assert!(out.accepted <= d.len(), "accepted > drafted");
            prop_assert!(
                (out.next_token as usize) < rows[0].len(),
                "next token out of vocab"
            );
            if temp == 0.0 {
                for i in 0..out.accepted {
                    let top = quasar::spec::argmax(&rows[i]) as i32;
                    prop_assert!(top == d.tokens[i], "accepted non-argmax at {i}");
                }
                if out.accepted < d.len() {
                    let top = quasar::spec::argmax(&rows[out.accepted]) as i32;
                    prop_assert!(
                        top != d.tokens[out.accepted],
                        "rejected an argmax match"
                    );
                    prop_assert!(out.next_token == top, "corrective != argmax");
                }
            }
            ok()
        },
    );
}

#[test]
fn ngram_drafts_are_always_copies_of_context() {
    // Whatever the stream, a PLD draft must be an exact substring of the
    // context whose preceding k-gram matches the context suffix.
    prop_check(
        "PLD drafts are verbatim context continuations",
        300,
        |rng| {
            let n = 3 + rng.usize_below(60);
            let vocab = 1 + rng.below(6);
            (0..n).map(|_| rng.below(vocab) as i64).collect::<Vec<i64>>()
        },
        |stream| {
            let toks: Vec<i32> = stream.iter().map(|&t| t as i32).collect();
            let mut ix = NgramIndex::new(1, 4);
            ix.extend(&toks);
            let draft = ix.draft(6, 1, 4);
            if draft.is_empty() {
                return ok();
            }
            // find the draft as a contiguous slice of the context
            let found = toks
                .windows(draft.len())
                .enumerate()
                .any(|(start, w)| {
                    if w != draft.as_slice() || start == 0 {
                        return false;
                    }
                    // some k-suffix of the context must precede this window
                    (1..=4usize).any(|k| {
                        start >= k
                            && toks.len() >= k
                            && toks[start - k..start] == toks[toks.len() - k..]
                    })
                });
            prop_assert!(found, "draft {draft:?} is not a matched continuation of {toks:?}");
            ok()
        },
    );
}

#[test]
fn tensor_row_splice_is_self_inverse() {
    prop_check(
        "splice row out and back leaves cache unchanged",
        200,
        |rng| {
            let vals: Vec<u64> = (0..2 * 3 * 4).map(|_| rng.below(100)).collect();
            let row = rng.below(3);
            (vals, row)
        },
        |(vals, row)| {
            let row = *row as usize;
            let data: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
            let orig = Tensor::from_vec(data, &[2, 3, 4]).unwrap();
            // extract row into a [2,1,4] tensor
            let mut single = Tensor::<f32>::zeros(&[2, 1, 4]);
            single.copy_axis1_row_from(0, &orig, row);
            // splice back into a copy with the row zeroed
            let mut modified = orig.clone();
            modified.zero_axis1_row(row);
            modified.copy_axis1_row_from(row, &single, 0);
            prop_assert!(modified == orig, "splice round-trip changed data");
            ok()
        },
    );
}

#[test]
fn json_roundtrip_fuzz() {
    use quasar::util::json::{parse, Json};
    // generate random JSON values, emit, reparse, compare
    fn gen_value(rng: &mut Pcg, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => Json::Str(format!("s{}né\"\\\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.usize_below(4)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    prop_check(
        "json emit->parse is identity",
        400,
        |rng| {
            let seed = rng.next_u64();
            seed
        },
        |seed| {
            let mut rng = Pcg::seeded(*seed);
            let v = gen_value(&mut rng, 0);
            let text = v.to_string();
            match parse(&text) {
                Ok(back) => {
                    prop_assert!(back == v, "roundtrip mismatch for {text}");
                    ok()
                }
                Err(e) => Err(format!("emitted invalid json {text}: {e}")),
            }
        },
    );
}

#[test]
fn tokenizer_roundtrips_vocab_sentences() {
    use quasar::tokenizer::Tokenizer;
    use quasar::util::json::parse;
    let tok_json = parse(
        r#"{"kind":"closed-lexicon-word",
            "vocab":["<pad>","<bos>","<eos>","<unk>","tom","has","3","apples",".","plus","equals"],
            "pad_id":0,"bos_id":1,"eos_id":2,"unk_id":3}"#,
    )
    .unwrap();
    let tok = Tokenizer::from_json(&tok_json).unwrap();
    let words = ["tom", "has", "3", "apples", ".", "plus", "equals"];
    prop_check(
        "decode(encode(x)) == x over the vocab language",
        300,
        |rng| {
            (0..1 + rng.usize_below(30))
                .map(|_| rng.below(words.len() as u64))
                .collect::<Vec<u64>>()
        },
        |idxs| {
            let text = idxs
                .iter()
                .map(|&i| words[i as usize])
                .collect::<Vec<_>>()
                .join(" ");
            let ids = tok.encode(&text, false);
            prop_assert!(tok.decode(&ids) == text, "roundtrip failed for {text}");
            ok()
        },
    );
}

// ---------------------------------------------------------------------
// Elastic-plan equivalence: gather -> execute -> scatter through planned
// sub-batches must commit token streams bit-identical to the monolithic
// full-bucket step. The "model" here is a deterministic mock chunk function
// over real BatchGroup / Tensor movement, so the property exercises the
// actual planning and KV row plumbing without PJRT.
// ---------------------------------------------------------------------

const SIM_L: usize = 2;
const SIM_H: usize = 2;
const SIM_S: usize = 64;
const SIM_HD: usize = 2;
const SIM_VOCAB: usize = 4;
const SIM_CHUNK: usize = 5; // verify chunk (gamma 4)

fn sim_device(bf16_ops: f64, launch_s: f64) -> CostModelCfg {
    CostModelCfg {
        device: "sim".into(),
        hbm_bw_bytes_per_s: 1.6e12,
        int8_ops_per_s: 2.0 * bf16_ops,
        bf16_ops_per_s: bf16_ops,
        bytes_per_weight: BTreeMap::from([("fp32".to_string(), 2.0)]),
        kernel_launch_s: launch_s,
        drafter_cost_per_token_s: 1e-6,
    }
}

fn sim_model_cfg(d_model: usize, max_seq: usize) -> ModelCfg {
    ModelCfg {
        name: "sim".into(), vocab_size: 64, d_model, n_layers: SIM_L,
        n_heads: 8, ffn_dim: 2 * d_model, max_seq, prefill_len: 16,
        gamma_max: SIM_CHUNK - 1, head_dim: 64,
    }
}

/// Three pricing regimes so the planner's *choice* varies across cases
/// while correctness must not: KV-bound (shrinks), compute-starved
/// (splits), weight-bound (stays monolithic-shaped).
fn sim_perf(sel: u64) -> PerfModel {
    match sel % 3 {
        0 => PerfModel::new(sim_device(188e12, 2e-5), sim_model_cfg(32, 4096)),
        1 => PerfModel::new(sim_device(1e12, 1e-9), sim_model_cfg(32, 4096)),
        _ => PerfModel::new(sim_device(188e12, 2e-5), sim_model_cfg(2048, 64)),
    }
}

fn tset(t: &mut Tensor<f32>, idx: &[usize], val: f32) {
    let strides = t.strides();
    let off: usize = idx.iter().zip(&strides).map(|(&i, &s)| i * s).sum();
    t.data[off] = val;
}

/// Deterministic row-independent "transformer chunk": writes each row's
/// tokens into the cache at `pos..pos+chunk` (every layer/head/dim carries
/// the token value) and emits one-hot logits whose argmax depends on the
/// row's entire cache prefix — so a wrong row map, stale gather, or wrong
/// position offset changes the output stream. `flip` models a *degraded
/// quantized variant*: same KV writes, but every argmax shifted by one —
/// zero top-1 agreement with the reference, which is what the fidelity
/// governor must catch.
fn mock_chunk(
    k: &mut Tensor<f32>,
    v: &mut Tensor<f32>,
    tokens: &[i32],
    pos: &[i32],
    bucket: usize,
    chunk: usize,
    flip: bool,
) -> Tensor<f32> {
    let mut logits = Tensor::<f32>::zeros(&[bucket, chunk, SIM_VOCAB]);
    for r in 0..bucket {
        let p0 = pos[r] as usize;
        for j in 0..chunk {
            let t = tokens[r * chunk + j] as f32;
            for l in 0..SIM_L {
                for h in 0..SIM_H {
                    for d in 0..SIM_HD {
                        tset(k, &[l, r, h, p0 + j, d], t);
                        tset(v, &[l, r, h, p0 + j, d], t + 0.5);
                    }
                }
            }
            let prefix: f32 = (0..=p0 + j).map(|p| k.at(&[0, r, 0, p, 0])).sum();
            // rem_euclid: padding rows of a dirty scratch can sum negative
            let mut next = (prefix as i64 * 31 + (p0 + j) as i64 * 7)
                .rem_euclid(SIM_VOCAB as i64) as usize;
            if flip {
                next = (next + 1) % SIM_VOCAB;
            }
            tset(&mut logits, &[r, j, next], 1.0);
        }
    }
    logits
}

struct SimReq {
    row: usize,
    committed: Vec<i32>,
    cached: usize,
}

/// Minimal engine over the mock chunk: monolithic mode reproduces the
/// pre-planner step (one full-bucket call, whole-cache adopt), elastic mode
/// runs the real plan -> gather -> execute -> scatter pipeline.
struct Sim {
    group: BatchGroup,
    reqs: Vec<SimReq>,
    log: CallLog,
    perf: PerfModel,
    full: usize,
    elastic: bool,
    /// Degraded-variant mode: the mock chunk flips every argmax (see
    /// `mock_chunk`). Toggled per step by the governed-sim test.
    flip: bool,
}

impl Sim {
    fn new(n_req: usize, full: usize, perf: PerfModel, elastic: bool) -> Sim {
        let mut group = BatchGroup::new(SIM_L, full, SIM_H, SIM_S, SIM_HD);
        let mut reqs = Vec::new();
        for i in 0..n_req {
            let prompt_tok = (i % SIM_VOCAB) as i32;
            let mut k1 = Tensor::<f32>::zeros(&[SIM_L, 1, SIM_H, SIM_S, SIM_HD]);
            let mut v1 = k1.clone();
            for l in 0..SIM_L {
                for h in 0..SIM_H {
                    for d in 0..SIM_HD {
                        tset(&mut k1, &[l, 0, h, 0, d], prompt_tok as f32);
                        tset(&mut v1, &[l, 0, h, 0, d], prompt_tok as f32 + 0.5);
                    }
                }
            }
            let row = group.join(i, &k1, &v1).unwrap();
            reqs.push(SimReq { row, committed: vec![prompt_tok], cached: 1 });
        }
        Sim { group, reqs, log: CallLog::default(), perf, full, elastic, flip: false }
    }

    fn commit(req: &mut SimReq, draft: &[i32], logits: &Tensor<f32>, lrow: usize) {
        let d = Draft::point_mass(draft.to_vec());
        let out = verify_draft(&d, |j| logits.row(&[lrow, j]), 0.0, &mut Pcg::seeded(0));
        let mut commit: Vec<i32> = d.tokens[..out.accepted].to_vec();
        commit.push(out.next_token);
        req.cached += commit.len();
        req.committed.extend_from_slice(&commit);
    }

    fn record(&mut self, fn_kind: FnKind, bucket: usize, chunk: usize, rows: usize,
              tokens_used: usize, useful: usize) {
        self.log.record(CallRecord {
            variant: "fp32".into(),
            fn_kind,
            batch: bucket,
            n_layers: SIM_L,
            active_rows: rows,
            tokens_used,
            chunk_len: chunk,
            useful_tokens: useful,
            wall_s: 0.0,
        });
    }

    fn step(&mut self, drafts: &[Vec<i32>]) {
        assert_eq!(drafts.len(), self.reqs.len());
        if self.elastic {
            self.step_elastic(drafts)
        } else {
            self.step_mono(drafts)
        }
    }

    /// Seed-engine shape: one call at the configured bucket, token block
    /// indexed by group row, whole-cache adopt.
    fn step_mono(&mut self, drafts: &[Vec<i32>]) {
        let any = drafts.iter().any(|d| !d.is_empty());
        let (fn_kind, chunk) = if any { (FnKind::Verify, SIM_CHUNK) } else { (FnKind::Decode, 1) };
        let b = self.full;
        let mut tokens = vec![0i32; b * chunk];
        let mut pos = vec![0i32; b];
        for (req, draft) in self.reqs.iter().zip(drafts) {
            tokens[req.row * chunk] = *req.committed.last().unwrap();
            for (j, &t) in draft.iter().enumerate().take(chunk - 1) {
                tokens[req.row * chunk + 1 + j] = t;
            }
            pos[req.row] = req.cached as i32;
        }
        let mut k = self.group.k.clone();
        let mut v = self.group.v.clone();
        let logits = mock_chunk(&mut k, &mut v, &tokens, &pos, b, chunk, self.flip);
        self.group.k = k; // whole-cache adopt, garbage rows included
        self.group.v = v;
        let used = drafts.iter().map(|d| d.len() + 1).max().unwrap_or(1);
        let useful: usize = drafts.iter().map(|d| d.len() + 1).sum();
        self.record(fn_kind, b, chunk, self.reqs.len(), used, useful);
        for (i, draft) in drafts.iter().enumerate() {
            let lrow = self.reqs[i].row;
            Self::commit(&mut self.reqs[i], draft, &logits, lrow);
        }
    }

    /// The refactored shape: plan, then gather/execute/scatter per
    /// sub-batch against dirty scratch caches.
    fn step_elastic(&mut self, drafts: &[Vec<i32>]) {
        let rows: Vec<PlanRow> =
            drafts.iter().map(|d| PlanRow::new(d.len(), 0)).collect();
        let buckets = [1usize, 2, 4];
        let plan = {
            let variants = [VariantCtx {
                name: "fp32",
                verify_buckets: &buckets,
                decode_buckets: &buckets,
            }];
            let ctx = PlanCtx {
                perf: &self.perf,
                variants: &variants,
                n_layers: SIM_L,
                full_bucket: self.full,
                verify_chunk: SIM_CHUNK,
                elastic: true,
            };
            plan_step(&ctx, &rows).unwrap()
        };
        assert!(plan.modeled_s <= plan.monolithic_s + 1e-15);
        for sb in &plan.sub_batches {
            let (bucket, chunk) = (sb.bucket, sb.chunk);
            let row_map: Vec<usize> = sb.rows.iter().map(|&di| self.reqs[di].row).collect();
            // dirty pooled scratch: gather must overwrite everything read
            let mut sk = Tensor::<f32>::zeros(&[SIM_L, bucket, SIM_H, SIM_S, SIM_HD]);
            sk.data.iter_mut().for_each(|x| *x = -7.0);
            let mut sv = sk.clone();
            self.group.gather_rows(&row_map, &mut sk, &mut sv).unwrap();
            let mut tokens = vec![0i32; bucket * chunk];
            let mut pos = vec![0i32; bucket];
            for (i, &di) in sb.rows.iter().enumerate() {
                let req = &self.reqs[di];
                tokens[i * chunk] = *req.committed.last().unwrap();
                for (j, &t) in drafts[di].iter().enumerate().take(chunk - 1) {
                    tokens[i * chunk + 1 + j] = t;
                }
                pos[i] = req.cached as i32;
            }
            let logits = mock_chunk(&mut sk, &mut sv, &tokens, &pos, bucket, chunk, self.flip);
            self.group.scatter_rows(&row_map, &sk, &sv).unwrap();
            self.record(sb.fn_kind, bucket, chunk, sb.rows.len(), sb.tokens_used,
                        sb.useful_tokens);
            for (i, &di) in sb.rows.iter().enumerate() {
                Self::commit(&mut self.reqs[di], &drafts[di], &logits, i);
            }
        }
    }
}

/// Drive monolithic and elastic sims with identical drafts; compare streams
/// and the committed cache prefix of every leased row.
fn run_equivalence(n_req: usize, perf_sel: u64, seed: u64, steps: usize) -> (Sim, Sim) {
    let full = 4usize;
    let mut mono = Sim::new(n_req, full, sim_perf(perf_sel), false);
    let mut ela = Sim::new(n_req, full, sim_perf(perf_sel), true);
    let mut rng = Pcg::seeded(seed ^ 0xE1A5);
    for _ in 0..steps {
        let drafts: Vec<Vec<i32>> = (0..n_req)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        mono.step(&drafts);
        ela.step(&drafts);
    }
    (mono, ela)
}

fn check_equivalent(mono: &Sim, ela: &Sim) -> Result<(), String> {
    for (i, (m, e)) in mono.reqs.iter().zip(&ela.reqs).enumerate() {
        prop_assert!(
            m.committed == e.committed,
            "req {i} streams diverged:\n  mono {:?}\n  ela  {:?}",
            m.committed, e.committed
        );
        prop_assert!(m.cached == e.cached, "req {i} cached diverged");
        // committed KV prefix must be bit-identical (positions beyond
        // `cached` hold unread speculative leftovers and may differ)
        for l in 0..SIM_L {
            for h in 0..SIM_H {
                for p in 0..m.cached {
                    for d in 0..SIM_HD {
                        let a = mono.group.k.at(&[l, m.row, h, p, d]);
                        let b = ela.group.k.at(&[l, e.row, h, p, d]);
                        prop_assert!(a == b, "req {i} kv prefix diverged at {l}/{h}/{p}/{d}");
                        let a = mono.group.v.at(&[l, m.row, h, p, d]);
                        let b = ela.group.v.at(&[l, e.row, h, p, d]);
                        prop_assert!(a == b, "req {i} v prefix diverged at {l}/{h}/{p}/{d}");
                    }
                }
            }
        }
    }
    ok()
}

#[test]
fn elastic_plan_commits_identical_streams_to_monolithic() {
    prop_check(
        "plan/gather/execute/scatter == monolithic full-bucket step",
        150,
        |rng| (1 + rng.below(4), rng.below(3), rng.next_u64()),
        |&(n_req, perf_sel, seed)| {
            let (mono, ela) = run_equivalence(n_req.max(1) as usize, perf_sel, seed, 5);
            check_equivalent(&mono, &ela)
        },
    );
}

#[test]
fn mixed_workload_splits_into_cheaper_sub_batches() {
    // Acceptance scenario: 3 rows in a batch-4 group, one drafting and two
    // decode-only, on the compute-starved device. The elastic engine must
    // execute at least one step as multiple sub-batches with buckets below
    // the configured one, commit identical tokens, and price below the
    // monolithic call log under PerfModel::run_time.
    let perf_sel = 1u64; // pad-heavy pricing regime
    let full = 4usize;
    let perf = sim_perf(perf_sel);
    let mut mono = Sim::new(3, full, sim_perf(perf_sel), false);
    let mut ela = Sim::new(3, full, sim_perf(perf_sel), true);
    let mut rng = Pcg::seeded(0xD1CE);
    for _ in 0..4 {
        // row 0 always drafts a full-depth guess; rows 1-2 never draft
        let draft: Vec<i32> =
            (0..SIM_CHUNK - 1).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect();
        let drafts = vec![draft, Vec::new(), Vec::new()];
        mono.step(&drafts);
        ela.step(&drafts);
    }
    check_equivalent(&mono, &ela).unwrap();

    // every monolithic step ran one call at the configured bucket
    assert!(mono.log.records.iter().all(|r| r.batch == full));
    assert_eq!(mono.log.records.len(), 4);
    // the planner split: more calls than steps, and smaller buckets
    assert!(ela.log.records.len() > 4, "expected multi-sub-batch steps");
    assert!(ela.log.records.iter().all(|r| r.batch < full));
    assert!(ela.log.records.iter().any(|r| r.fn_kind == FnKind::Decode));
    // and the executed plan prices strictly below the monolithic log
    let t_mono = perf.run_time(&mono.log, None);
    let t_ela = perf.run_time(&ela.log, None);
    assert!(
        t_ela < t_mono,
        "elastic modeled time {t_ela} not below monolithic {t_mono}"
    );
    // chunk efficiency improves: decode rows no longer pad the verify chunk
    assert!(ela.log.chunk_efficiency() > mono.log.chunk_efficiency());
}

// ---------------------------------------------------------------------
// Fidelity governor: the precision-policy state machine and its coupling
// to committed output. The quantized variant is modeled by `mock_chunk`'s
// `flip` mode (every argmax shifted — zero top-1 agreement); audits report
// agreement 1.0 when the variants coincide and 0.0 when flipped, exactly
// what the engine's logits comparison would measure on these one-hot rows.
// ---------------------------------------------------------------------

/// Audits a degraded verifier must demote within a bounded window:
/// `max(min_audits, ceil(ln floor / ln(1-alpha)))` forced-zero audits.
#[test]
fn governor_demotes_within_the_hysteresis_window_for_any_config() {
    prop_check(
        "bounded demotion window",
        300,
        |rng| {
            let min_audits = 1 + rng.below(8);
            let floor = 0.5 + rng.f64() * 0.49; // (0.5, 0.99)
            let alpha = 0.05 + rng.f64() * 0.9; // (0.05, 0.95)
            (min_audits, floor, alpha)
        },
        |&(min_audits, floor, alpha)| {
            // Clamp so shrunk candidates (the framework drives values
            // toward 0 on failure) stay in the config's sane domain.
            let min_audits = min_audits.clamp(1, 8);
            let floor = floor.clamp(0.5, 0.99);
            let alpha = alpha.clamp(0.05, 0.95);
            let mut g = Governor::new(
                GovernorConfig {
                    enabled: true,
                    min_audits: min_audits as u32,
                    floor,
                    alpha,
                    ..Default::default()
                },
                min_audits ^ 0xA0D1,
            );
            // EWMA from the optimistic 1.0 start under forced-zero
            // agreement: value after n audits is (1-alpha)^n. +1 absorbs
            // the strict-inequality boundary when the ratio lands on an
            // integer (EWMA == floor does not demote).
            let sink = (floor.ln() / (1.0 - alpha).ln()).ceil() as u64 + 1;
            let window = min_audits.max(sink);
            let mut demoted_at = None;
            for i in 1..=window + 2 {
                g.begin_step();
                match g.record_audit("c", 0.0, 0.0) {
                    Some(Transition::Demoted) => {
                        demoted_at = Some(i);
                        break;
                    }
                    Some(Transition::Promoted) => {
                        return Err("promoted a healthy-born class".into())
                    }
                    None => {}
                }
            }
            let at = match demoted_at {
                Some(at) => at,
                None => return Err(format!("never demoted within window {window}")),
            };
            prop_assert!(at >= min_audits, "demoted before the hysteresis gate");
            prop_assert!(at <= window, "demoted later than the bound {window}");
            prop_assert!(g.resolve("c") == Route::Reference, "resolve after demotion");
            ok()
        },
    );
}

/// Perfect agreement must never demote, no matter how long the run.
#[test]
fn governor_never_demotes_on_perfect_agreement() {
    prop_check(
        "perfect agreement stays primary",
        100,
        |rng| (1 + rng.below(500), rng.next_u64()),
        |&(n_audits, seed)| {
            let mut g = Governor::new(
                GovernorConfig { enabled: true, floor: 0.995, min_audits: 1, ..Default::default() },
                seed,
            );
            for _ in 0..n_audits {
                g.begin_step();
                if g.record_audit("c", 1.0, 0.0).is_some() {
                    return Err("transitioned under perfect agreement".into());
                }
                prop_assert!(g.resolve("c") == Route::Primary, "left Primary");
            }
            prop_assert!(g.demotions == 0, "demotion counter moved");
            ok()
        },
    );
}

/// End-to-end over the mock engine: a degraded quantized variant visibly
/// corrupts output until the governor demotes; afterwards (state persists
/// across requests) a governed run is bit-identical to the fp32-pinned sim.
/// A healthy variant never demotes and never diverges.
#[test]
fn governed_sim_demotes_on_degraded_quant_then_matches_fp32_pinned() {
    let gcfg = GovernorConfig {
        enabled: true,
        audit_rate: 1.0,
        floor: 0.98,
        min_audits: 3,
        alpha: 0.25,
        ..Default::default()
    };

    // Phase 1 — degraded: drive a governed sim whose quantized variant
    // flips every argmax. Audits report agreement 0.0 while the primary
    // runs quantized, 1.0 once probes compare identical fp32 outputs.
    let mut governor = Governor::new(gcfg.clone(), 7);
    let mut gov = Sim::new(2, 4, sim_perf(0), false);
    let mut fp = Sim::new(2, 4, sim_perf(0), false);
    let mut rng = Pcg::seeded(0x60_5157);
    let mut demoted_at = None;
    for step in 1..=10usize {
        let drafts: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        governor.begin_step();
        let quant = governor.resolve("c") == Route::Primary;
        gov.flip = quant; // degraded quantized variant
        gov.step(&drafts);
        fp.step(&drafts);
        let agreement = if quant { 0.0 } else { 1.0 };
        if governor.record_audit("c", agreement, 0.0) == Some(Transition::Demoted) {
            demoted_at = Some(step);
        }
    }
    let at = demoted_at.expect("degraded variant must demote");
    assert_eq!(at as u32, gcfg.min_audits, "demotes exactly at the hysteresis window");
    assert_eq!(governor.resolve("c"), Route::Reference);
    assert!(
        gov.reqs[0].committed != fp.reqs[0].committed,
        "degraded pre-demotion steps must have visibly corrupted the stream \
         (otherwise this test proves nothing)"
    );

    // Phase 2 — after demotion, fresh workload, same governor: every call
    // runs the reference variant, so the governed sim is bit-identical to
    // the fp32-pinned one.
    let mut gov2 = Sim::new(3, 4, sim_perf(0), false);
    let mut fp2 = Sim::new(3, 4, sim_perf(0), false);
    for _ in 0..8 {
        let drafts: Vec<Vec<i32>> = (0..3)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        governor.begin_step();
        let quant = governor.resolve("c") == Route::Primary;
        assert!(!quant, "demoted class must stay on the reference");
        gov2.flip = quant;
        gov2.step(&drafts);
        fp2.step(&drafts);
    }
    check_equivalent(&gov2, &fp2).expect("post-demotion output must be bit-identical to fp32");

    // Phase 3 — healthy: quantized agrees with the reference; the governor
    // must never demote and the governed stream never diverges.
    let mut g2 = Governor::new(gcfg, 9);
    let mut gov3 = Sim::new(2, 4, sim_perf(0), false);
    let mut fp3 = Sim::new(2, 4, sim_perf(0), false);
    for _ in 0..12 {
        let drafts: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        g2.begin_step();
        assert_eq!(g2.resolve("c"), Route::Primary);
        gov3.flip = false; // healthy quantized == reference argmax
        gov3.step(&drafts);
        fp3.step(&drafts);
        assert_eq!(g2.record_audit("c", 1.0, 0.0), None);
    }
    assert_eq!(g2.demotions, 0, "healthy verifier must never demote");
    check_equivalent(&gov3, &fp3).expect("healthy governed output matches fp32");
}

// ---------------------------------------------------------------------
// Prefix-cache lease safety (coordinator::prefixcache)
// ---------------------------------------------------------------------

#[test]
fn prefix_cache_never_evicts_leased_segments_for_any_interleaving() {
    // Arbitrary insert / lookup(+hold lease) / release interleavings over a
    // tiny byte budget (heavy eviction pressure). Invariants checked after
    // every op:
    //   1. every outstanding lease's segment is still resident (the evictor
    //      never frees leased KV), and splicing through it still works;
    //   2. the outstanding-lease count matches our model exactly;
    //   3. the cache only exceeds its byte budget while unleased victims
    //      are unavailable (all-but-newest leased).
    // At the end, releasing everything and inserting once more drives the
    // refcounts to zero and the resident bytes back under budget.
    let dims = [2usize, 1, 2, 8, 4];
    let row_bytes = 2 * dims.iter().product::<usize>() * 4;
    prop_check(
        "prefix cache lease safety",
        200,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(60)).map(|_| rng.below(1 << 16)).collect();
            ops
        },
        |ops| {
            let mut cache = PrefixCache::new(PrefixCacheConfig {
                enabled: true,
                budget_bytes: 2 * row_bytes, // room for two segments
                min_prefix: 1,
            });
            let (k, v) = (
                Tensor::<f32>::zeros(&dims),
                Tensor::<f32>::zeros(&dims),
            );
            // Keys drawn from a small alphabet so lookups actually hit.
            let key = |sel: u64| -> Vec<i32> {
                let len = 1 + (sel % 5) as usize;
                (0..len).map(|i| ((sel / 7 + i as u64) % 3) as i32 + 10).collect()
            };
            let mut held: Vec<Lease> = Vec::new();
            for &op in ops {
                match op % 3 {
                    0 => {
                        cache.insert("v", &key(op / 3), &k, &v);
                    }
                    1 => {
                        if let Some(l) = cache.lookup("v", &key(op / 3)) {
                            held.push(l);
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let idx = (op as usize / 3) % held.len();
                            cache.release(held.swap_remove(idx));
                        }
                    }
                }
                let stats = cache.stats();
                for l in &held {
                    prop_assert!(
                        cache.has_segment(l.id()),
                        "leased segment {} evicted (op {op})",
                        l.id()
                    );
                    let mut dk = Tensor::<f32>::zeros(&dims);
                    let mut dv = Tensor::<f32>::zeros(&dims);
                    prop_assert!(
                        cache.splice(l, &mut dk, &mut dv).is_ok(),
                        "splice through live lease {} failed",
                        l.id()
                    );
                }
                prop_assert!(
                    stats.leases == held.len(),
                    "lease accounting drifted: cache {} vs model {}",
                    stats.leases,
                    held.len()
                );
                // Right after an insert (the only point eviction runs), the
                // budget may only be exceeded under lease pressure: every
                // resident segment except possibly the just-inserted one is
                // leased. (A later release can leave the cache stale-over-
                // budget until the next insert — by design — so the check
                // is tied to insert ops.)
                if op % 3 == 0 {
                    let leased_ids: std::collections::BTreeSet<u64> =
                        held.iter().map(Lease::id).collect();
                    prop_assert!(
                        stats.resident_bytes <= cache.config().budget_bytes
                            || stats.segments <= leased_ids.len() + 1,
                        "over budget ({} bytes, {} segments) without lease \
                         pressure ({} leased)",
                        stats.resident_bytes,
                        stats.segments,
                        leased_ids.len()
                    );
                }
            }
            // Drain: refcounts return to zero and eviction can do its job.
            for l in held.drain(..) {
                cache.release(l);
            }
            cache.insert("v", &[99, 99, 99], &k, &v);
            let stats = cache.stats();
            prop_assert!(stats.leases == 0, "refcounts did not return to zero");
            prop_assert!(
                stats.resident_bytes <= cache.config().budget_bytes,
                "still over budget ({} bytes) with nothing leased",
                stats.resident_bytes
            );
            ok()
        },
    );
}

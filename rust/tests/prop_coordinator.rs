//! Property tests over coordinator/spec invariants (pure logic — no PJRT),
//! using the in-repo `util::prop` micro-framework.

use quasar::coordinator::{BatchGroup, GenParams, Priority, Request, SchedPolicy, Scheduler};
use quasar::prop_assert;
use quasar::runtime::Tensor;
use quasar::spec::{verify_draft, Draft, NgramIndex};
use quasar::util::prop::{ok, prop_check};
use quasar::util::rng::Pcg;

#[test]
fn batch_group_never_loses_or_duplicates_rows() {
    // Random join/leave sequences: every leased slot is unique, frees are
    // exact, and capacity is respected.
    prop_check(
        "batch group lease discipline",
        300,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(40)).map(|_| rng.below(100)).collect();
            ops
        },
        |ops| {
            let batch = 4;
            let mut g = BatchGroup::new(2, batch, 2, 8, 4);
            let k1 = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
            let mut next_slot = 0usize;
            let mut leased: Vec<(usize, usize)> = Vec::new(); // (row, slot)
            for &op in ops {
                if op % 2 == 0 {
                    // join
                    let r = g.join(next_slot, &k1, &k1);
                    if leased.len() < batch {
                        let row = match r {
                            Ok(row) => row,
                            Err(e) => return Err(format!("join failed with space: {e}")),
                        };
                        prop_assert!(
                            !leased.iter().any(|(rw, _)| *rw == row),
                            "row {row} double-leased"
                        );
                        leased.push((row, next_slot));
                        next_slot += 1;
                    } else {
                        prop_assert!(r.is_err(), "join succeeded on full group");
                    }
                } else if !leased.is_empty() {
                    let idx = (op as usize / 2) % leased.len();
                    let (row, slot) = leased.remove(idx);
                    match g.leave(row) {
                        Ok(s) => prop_assert!(s == slot, "leave returned wrong slot"),
                        Err(e) => return Err(format!("leave failed: {e}")),
                    }
                }
                // invariant: active rows equals our model
                let mut active = g.active_rows();
                active.sort_unstable();
                let mut expect = leased.clone();
                expect.sort_unstable();
                prop_assert!(active == expect, "active rows diverged");
                prop_assert!(
                    g.free_rows() == batch - leased.len(),
                    "free row count diverged"
                );
            }
            ok()
        },
    );
}

#[test]
fn scheduler_pop_order_matches_policy() {
    // For any mix of priorities and prompt lengths, draining the scheduler
    // yields a sequence sorted by the policy's key with arrival order as
    // the tiebreak — and never loses or duplicates a request.
    prop_check(
        "scheduler drains in policy order",
        300,
        |rng| {
            (0..rng.usize_below(24))
                .map(|_| (rng.below(3), 1 + rng.usize_below(9)))
                .collect::<Vec<(u64, usize)>>()
        },
        |items| {
            for policy in [
                SchedPolicy::Fifo,
                SchedPolicy::ShortestPromptFirst,
                SchedPolicy::Priority,
            ] {
                let mut s = Scheduler::new(policy);
                for (i, (pr, plen)) in items.iter().enumerate() {
                    let params = GenParams {
                        priority: match *pr {
                            0 => Priority::High,
                            1 => Priority::Normal,
                            _ => Priority::Low,
                        },
                        ..GenParams::default()
                    };
                    // id == arrival order + 1, so it doubles as the seq key
                    s.push(Request::new(i as u64 + 1, vec![1; *plen], params));
                }
                let mut popped: Vec<Request> = Vec::new();
                while let Some(r) = s.pop() {
                    popped.push(r);
                }
                prop_assert!(popped.len() == items.len(), "scheduler lost requests");
                for w in popped.windows(2) {
                    let ordered = match policy {
                        SchedPolicy::Fifo => w[0].id < w[1].id,
                        SchedPolicy::ShortestPromptFirst => {
                            (w[0].prompt.len(), w[0].id) < (w[1].prompt.len(), w[1].id)
                        }
                        SchedPolicy::Priority => {
                            (w[0].params.priority, w[0].id) < (w[1].params.priority, w[1].id)
                        }
                    };
                    prop_assert!(ordered, "out of order under {policy:?}");
                }
            }
            ok()
        },
    );
}

#[test]
fn verify_outcome_always_commits_accepted_plus_one() {
    // For any draft and any logits, the outcome accepts a prefix (0..=g) and
    // emits exactly one extra token; at T=0 the accepted prefix must match
    // argmax at every accepted position and mismatch at the rejection point.
    prop_check(
        "rejection sampler commits prefix + 1",
        400,
        |rng| {
            let v = 8usize;
            let g = rng.usize_below(5);
            let logits: Vec<Vec<f64>> = (0..=g)
                .map(|_| (0..v).map(|_| rng.f64() * 8.0 - 4.0).collect())
                .collect();
            let draft: Vec<i64> = (0..g).map(|_| rng.below(v as u64) as i64).collect();
            let temp_sel = rng.below(2);
            (logits, draft, temp_sel)
        },
        |(logits, draft, temp_sel)| {
            let rows: Vec<Vec<f32>> = logits
                .iter()
                .map(|r| r.iter().map(|&x| x as f32).collect())
                .collect();
            let d = Draft::point_mass(draft.iter().map(|&t| t as i32).collect());
            let temp = if *temp_sel == 0 { 0.0 } else { 1.0 };
            let mut rng = Pcg::seeded(42);
            let out = verify_draft(&d, |i| rows[i].as_slice(), temp, &mut rng);
            prop_assert!(out.accepted <= d.len(), "accepted > drafted");
            prop_assert!(
                (out.next_token as usize) < rows[0].len(),
                "next token out of vocab"
            );
            if temp == 0.0 {
                for i in 0..out.accepted {
                    let top = quasar::spec::argmax(&rows[i]) as i32;
                    prop_assert!(top == d.tokens[i], "accepted non-argmax at {i}");
                }
                if out.accepted < d.len() {
                    let top = quasar::spec::argmax(&rows[out.accepted]) as i32;
                    prop_assert!(
                        top != d.tokens[out.accepted],
                        "rejected an argmax match"
                    );
                    prop_assert!(out.next_token == top, "corrective != argmax");
                }
            }
            ok()
        },
    );
}

#[test]
fn ngram_drafts_are_always_copies_of_context() {
    // Whatever the stream, a PLD draft must be an exact substring of the
    // context whose preceding k-gram matches the context suffix.
    prop_check(
        "PLD drafts are verbatim context continuations",
        300,
        |rng| {
            let n = 3 + rng.usize_below(60);
            let vocab = 1 + rng.below(6);
            (0..n).map(|_| rng.below(vocab) as i64).collect::<Vec<i64>>()
        },
        |stream| {
            let toks: Vec<i32> = stream.iter().map(|&t| t as i32).collect();
            let mut ix = NgramIndex::new(1, 4);
            ix.extend(&toks);
            let draft = ix.draft(6, 1, 4);
            if draft.is_empty() {
                return ok();
            }
            // find the draft as a contiguous slice of the context
            let found = toks
                .windows(draft.len())
                .enumerate()
                .any(|(start, w)| {
                    if w != draft.as_slice() || start == 0 {
                        return false;
                    }
                    // some k-suffix of the context must precede this window
                    (1..=4usize).any(|k| {
                        start >= k
                            && toks.len() >= k
                            && toks[start - k..start] == toks[toks.len() - k..]
                    })
                });
            prop_assert!(found, "draft {draft:?} is not a matched continuation of {toks:?}");
            ok()
        },
    );
}

#[test]
fn tensor_row_splice_is_self_inverse() {
    prop_check(
        "splice row out and back leaves cache unchanged",
        200,
        |rng| {
            let vals: Vec<u64> = (0..2 * 3 * 4).map(|_| rng.below(100)).collect();
            let row = rng.below(3);
            (vals, row)
        },
        |(vals, row)| {
            let row = *row as usize;
            let data: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
            let orig = Tensor::from_vec(data, &[2, 3, 4]).unwrap();
            // extract row into a [2,1,4] tensor
            let mut single = Tensor::<f32>::zeros(&[2, 1, 4]);
            single.copy_axis1_row_from(0, &orig, row);
            // splice back into a copy with the row zeroed
            let mut modified = orig.clone();
            modified.zero_axis1_row(row);
            modified.copy_axis1_row_from(row, &single, 0);
            prop_assert!(modified == orig, "splice round-trip changed data");
            ok()
        },
    );
}

#[test]
fn json_roundtrip_fuzz() {
    use quasar::util::json::{parse, Json};
    // generate random JSON values, emit, reparse, compare
    fn gen_value(rng: &mut Pcg, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => Json::Str(format!("s{}né\"\\\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.usize_below(4)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    prop_check(
        "json emit->parse is identity",
        400,
        |rng| {
            let seed = rng.next_u64();
            seed
        },
        |seed| {
            let mut rng = Pcg::seeded(*seed);
            let v = gen_value(&mut rng, 0);
            let text = v.to_string();
            match parse(&text) {
                Ok(back) => {
                    prop_assert!(back == v, "roundtrip mismatch for {text}");
                    ok()
                }
                Err(e) => Err(format!("emitted invalid json {text}: {e}")),
            }
        },
    );
}

#[test]
fn tokenizer_roundtrips_vocab_sentences() {
    use quasar::tokenizer::Tokenizer;
    use quasar::util::json::parse;
    let tok_json = parse(
        r#"{"kind":"closed-lexicon-word",
            "vocab":["<pad>","<bos>","<eos>","<unk>","tom","has","3","apples",".","plus","equals"],
            "pad_id":0,"bos_id":1,"eos_id":2,"unk_id":3}"#,
    )
    .unwrap();
    let tok = Tokenizer::from_json(&tok_json).unwrap();
    let words = ["tom", "has", "3", "apples", ".", "plus", "equals"];
    prop_check(
        "decode(encode(x)) == x over the vocab language",
        300,
        |rng| {
            (0..1 + rng.usize_below(30))
                .map(|_| rng.below(words.len() as u64))
                .collect::<Vec<u64>>()
        },
        |idxs| {
            let text = idxs
                .iter()
                .map(|&i| words[i as usize])
                .collect::<Vec<_>>()
                .join(" ");
            let ids = tok.encode(&text, false);
            prop_assert!(tok.decode(&ids) == text, "roundtrip failed for {text}");
            ok()
        },
    );
}

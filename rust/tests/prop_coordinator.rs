//! Property tests over coordinator/spec invariants (pure logic — no PJRT),
//! using the in-repo `util::prop` micro-framework and the shared mock-chunk
//! harness in `tests/common`.

mod common;

use common::sim::{check_equivalent, mock_chunk, run_equivalence, sim_perf, Sim, SIM_CHUNK,
                  SIM_H, SIM_HD, SIM_L, SIM_S, SIM_VOCAB};
use quasar::coordinator::{
    build_ring, dispatch_decision, replica_of_id, ring_assign, BatchGroup, FnKind, GammaConfig,
    GammaController, GenParams, Governor, GovernorConfig, Lease, PagedGroup, PrefixCache,
    PrefixCacheConfig, Priority, Request, Route, SchedPolicy, Scheduler, Transition,
};
use quasar::prop_assert;
use quasar::runtime::Tensor;
use quasar::spec::{verify_draft, Draft, NgramIndex};
use quasar::util::prop::{ok, prop_check};
use quasar::util::rng::Pcg;

#[test]
fn batch_group_never_loses_or_duplicates_rows() {
    // Random join/leave sequences: every leased slot is unique, frees are
    // exact, and capacity is respected.
    prop_check(
        "batch group lease discipline",
        300,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(40)).map(|_| rng.below(100)).collect();
            ops
        },
        |ops| {
            let batch = 4;
            let mut g = BatchGroup::new(2, batch, 2, 8, 4);
            let k1 = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
            let mut next_slot = 0usize;
            let mut leased: Vec<(usize, usize)> = Vec::new(); // (row, slot)
            for &op in ops {
                if op % 2 == 0 {
                    // join
                    let r = g.join(next_slot, &k1, &k1);
                    if leased.len() < batch {
                        let row = match r {
                            Ok(row) => row,
                            Err(e) => return Err(format!("join failed with space: {e}")),
                        };
                        prop_assert!(
                            !leased.iter().any(|(rw, _)| *rw == row),
                            "row {row} double-leased"
                        );
                        leased.push((row, next_slot));
                        next_slot += 1;
                    } else {
                        prop_assert!(r.is_err(), "join succeeded on full group");
                    }
                } else if !leased.is_empty() {
                    let idx = (op as usize / 2) % leased.len();
                    let (row, slot) = leased.remove(idx);
                    match g.leave(row) {
                        Ok(s) => prop_assert!(s == slot, "leave returned wrong slot"),
                        Err(e) => return Err(format!("leave failed: {e}")),
                    }
                }
                // invariant: active rows equals our model
                let mut active = g.active_rows();
                active.sort_unstable();
                let mut expect = leased.clone();
                expect.sort_unstable();
                prop_assert!(active == expect, "active rows diverged");
                prop_assert!(
                    g.free_rows() == batch - leased.len(),
                    "free row count diverged"
                );
            }
            ok()
        },
    );
}

#[test]
fn scheduler_pop_order_matches_policy() {
    // For any mix of priorities and prompt lengths, draining the scheduler
    // yields a sequence sorted by the policy's key with arrival order as
    // the tiebreak — and never loses or duplicates a request.
    prop_check(
        "scheduler drains in policy order",
        300,
        |rng| {
            (0..rng.usize_below(24))
                .map(|_| (rng.below(3), 1 + rng.usize_below(9)))
                .collect::<Vec<(u64, usize)>>()
        },
        |items| {
            for policy in [
                SchedPolicy::Fifo,
                SchedPolicy::ShortestPromptFirst,
                SchedPolicy::Priority,
            ] {
                let mut s = Scheduler::new(policy);
                for (i, (pr, plen)) in items.iter().enumerate() {
                    let params = GenParams {
                        priority: match *pr {
                            0 => Priority::High,
                            1 => Priority::Normal,
                            _ => Priority::Low,
                        },
                        ..GenParams::default()
                    };
                    // id == arrival order + 1, so it doubles as the seq key
                    s.push(Request::new(i as u64 + 1, vec![1; *plen], params));
                }
                let mut popped: Vec<Request> = Vec::new();
                while let Some(r) = s.pop() {
                    popped.push(r);
                }
                prop_assert!(popped.len() == items.len(), "scheduler lost requests");
                for w in popped.windows(2) {
                    let ordered = match policy {
                        SchedPolicy::Fifo => w[0].id < w[1].id,
                        SchedPolicy::ShortestPromptFirst => {
                            (w[0].prompt.len(), w[0].id) < (w[1].prompt.len(), w[1].id)
                        }
                        SchedPolicy::Priority => {
                            (w[0].params.priority, w[0].id) < (w[1].params.priority, w[1].id)
                        }
                    };
                    prop_assert!(ordered, "out of order under {policy:?}");
                }
            }
            ok()
        },
    );
}

#[test]
fn verify_outcome_always_commits_accepted_plus_one() {
    // For any draft and any logits, the outcome accepts a prefix (0..=g) and
    // emits exactly one extra token; at T=0 the accepted prefix must match
    // argmax at every accepted position and mismatch at the rejection point.
    prop_check(
        "rejection sampler commits prefix + 1",
        400,
        |rng| {
            let v = 8usize;
            let g = rng.usize_below(5);
            let logits: Vec<Vec<f64>> = (0..=g)
                .map(|_| (0..v).map(|_| rng.f64() * 8.0 - 4.0).collect())
                .collect();
            let draft: Vec<i64> = (0..g).map(|_| rng.below(v as u64) as i64).collect();
            let temp_sel = rng.below(2);
            (logits, draft, temp_sel)
        },
        |(logits, draft, temp_sel)| {
            let rows: Vec<Vec<f32>> = logits
                .iter()
                .map(|r| r.iter().map(|&x| x as f32).collect())
                .collect();
            let d = Draft::point_mass(draft.iter().map(|&t| t as i32).collect());
            let temp = if *temp_sel == 0 { 0.0 } else { 1.0 };
            let mut rng = Pcg::seeded(42);
            let out = verify_draft(&d, |i| rows[i].as_slice(), temp, &mut rng);
            prop_assert!(out.accepted <= d.len(), "accepted > drafted");
            prop_assert!(
                (out.next_token as usize) < rows[0].len(),
                "next token out of vocab"
            );
            if temp == 0.0 {
                for i in 0..out.accepted {
                    let top = quasar::spec::argmax(&rows[i]) as i32;
                    prop_assert!(top == d.tokens[i], "accepted non-argmax at {i}");
                }
                if out.accepted < d.len() {
                    let top = quasar::spec::argmax(&rows[out.accepted]) as i32;
                    prop_assert!(
                        top != d.tokens[out.accepted],
                        "rejected an argmax match"
                    );
                    prop_assert!(out.next_token == top, "corrective != argmax");
                }
            }
            ok()
        },
    );
}

#[test]
fn ngram_drafts_are_always_copies_of_context() {
    // Whatever the stream, a PLD draft must be an exact substring of the
    // context whose preceding k-gram matches the context suffix.
    prop_check(
        "PLD drafts are verbatim context continuations",
        300,
        |rng| {
            let n = 3 + rng.usize_below(60);
            let vocab = 1 + rng.below(6);
            (0..n).map(|_| rng.below(vocab) as i64).collect::<Vec<i64>>()
        },
        |stream| {
            let toks: Vec<i32> = stream.iter().map(|&t| t as i32).collect();
            let mut ix = NgramIndex::new(1, 4);
            ix.extend(&toks);
            let draft = ix.draft(6, 1, 4);
            if draft.is_empty() {
                return ok();
            }
            // find the draft as a contiguous slice of the context
            let found = toks
                .windows(draft.len())
                .enumerate()
                .any(|(start, w)| {
                    if w != draft.as_slice() || start == 0 {
                        return false;
                    }
                    // some k-suffix of the context must precede this window
                    (1..=4usize).any(|k| {
                        start >= k
                            && toks.len() >= k
                            && toks[start - k..start] == toks[toks.len() - k..]
                    })
                });
            prop_assert!(found, "draft {draft:?} is not a matched continuation of {toks:?}");
            ok()
        },
    );
}

#[test]
fn tensor_row_splice_is_self_inverse() {
    prop_check(
        "splice row out and back leaves cache unchanged",
        200,
        |rng| {
            let vals: Vec<u64> = (0..2 * 3 * 4).map(|_| rng.below(100)).collect();
            let row = rng.below(3);
            (vals, row)
        },
        |(vals, row)| {
            let row = *row as usize;
            let data: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
            let orig = Tensor::from_vec(data, &[2, 3, 4]).unwrap();
            // extract row into a [2,1,4] tensor
            let mut single = Tensor::<f32>::zeros(&[2, 1, 4]);
            single.copy_axis1_row_from(0, &orig, row);
            // splice back into a copy with the row zeroed
            let mut modified = orig.clone();
            modified.zero_axis1_row(row);
            modified.copy_axis1_row_from(row, &single, 0);
            prop_assert!(modified == orig, "splice round-trip changed data");
            ok()
        },
    );
}

#[test]
fn json_roundtrip_fuzz() {
    use quasar::util::json::{parse, Json};
    // generate random JSON values, emit, reparse, compare
    fn gen_value(rng: &mut Pcg, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => Json::Str(format!("s{}né\"\\\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.usize_below(4)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    prop_check(
        "json emit->parse is identity",
        400,
        |rng| {
            let seed = rng.next_u64();
            seed
        },
        |seed| {
            let mut rng = Pcg::seeded(*seed);
            let v = gen_value(&mut rng, 0);
            let text = v.to_string();
            match parse(&text) {
                Ok(back) => {
                    prop_assert!(back == v, "roundtrip mismatch for {text}");
                    ok()
                }
                Err(e) => Err(format!("emitted invalid json {text}: {e}")),
            }
        },
    );
}

#[test]
fn tokenizer_roundtrips_vocab_sentences() {
    use quasar::tokenizer::Tokenizer;
    use quasar::util::json::parse;
    let tok_json = parse(
        r#"{"kind":"closed-lexicon-word",
            "vocab":["<pad>","<bos>","<eos>","<unk>","tom","has","3","apples",".","plus","equals"],
            "pad_id":0,"bos_id":1,"eos_id":2,"unk_id":3}"#,
    )
    .unwrap();
    let tok = Tokenizer::from_json(&tok_json).unwrap();
    let words = ["tom", "has", "3", "apples", ".", "plus", "equals"];
    prop_check(
        "decode(encode(x)) == x over the vocab language",
        300,
        |rng| {
            (0..1 + rng.usize_below(30))
                .map(|_| rng.below(words.len() as u64))
                .collect::<Vec<u64>>()
        },
        |idxs| {
            let text = idxs
                .iter()
                .map(|&i| words[i as usize])
                .collect::<Vec<_>>()
                .join(" ");
            let ids = tok.encode(&text, false);
            prop_assert!(tok.decode(&ids) == text, "roundtrip failed for {text}");
            ok()
        },
    );
}

// ---------------------------------------------------------------------
// Elastic-plan equivalence: gather -> execute -> scatter through planned
// sub-batches must commit token streams bit-identical to the monolithic
// full-bucket step. The harness (mock chunk + Sim engine) lives in
// `tests/common::sim` — real BatchGroup / Tensor movement and the real
// planner, no PJRT.
// ---------------------------------------------------------------------

#[test]
fn elastic_plan_commits_identical_streams_to_monolithic() {
    prop_check(
        "plan/gather/execute/scatter == monolithic full-bucket step",
        150,
        |rng| (1 + rng.below(4), rng.below(3), rng.next_u64()),
        |&(n_req, perf_sel, seed)| {
            let (mono, ela) = run_equivalence(n_req.max(1) as usize, perf_sel, seed, 5);
            check_equivalent(&mono, &ela)
        },
    );
}

#[test]
fn mixed_workload_splits_into_cheaper_sub_batches() {
    // Acceptance scenario: 3 rows in a batch-4 group, one drafting and two
    // decode-only, on the compute-starved device. The elastic engine must
    // execute at least one step as multiple sub-batches with buckets below
    // the configured one, commit identical tokens, and price below the
    // monolithic call log under PerfModel::run_time.
    let perf_sel = 1u64; // pad-heavy pricing regime
    let full = 4usize;
    let perf = sim_perf(perf_sel);
    let mut mono = Sim::new(3, full, sim_perf(perf_sel), false);
    let mut ela = Sim::new(3, full, sim_perf(perf_sel), true);
    let mut rng = Pcg::seeded(0xD1CE);
    for _ in 0..4 {
        // row 0 always drafts a full-depth guess; rows 1-2 never draft
        let draft: Vec<i32> =
            (0..SIM_CHUNK - 1).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect();
        let drafts = vec![draft, Vec::new(), Vec::new()];
        mono.step(&drafts);
        ela.step(&drafts);
    }
    check_equivalent(&mono, &ela).unwrap();

    // every monolithic step ran one call at the configured bucket
    assert!(mono.log.records.iter().all(|r| r.batch == full));
    assert_eq!(mono.log.records.len(), 4);
    // the planner split: more calls than steps, and smaller buckets
    assert!(ela.log.records.len() > 4, "expected multi-sub-batch steps");
    assert!(ela.log.records.iter().all(|r| r.batch < full));
    assert!(ela.log.records.iter().any(|r| r.fn_kind == FnKind::Decode));
    // and the executed plan prices strictly below the monolithic log
    let t_mono = perf.run_time(&mono.log, None);
    let t_ela = perf.run_time(&ela.log, None);
    assert!(
        t_ela < t_mono,
        "elastic modeled time {t_ela} not below monolithic {t_mono}"
    );
    // chunk efficiency improves: decode rows no longer pad the verify chunk
    assert!(ela.log.chunk_efficiency() > mono.log.chunk_efficiency());
}

// ---------------------------------------------------------------------
// Fidelity governor: the precision-policy state machine and its coupling
// to committed output. The quantized variant is modeled by the mock
// chunk's `flip` mode (every argmax shifted — zero top-1 agreement);
// audits report agreement 1.0 when the variants coincide and 0.0 when
// flipped, exactly what the engine's logits comparison would measure on
// these one-hot rows.
// ---------------------------------------------------------------------

/// Audits a degraded verifier must demote within a bounded window:
/// `max(min_audits, ceil(ln floor / ln(1-alpha)))` forced-zero audits.
#[test]
fn governor_demotes_within_the_hysteresis_window_for_any_config() {
    prop_check(
        "bounded demotion window",
        300,
        |rng| {
            let min_audits = 1 + rng.below(8);
            let floor = 0.5 + rng.f64() * 0.49; // (0.5, 0.99)
            let alpha = 0.05 + rng.f64() * 0.9; // (0.05, 0.95)
            (min_audits, floor, alpha)
        },
        |&(min_audits, floor, alpha)| {
            // Clamp so shrunk candidates (the framework drives values
            // toward 0 on failure) stay in the config's sane domain.
            let min_audits = min_audits.clamp(1, 8);
            let floor = floor.clamp(0.5, 0.99);
            let alpha = alpha.clamp(0.05, 0.95);
            let mut g = Governor::new(
                GovernorConfig {
                    enabled: true,
                    min_audits: min_audits as u32,
                    floor,
                    alpha,
                    ..Default::default()
                },
                min_audits ^ 0xA0D1,
            );
            // EWMA from the optimistic 1.0 start under forced-zero
            // agreement: value after n audits is (1-alpha)^n. +1 absorbs
            // the strict-inequality boundary when the ratio lands on an
            // integer (EWMA == floor does not demote).
            let sink = (floor.ln() / (1.0 - alpha).ln()).ceil() as u64 + 1;
            let window = min_audits.max(sink);
            let mut demoted_at = None;
            for i in 1..=window + 2 {
                g.begin_step();
                match g.record_audit("c", 0.0, 0.0) {
                    Some(Transition::Demoted) => {
                        demoted_at = Some(i);
                        break;
                    }
                    Some(Transition::Promoted) => {
                        return Err("promoted a healthy-born class".into())
                    }
                    None => {}
                }
            }
            let at = match demoted_at {
                Some(at) => at,
                None => return Err(format!("never demoted within window {window}")),
            };
            prop_assert!(at >= min_audits, "demoted before the hysteresis gate");
            prop_assert!(at <= window, "demoted later than the bound {window}");
            prop_assert!(g.resolve("c") == Route::Reference, "resolve after demotion");
            ok()
        },
    );
}

/// Perfect agreement must never demote, no matter how long the run.
#[test]
fn governor_never_demotes_on_perfect_agreement() {
    prop_check(
        "perfect agreement stays primary",
        100,
        |rng| (1 + rng.below(500), rng.next_u64()),
        |&(n_audits, seed)| {
            let mut g = Governor::new(
                GovernorConfig { enabled: true, floor: 0.995, min_audits: 1, ..Default::default() },
                seed,
            );
            for _ in 0..n_audits {
                g.begin_step();
                if g.record_audit("c", 1.0, 0.0).is_some() {
                    return Err("transitioned under perfect agreement".into());
                }
                prop_assert!(g.resolve("c") == Route::Primary, "left Primary");
            }
            prop_assert!(g.demotions == 0, "demotion counter moved");
            ok()
        },
    );
}

/// End-to-end over the mock engine: a degraded quantized variant visibly
/// corrupts output until the governor demotes; afterwards (state persists
/// across requests) a governed run is bit-identical to the fp32-pinned sim.
/// A healthy variant never demotes and never diverges.
#[test]
fn governed_sim_demotes_on_degraded_quant_then_matches_fp32_pinned() {
    let gcfg = GovernorConfig {
        enabled: true,
        audit_rate: 1.0,
        floor: 0.98,
        min_audits: 3,
        alpha: 0.25,
        ..Default::default()
    };

    // Phase 1 — degraded: drive a governed sim whose quantized variant
    // flips every argmax. Audits report agreement 0.0 while the primary
    // runs quantized, 1.0 once probes compare identical fp32 outputs.
    let mut governor = Governor::new(gcfg.clone(), 7);
    let mut gov = Sim::new(2, 4, sim_perf(0), false);
    let mut fp = Sim::new(2, 4, sim_perf(0), false);
    let mut rng = Pcg::seeded(0x60_5157);
    let mut demoted_at = None;
    for step in 1..=10usize {
        let drafts: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        governor.begin_step();
        let quant = governor.resolve("c") == Route::Primary;
        gov.flip = quant; // degraded quantized variant
        gov.step(&drafts);
        fp.step(&drafts);
        let agreement = if quant { 0.0 } else { 1.0 };
        if governor.record_audit("c", agreement, 0.0) == Some(Transition::Demoted) {
            demoted_at = Some(step);
        }
    }
    let at = demoted_at.expect("degraded variant must demote");
    assert_eq!(at as u32, gcfg.min_audits, "demotes exactly at the hysteresis window");
    assert_eq!(governor.resolve("c"), Route::Reference);
    assert!(
        gov.reqs[0].committed != fp.reqs[0].committed,
        "degraded pre-demotion steps must have visibly corrupted the stream \
         (otherwise this test proves nothing)"
    );

    // Phase 2 — after demotion, fresh workload, same governor: every call
    // runs the reference variant, so the governed sim is bit-identical to
    // the fp32-pinned one.
    let mut gov2 = Sim::new(3, 4, sim_perf(0), false);
    let mut fp2 = Sim::new(3, 4, sim_perf(0), false);
    for _ in 0..8 {
        let drafts: Vec<Vec<i32>> = (0..3)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        governor.begin_step();
        let quant = governor.resolve("c") == Route::Primary;
        assert!(!quant, "demoted class must stay on the reference");
        gov2.flip = quant;
        gov2.step(&drafts);
        fp2.step(&drafts);
    }
    check_equivalent(&gov2, &fp2).expect("post-demotion output must be bit-identical to fp32");

    // Phase 3 — healthy: quantized agrees with the reference; the governor
    // must never demote and the governed stream never diverges.
    let mut g2 = Governor::new(gcfg, 9);
    let mut gov3 = Sim::new(2, 4, sim_perf(0), false);
    let mut fp3 = Sim::new(2, 4, sim_perf(0), false);
    for _ in 0..12 {
        let drafts: Vec<Vec<i32>> = (0..2)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        g2.begin_step();
        assert_eq!(g2.resolve("c"), Route::Primary);
        gov3.flip = false; // healthy quantized == reference argmax
        gov3.step(&drafts);
        fp3.step(&drafts);
        assert_eq!(g2.record_audit("c", 1.0, 0.0), None);
    }
    assert_eq!(g2.demotions, 0, "healthy verifier must never demote");
    check_equivalent(&gov3, &fp3).expect("healthy governed output matches fp32");
}

// ---------------------------------------------------------------------
// Paged prefix cache (coordinator::prefixcache): pool allocator safety and
// a differential check against the PR-4 whole-row segment semantics.
// ---------------------------------------------------------------------

const PX_DIMS: [usize; 5] = [2, 1, 2, 32, 4]; // [L, 1, H, S, hd]
const PX_PAGE: usize = 4; // page_tokens
const PX_PAGE_BYTES: usize = 2 * 2 * 2 * PX_PAGE * 4 * 4; // k+v pair, f32

/// A source row whose position `s` holds `tokens[s]` (`+0.5` on the v
/// side) — the shape real KV sharing relies on: identical token prefixes
/// mean identical bytes, so any matched run must serve exactly the query's
/// token values.
fn token_row(tokens: &[i32]) -> (Tensor<f32>, Tensor<f32>) {
    assert!(tokens.len() <= PX_DIMS[3]);
    let mut k = Tensor::<f32>::zeros(&PX_DIMS);
    let mut v = Tensor::<f32>::zeros(&PX_DIMS);
    let (h_n, s_n, d_n) = (PX_DIMS[2], PX_DIMS[3], PX_DIMS[4]);
    for l in 0..PX_DIMS[0] {
        for h in 0..h_n {
            for (s, &t) in tokens.iter().enumerate() {
                for d in 0..d_n {
                    let off = ((l * h_n + h) * s_n + s) * d_n + d;
                    k.data[off] = t as f32;
                    v.data[off] = t as f32 + 0.5;
                }
            }
        }
    }
    (k, v)
}

fn lcp_len(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Satellite: the page-pool allocator under arbitrary interleavings of
/// lease / extend / release / insert. Invariants checked after every op:
///
/// 1. a leased run — and **every page it references** — stays resident, a
///    splice through it succeeds, and the spliced bytes equal the query's
///    token coding (so a freed-and-reused or mis-tiled page is caught by
///    value, not just by id);
/// 2. lease accounting matches the model exactly, and refcounts return to
///    zero once everything is released;
/// 3. every resident run's pages tile `ceil(len/page_tokens)` without
///    duplicates (page-run token ranges never overlap in the pool), every
///    referenced page is live, and the pool's `page_refs` / byte / page
///    accounting is internally consistent;
/// 4. resident bytes only exceed the budget under lease pressure (checked
///    at insert ops, the only point eviction runs).
#[test]
fn paged_pool_holds_invariants_for_any_interleaving() {
    prop_check(
        "paged pool lease/extend/release/insert safety",
        200,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(60)).map(|_| rng.below(1 << 16)).collect();
            ops
        },
        |ops| {
            let mut cache = PrefixCache::new(PrefixCacheConfig {
                enabled: true,
                budget_bytes: 4 * PX_PAGE_BYTES, // heavy eviction pressure
                min_prefix: 1,
                page_tokens: PX_PAGE,
                mid_stream: true,
            });
            // Keys share a 4-token template spine and branch after it, so
            // page sharing, tail COW, and partial matches all exercise.
            let key = |sel: u64| -> Vec<i32> {
                let len = 1 + (sel % 10) as usize;
                let branch = ((sel / 11) % 3) as i32;
                (0..len)
                    .map(|i| if i < 4 { 7 } else { branch * 10 + i as i32 })
                    .collect()
            };
            let mut held: Vec<(Lease, Vec<i32>)> = Vec::new();
            for &op in ops {
                match op % 4 {
                    0 => {
                        // insert
                        let kk = key(op / 4);
                        let (k, v) = token_row(&kk);
                        cache.insert("v", &kk, &k, &v);
                    }
                    1 => {
                        // extend: a strict extension of a (likely cached)
                        // key — the tail-page in-place/COW path, flagged as
                        // a mid-stream snapshot with the base as its prompt.
                        let base_len = key(op / 4).len();
                        let mut kk = key(op / 4);
                        kk.extend_from_slice(&[90, 91, 92]);
                        let (k, v) = token_row(&kk);
                        cache.insert_from_row("v", &kk, &k, &v, 0, Some(base_len));
                    }
                    2 => {
                        // lease and hold
                        let q = key(op / 4);
                        if let Some(l) = cache.lookup("v", &q) {
                            held.push((l, q));
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let idx = (op as usize / 4) % held.len();
                            let (l, _) = held.swap_remove(idx);
                            cache.release(l);
                        }
                    }
                }
                let stats = cache.stats();
                // 1. leased runs + their pages resident; spliced content
                //    equals the query's token coding.
                for (l, q) in &held {
                    prop_assert!(cache.has_run(l.id()), "leased run {} evicted", l.id());
                    for pid in cache.run_pages(l.id()).expect("leased run resident") {
                        prop_assert!(cache.has_page(pid), "leased page {pid} freed");
                    }
                    let mut dk = Tensor::<f32>::zeros(&PX_DIMS);
                    let mut dv = Tensor::<f32>::zeros(&PX_DIMS);
                    prop_assert!(
                        cache.splice(l, &mut dk, &mut dv).is_ok(),
                        "splice through live lease {} failed",
                        l.id()
                    );
                    for s in 0..l.len() {
                        prop_assert!(
                            dk.at(&[0, 0, 0, s, 0]) == q[s] as f32
                                && dv.at(&[1, 0, 1, s, 2]) == q[s] as f32 + 0.5,
                            "spliced bytes diverged from the matched tokens at {s}"
                        );
                    }
                    for s in l.len()..PX_DIMS[3] {
                        prop_assert!(
                            dk.at(&[0, 0, 0, s, 0]) == 0.0,
                            "splice leaked past the match at {s}"
                        );
                    }
                }
                // 2. lease accounting.
                prop_assert!(
                    stats.leases == held.len(),
                    "lease accounting drifted: cache {} vs model {}",
                    stats.leases,
                    held.len()
                );
                // 3. page-run tiling + pool accounting.
                let mut total_refs = 0usize;
                for id in cache.run_ids() {
                    let pages = cache.run_pages(id).expect("listed run resident");
                    let len = cache.run_key_len(id).expect("listed run resident");
                    prop_assert!(
                        pages.len() == len.div_ceil(PX_PAGE),
                        "run {id} pages do not tile its {len}-token key"
                    );
                    let mut uniq = pages.clone();
                    uniq.sort_unstable();
                    uniq.dedup();
                    prop_assert!(
                        uniq.len() == pages.len(),
                        "page token ranges overlap within run {id}"
                    );
                    for pid in &pages {
                        prop_assert!(cache.has_page(*pid), "run {id} references freed page");
                    }
                    total_refs += pages.len();
                }
                prop_assert!(
                    total_refs == stats.page_refs,
                    "page_refs accounting drifted: {} vs {}",
                    total_refs,
                    stats.page_refs
                );
                prop_assert!(
                    stats.resident_bytes == stats.resident_pages * PX_PAGE_BYTES,
                    "byte accounting is not page-granular"
                );
                // 4. Right after an insert-type op (the only point eviction
                //    runs), the budget may only be exceeded under lease
                //    pressure: every resident run except possibly the
                //    just-inserted one is leased.
                if op % 4 <= 1 {
                    let leased_runs: std::collections::BTreeSet<u64> =
                        held.iter().map(|(l, _)| l.id()).collect();
                    prop_assert!(
                        stats.resident_bytes <= cache.config().budget_bytes
                            || stats.segments <= leased_runs.len() + 1,
                        "over budget ({} bytes, {} runs) without lease pressure \
                         ({} leased)",
                        stats.resident_bytes,
                        stats.segments,
                        leased_runs.len()
                    );
                }
            }
            // Drain: refcounts return to zero and eviction can do its job.
            for (l, _) in held.drain(..) {
                cache.release(l);
            }
            let (k, v) = token_row(&[99, 99, 99]);
            cache.insert("v", &[99, 99, 99], &k, &v);
            let stats = cache.stats();
            prop_assert!(stats.leases == 0, "refcounts did not return to zero");
            prop_assert!(
                stats.resident_bytes <= cache.config().budget_bytes,
                "still over budget ({} bytes) with nothing leased",
                stats.resident_bytes
            );
            ok()
        },
    );
}

/// Satellite: differential test against PR 4's whole-row segment store —
/// for any insert/lookup sequence (no budget pressure, so hit sets match),
/// the paged cache must produce the same hit/miss decisions, the same
/// match lengths, and byte-identical spliced KV as a whole-row oracle,
/// while never holding more resident bytes than the oracle's
/// one-`max_seq`-row-per-key footprint.
#[test]
fn paged_cache_matches_the_whole_row_segment_oracle() {
    prop_check(
        "paged store == whole-row store semantics, fewer bytes",
        150,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(40)).map(|_| rng.below(1 << 16)).collect();
            ops
        },
        |ops| {
            let min_prefix = 2usize;
            let mut paged = PrefixCache::new(PrefixCacheConfig {
                enabled: true,
                budget_bytes: usize::MAX / 4, // no eviction on either side
                min_prefix,
                page_tokens: PX_PAGE,
                mid_stream: true,
            });
            // The oracle: PR-4 semantics. One whole-row copy per distinct
            // key, longest-common-prefix matching over all stored keys,
            // prefix-bounded splice.
            let mut oracle: Vec<(Vec<i32>, Tensor<f32>, Tensor<f32>)> = Vec::new();
            let key = |sel: u64| -> Vec<i32> {
                let len = 1 + (sel % 9) as usize;
                let branch = ((sel / 9) % 4) as i32;
                (0..len)
                    .map(|i| if i < 3 { 5 } else { branch * 16 + i as i32 + 1 })
                    .collect()
            };
            for &op in ops {
                let kk = key(op / 2);
                if op % 2 == 0 {
                    let (k, v) = token_row(&kk);
                    paged.insert("v", &kk, &k, &v);
                    if kk.len() >= min_prefix && !oracle.iter().any(|(ek, ..)| *ek == kk) {
                        oracle.push((kk, k, v));
                    }
                } else {
                    let want = oracle
                        .iter()
                        .map(|(ek, ..)| lcp_len(ek, &kk))
                        .max()
                        .filter(|&m| m >= min_prefix);
                    match (paged.lookup("v", &kk), want) {
                        (None, None) => {}
                        (Some(l), Some(w)) => {
                            prop_assert!(
                                l.len() == w,
                                "match length diverged: paged {} vs oracle {w}",
                                l.len()
                            );
                            let mut pk = Tensor::<f32>::zeros(&PX_DIMS);
                            let mut pv = Tensor::<f32>::zeros(&PX_DIMS);
                            paged.splice(&l, &mut pk, &mut pv).map_err(|e| e.to_string())?;
                            let (_, ok_src, ov_src) = oracle
                                .iter()
                                .max_by_key(|(ek, ..)| lcp_len(ek, &kk))
                                .expect("oracle hit has a source");
                            let mut qk = Tensor::<f32>::zeros(&PX_DIMS);
                            let mut qv = Tensor::<f32>::zeros(&PX_DIMS);
                            qk.copy_seq_prefix_from(ok_src, w);
                            qv.copy_seq_prefix_from(ov_src, w);
                            prop_assert!(
                                pk == qk && pv == qv,
                                "spliced bytes diverged from the whole-row oracle"
                            );
                            paged.release(l);
                        }
                        (got, want) => {
                            let got = got.map(|l| {
                                let n = l.len();
                                paged.release(l);
                                n
                            });
                            return Err(format!(
                                "hit/miss diverged: paged {got:?} vs oracle {want:?}"
                            ));
                        }
                    }
                }
            }
            // Same hit set, page-granular residency: the paged store never
            // exceeds the whole-row store's footprint for these keys.
            let row_bytes = 2 * PX_DIMS.iter().product::<usize>() * 4;
            let stats = paged.stats();
            prop_assert!(
                stats.resident_bytes <= oracle.len() * row_bytes,
                "paged resident {} bytes exceeds whole-row {} bytes",
                stats.resident_bytes,
                oracle.len() * row_bytes
            );
            ok()
        },
    );
}

// ---------------------------------------------------------------------
// Page-table batch rows (kv::PagedGroup over the pool): random
// admit / advance / finish(+snapshot) interleavings, differential against
// the copy-based slab backend (PR-5 oracle pattern).
// ---------------------------------------------------------------------

/// Token-code positions `[from, from + toks.len())` of scratch row `row`:
/// position `s` holds the token value on the k side, `+0.5` on v — the
/// same coding as [`token_row`], extended mid-sequence.
fn code_into(k: &mut Tensor<f32>, v: &mut Tensor<f32>, row: usize, from: usize, toks: &[i32]) {
    let strides = k.strides();
    for (j, &t) in toks.iter().enumerate() {
        let s = from + j;
        for l in 0..k.dims[0] {
            for h in 0..k.dims[2] {
                for d in 0..k.dims[4] {
                    let off = l * strides[0] + row * strides[1] + h * strides[2]
                        + s * strides[3] + d * strides[4];
                    k.data[off] = t as f32;
                    v.data[off] = t as f32 + 0.5;
                }
            }
        }
    }
}

/// Tentpole property: for any interleaving of admissions (insert-then-lease,
/// the engine's ordering), committed advances (length-bounded gather →
/// chunk write → delta scatter), and finishes (with or without a
/// by-reference mid-stream snapshot), page-table rows must behave exactly
/// like the copy-based slab rows, under heavy pool eviction pressure:
///
/// 1. **bit-identity** — every gathered committed prefix is byte-equal
///    between the two backends (the slab is the oracle);
/// 2. **no full-page admission copies** — admission after inserting the
///    prefill shares every full page by refcount bump (`rp.copied == 0`),
///    warm or cold;
/// 3. **live pages stay live** — a page referenced by any leased row is
///    never freed out from under it, and the pool's row-reference
///    accounting exactly matches the groups' page tables;
/// 4. **refcounts return to zero** — after every row leaves, no row
///    references remain and the slab is bit-zero (leave's committed-prefix
///    zeroing invariant).
#[test]
fn paged_rows_match_slab_rows_under_random_interleavings() {
    prop_check(
        "paged rows == slab rows; refcounts return to zero",
        120,
        |rng| {
            let ops: Vec<u64> = (0..rng.usize_below(50)).map(|_| rng.next_u64()).collect();
            ops
        },
        |ops| {
            const BATCH: usize = 3;
            let max_seq = PX_DIMS[3];
            let mut pool = PrefixCache::new(PrefixCacheConfig {
                enabled: true,
                budget_bytes: 6 * PX_PAGE_BYTES, // heavy eviction pressure
                min_prefix: 1,
                page_tokens: PX_PAGE,
                mid_stream: true,
            });
            let mut paged = PagedGroup::new(BATCH, PX_PAGE, max_seq);
            let mut slab = BatchGroup::new(PX_DIMS[0], BATCH, PX_DIMS[2], max_seq, PX_DIMS[4]);
            struct LiveRow {
                row_p: usize,
                row_c: usize,
                committed: Vec<i32>,
                prompt_len: usize,
            }
            let mut live: Vec<LiveRow> = Vec::new();
            let mut next_slot = 0usize;
            // Prompts share an 8-token spine (two full pages) then branch,
            // so admissions lease genuinely shared pages across rows.
            let prompt = |sel: u64| -> Vec<i32> {
                let len = 1 + (sel % 11) as usize;
                let branch = ((sel / 11) % 3) as i32;
                (0..len)
                    .map(|i| if i < 8 { 7 } else { branch * 10 + i as i32 })
                    .collect()
            };
            let dirty = || {
                let mut t = Tensor::<f32>::zeros(&PX_DIMS);
                t.data.iter_mut().for_each(|x| *x = -7.0);
                t
            };
            for &op in ops {
                match op % 4 {
                    0 if paged.free_rows() > 0 => {
                        // Admit, in the engine's order: insert the prefill
                        // into the pool, then lease — so even a cold prompt
                        // shares every full page with its own fresh run.
                        let pr = prompt(op >> 2);
                        let (k1, v1) = token_row(&pr);
                        pool.insert("v", &pr, &k1, &v1);
                        let rp = pool
                            .lease_row_pages("v", &pr, &k1, &v1, 0)
                            .map_err(|e| e.to_string())?;
                        prop_assert!(
                            rp.copied == 0,
                            "admission copied {} full pages after inserting the prefill",
                            rp.copied
                        );
                        let row_p = paged
                            .join_pages(next_slot, rp.pages, pr.len())
                            .map_err(|e| e.to_string())?;
                        let row_c = slab
                            .join_prefix(next_slot, &k1, &v1, pr.len())
                            .map_err(|e| e.to_string())?;
                        let prompt_len = pr.len();
                        live.push(LiveRow { row_p, row_c, committed: pr, prompt_len });
                        next_slot += 1;
                    }
                    1 if !live.is_empty() => {
                        // Advance: gather the committed prefix into dirty
                        // scratch, "execute" a chunk (token-code the new
                        // positions), write back — delta-only on the paged
                        // side, full prefix on the slab side.
                        let i = ((op >> 2) as usize) % live.len();
                        let lv = &mut live[i];
                        let cached = lv.committed.len();
                        if cached < max_seq {
                            let chunk = (1 + ((op >> 8) % 4) as usize).min(max_seq - cached);
                            let toks: Vec<i32> = (0..chunk)
                                .map(|j| (((op >> 16) as usize + j) % 40) as i32 + 1)
                                .collect();
                            let (mut pk, mut pv) = (dirty(), dirty());
                            paged
                                .gather_rows(&pool, &[(lv.row_p, cached)], &mut pk, &mut pv)
                                .map_err(|e| e.to_string())?;
                            let (mut ck, mut cv) = (dirty(), dirty());
                            slab.gather_rows(&[(lv.row_c, cached)], &mut ck, &mut cv)
                                .map_err(|e| e.to_string())?;
                            // bit-identity oracle over the committed prefix
                            for s in 0..cached {
                                for l in 0..PX_DIMS[0] {
                                    for h in 0..PX_DIMS[2] {
                                        for d in 0..PX_DIMS[4] {
                                            let idx = [l, 0, h, s, d];
                                            prop_assert!(
                                                pk.at(&idx) == ck.at(&idx)
                                                    && pv.at(&idx) == cv.at(&idx),
                                                "gathered prefix diverged at pos {s}"
                                            );
                                        }
                                    }
                                }
                            }
                            prop_assert!(
                                pk.at(&[0, 0, 0, cached - 1, 0])
                                    == lv.committed[cached - 1] as f32,
                                "gathered bytes are not the committed token coding"
                            );
                            let to = cached + chunk;
                            code_into(&mut pk, &mut pv, 0, cached, &toks);
                            code_into(&mut ck, &mut cv, 0, cached, &toks);
                            paged
                                .scatter_advance(&mut pool, &[(lv.row_p, cached, to)], &pk, &pv)
                                .map_err(|e| e.to_string())?;
                            paged.set_len(lv.row_p, to).map_err(|e| e.to_string())?;
                            slab.scatter_rows(&[(lv.row_c, to)], &ck, &cv)
                                .map_err(|e| e.to_string())?;
                            lv.committed.extend_from_slice(&toks);
                        }
                    }
                    2 | 3 if !live.is_empty() => {
                        // Finish; on the even arm take a finish-time
                        // mid-stream snapshot first — refcount bumps on the
                        // row's own pages, partial tail included.
                        let i = ((op >> 2) as usize) % live.len();
                        let lv = live.swap_remove(i);
                        if op % 4 == 2 && lv.committed.len() > lv.prompt_len {
                            let pages: Vec<u64> =
                                paged.row_pages(lv.row_p).expect("live row").to_vec();
                            pool.insert_pages("v", &lv.committed, &pages, Some(lv.prompt_len));
                        }
                        let sp = paged.leave(&mut pool, lv.row_p).map_err(|e| e.to_string())?;
                        let sc = slab.leave(lv.row_c).map_err(|e| e.to_string())?;
                        prop_assert!(sp == sc, "backends returned different slots on leave");
                    }
                    _ => {}
                }
                // Live pages stay live: every page referenced by a leased
                // row is still allocated, and the pool's row-reference
                // count equals the group's page-table total.
                for lv in &live {
                    for pid in paged.row_pages(lv.row_p).expect("live row") {
                        prop_assert!(
                            pool.page_ref_count(*pid).is_some(),
                            "page {pid} freed out from under a live row"
                        );
                    }
                }
                let stats = pool.stats();
                prop_assert!(
                    stats.row_page_refs == paged.total_pages(),
                    "row-page reference accounting drifted: pool {} vs group {}",
                    stats.row_page_refs,
                    paged.total_pages()
                );
            }
            // Drain: every row leaves, refcounts return to zero, and the
            // slab's leave zeroing holds bit-exactly.
            for lv in live.drain(..) {
                paged.leave(&mut pool, lv.row_p).map_err(|e| e.to_string())?;
                slab.leave(lv.row_c).map_err(|e| e.to_string())?;
            }
            let stats = pool.stats();
            prop_assert!(
                stats.row_page_refs == 0,
                "row-page refcounts did not return to zero ({})",
                stats.row_page_refs
            );
            prop_assert!(paged.total_pages() == 0 && paged.is_empty(), "rows left behind");
            prop_assert!(
                slab.k.data.iter().all(|&x| x == 0.0)
                    && slab.v.data.iter().all(|&x| x == 0.0),
                "slab leave left residue in the cache"
            );
            ok()
        },
    );
}

// ---------------------------------------------------------------------
// Chunked admission prefill (engine.rs `chunked_prefill`): random
// admit / advance interleavings through the mock chunk, differential
// against the monolithic one-shot prefill, on both RowStore backends.
// ---------------------------------------------------------------------

/// Tentpole property: feeding a prompt through fixed-size prefill chunks —
/// gather the partial row, run the chunk window at `pos = cached`, scatter
/// back only the `take` real positions — must reproduce the monolithic
/// one-shot prefill exactly, for any admit/advance interleaving across
/// concurrent rows and any chunk size:
///
/// 1. **first token** — the argmax sampled from the chunk that covers the
///    final prompt position equals the monolithic call's;
/// 2. **KV bytes** — the completed row's committed prefix is byte-equal to
///    the monolithic row's, on the copy-based slab *and* on page-table rows
///    that accumulated pool pages chunk-by-chunk;
/// 3. **window padding never commits** — a short final chunk's padding
///    positions (written by the call, as on the real device) stay out of
///    the row because the scatter is bounded at `cached + take`.
#[test]
fn chunked_prefill_matches_monolithic_under_random_interleavings() {
    prop_check(
        "chunked prefill == monolithic prefill on both row backends",
        120,
        |rng| {
            let chunk_sel = rng.below(5);
            let ops: Vec<u64> = (0..rng.usize_below(40)).map(|_| rng.next_u64()).collect();
            (chunk_sel, ops)
        },
        |case| {
            let (chunk_sel, ops) = case;
            const BATCH: usize = 3;
            const PAGE: usize = 4;
            let chunk = 2 + *chunk_sel as usize; // 2..=6 tokens per chunk
            let dims = [SIM_L, 1, SIM_H, SIM_S, SIM_HD];
            let dirty = || {
                let mut t = Tensor::<f32>::zeros(&dims);
                t.data.iter_mut().for_each(|x| *x = -7.0);
                t
            };
            let argmax = |l: &[f32]| -> i32 {
                let mut best = 0usize;
                for (i, &x) in l.iter().enumerate() {
                    if x > l[best] {
                        best = i;
                    }
                }
                best as i32
            };
            let pool_cfg = || PrefixCacheConfig {
                enabled: true,
                budget_bytes: 1 << 22,
                min_prefix: 1,
                page_tokens: PAGE,
                mid_stream: false,
            };
            // Four independent rows per request: {slab, paged} x {chunked,
            // monolithic}. The monolithic pair is the oracle.
            let mut slab_c = BatchGroup::new(SIM_L, BATCH, SIM_H, SIM_S, SIM_HD);
            let mut slab_m = BatchGroup::new(SIM_L, BATCH, SIM_H, SIM_S, SIM_HD);
            let mut pool_c = PrefixCache::new(pool_cfg());
            let mut pool_m = PrefixCache::new(pool_cfg());
            let mut paged_c = PagedGroup::new(BATCH, PAGE, SIM_S);
            let mut paged_m = PagedGroup::new(BATCH, PAGE, SIM_S);
            struct ChunkReq {
                prompt: Vec<i32>,
                cached: usize,
                row_sc: usize,
                row_pc: usize,
                row_sm: usize,
                row_pm: usize,
                first_mono: i32,
            }
            let mut live: Vec<ChunkReq> = Vec::new();
            let mut next_slot = 0usize;
            let mut qi = 0usize;
            loop {
                // Past the generated ops, drain: advance until every
                // pending prefill completes and has been compared.
                let op = if qi < ops.len() {
                    let o = ops[qi];
                    qi += 1;
                    o
                } else if !live.is_empty() {
                    1
                } else {
                    break;
                };
                match op % 2 {
                    0 if slab_c.free_rows() > 0 => {
                        // Admit: monolithic oracle prefills the whole prompt
                        // in one window; the chunked rows start empty.
                        let len = 2 + ((op >> 2) % 23) as usize;
                        let prompt: Vec<i32> = (0..len)
                            .map(|i| (((op >> 7) as usize + 3 * i) % SIM_VOCAB) as i32)
                            .collect();
                        let (mut mk, mut mv) = (dirty(), dirty());
                        let logits =
                            mock_chunk(&mut mk, &mut mv, &prompt, &[0], 1, len, false);
                        let first_mono = argmax(logits.row(&[0, len - 1]));
                        let row_sm = slab_m
                            .join_prefix(next_slot, &mk, &mv, len)
                            .map_err(|e| e.to_string())?;
                        let row_pm = paged_m
                            .join_pages(next_slot, Vec::new(), 0)
                            .map_err(|e| e.to_string())?;
                        paged_m
                            .scatter_advance(&mut pool_m, &[(row_pm, 0, len)], &mk, &mv)
                            .map_err(|e| e.to_string())?;
                        paged_m.set_len(row_pm, len).map_err(|e| e.to_string())?;
                        let z = Tensor::<f32>::zeros(&dims);
                        let row_sc = slab_c
                            .join_prefix(next_slot, &z, &z, 0)
                            .map_err(|e| e.to_string())?;
                        let row_pc = paged_c
                            .join_pages(next_slot, Vec::new(), 0)
                            .map_err(|e| e.to_string())?;
                        live.push(ChunkReq {
                            prompt, cached: 0, row_sc, row_pc, row_sm, row_pm, first_mono,
                        });
                        next_slot += 1;
                    }
                    _ if !live.is_empty() => {
                        // Advance one pending row by one chunk, mirroring
                        // the engine's rider: window at `pos = cached`,
                        // commit only the `take` real positions.
                        let i = ((op >> 2) as usize) % live.len();
                        let lv = &mut live[i];
                        let len = lv.prompt.len();
                        let cached = lv.cached;
                        let take = (len - cached).min(chunk);
                        let mut toks = vec![0i32; chunk];
                        toks[..take].copy_from_slice(&lv.prompt[cached..cached + take]);
                        let (mut sk, mut sv) = (dirty(), dirty());
                        slab_c
                            .gather_rows(&[(lv.row_sc, cached)], &mut sk, &mut sv)
                            .map_err(|e| e.to_string())?;
                        let (mut pk, mut pv) = (dirty(), dirty());
                        paged_c
                            .gather_rows(&pool_c, &[(lv.row_pc, cached)], &mut pk, &mut pv)
                            .map_err(|e| e.to_string())?;
                        let pos = [cached as i32];
                        let logits_s =
                            mock_chunk(&mut sk, &mut sv, &toks, &pos, 1, chunk, false);
                        let logits_p =
                            mock_chunk(&mut pk, &mut pv, &toks, &pos, 1, chunk, false);
                        slab_c
                            .scatter_rows(&[(lv.row_sc, cached + take)], &sk, &sv)
                            .map_err(|e| e.to_string())?;
                        paged_c
                            .scatter_advance(
                                &mut pool_c,
                                &[(lv.row_pc, cached, cached + take)],
                                &pk,
                                &pv,
                            )
                            .map_err(|e| e.to_string())?;
                        paged_c
                            .set_len(lv.row_pc, cached + take)
                            .map_err(|e| e.to_string())?;
                        lv.cached += take;
                        if lv.cached < len {
                            continue;
                        }
                        // Prefill complete: the chunk covering the final
                        // prompt position samples the first token.
                        let first_s = argmax(logits_s.row(&[0, (len - 1) - cached]));
                        let first_p = argmax(logits_p.row(&[0, (len - 1) - cached]));
                        prop_assert!(
                            first_s == lv.first_mono && first_p == lv.first_mono,
                            "first token diverged: mono {} vs slab {} / paged {}",
                            lv.first_mono, first_s, first_p
                        );
                        let lv = live.swap_remove(i);
                        let (mut gck, mut gcv) = (dirty(), dirty()); // chunked slab
                        let (mut gpk, mut gpv) = (dirty(), dirty()); // chunked paged
                        let (mut omk, mut omv) = (dirty(), dirty()); // mono slab
                        let (mut opk, mut opv) = (dirty(), dirty()); // mono paged
                        slab_c
                            .gather_rows(&[(lv.row_sc, len)], &mut gck, &mut gcv)
                            .map_err(|e| e.to_string())?;
                        slab_m
                            .gather_rows(&[(lv.row_sm, len)], &mut omk, &mut omv)
                            .map_err(|e| e.to_string())?;
                        paged_c
                            .gather_rows(&pool_c, &[(lv.row_pc, len)], &mut gpk, &mut gpv)
                            .map_err(|e| e.to_string())?;
                        paged_m
                            .gather_rows(&pool_m, &[(lv.row_pm, len)], &mut opk, &mut opv)
                            .map_err(|e| e.to_string())?;
                        for s in 0..len {
                            for l in 0..SIM_L {
                                for hh in 0..SIM_H {
                                    for dd in 0..SIM_HD {
                                        let idx = [l, 0, hh, s, dd];
                                        let want_k = omk.at(&idx);
                                        let want_v = omv.at(&idx);
                                        prop_assert!(
                                            want_k == opk.at(&idx)
                                                && want_v == opv.at(&idx),
                                            "mono backends disagree at pos {s}"
                                        );
                                        prop_assert!(
                                            gck.at(&idx) == want_k
                                                && gcv.at(&idx) == want_v,
                                            "chunked slab KV diverged at pos {s}"
                                        );
                                        prop_assert!(
                                            gpk.at(&idx) == want_k
                                                && gpv.at(&idx) == want_v,
                                            "chunked paged KV diverged at pos {s}"
                                        );
                                    }
                                }
                            }
                        }
                        slab_c.leave(lv.row_sc).map_err(|e| e.to_string())?;
                        slab_m.leave(lv.row_sm).map_err(|e| e.to_string())?;
                        paged_c
                            .leave(&mut pool_c, lv.row_pc)
                            .map_err(|e| e.to_string())?;
                        paged_m
                            .leave(&mut pool_m, lv.row_pm)
                            .map_err(|e| e.to_string())?;
                    }
                    _ => {}
                }
            }
            // Drained: chunk-accumulated pages all returned to the pool.
            prop_assert!(
                pool_c.stats().row_page_refs == 0 && paged_c.total_pages() == 0,
                "chunk-accumulated row pages leaked"
            );
            ok()
        },
    );
}

#[test]
fn cluster_ring_add_moves_about_one_nth_of_keys() {
    // Consistent-hash stability: growing the fleet from n to n+1 replicas
    // may only move keys *onto* the new replica (vnode positions of the
    // surviving replicas are identical in both rings), and the moved share
    // concentrates around 1/(n+1). Removal is the mirror image — the same
    // moved set returns home — so one direction bounds both.
    prop_check(
        "consistent-hash ring stability under replica add/remove",
        150,
        |rng| {
            let n = 2 + rng.usize_below(7); // fleet size before the add
            let keys: Vec<u64> = (0..256).map(|_| rng.next_u64()).collect();
            (n as u64, keys)
        },
        |(n, keys)| {
            let n = (*n as usize).clamp(2, 16);
            let before = build_ring(n, 64);
            let after = build_ring(n + 1, 64);
            let mut moved = 0usize;
            for &k in keys {
                let a = ring_assign(&before, k);
                let b = ring_assign(&after, k);
                if a != b {
                    prop_assert!(
                        b == n,
                        "key moved between surviving replicas: {a} -> {b} (new replica {n})"
                    );
                    moved += 1;
                }
            }
            // 64 vnodes keep the new replica's realized share concentrated
            // around the 1/(n+1) mean; 2.5x mean plus slack is a
            // conservative ceiling that still fails a broken ring (which
            // reshuffles ~half the space).
            let cap = keys.len() as f64 * 2.5 / (n + 1) as f64 + 8.0;
            prop_assert!(
                (moved as f64) < cap,
                "add moved {moved}/{} keys for n={n} (cap {cap:.1})",
                keys.len()
            );
            ok()
        },
    )
}

#[test]
fn cluster_steal_decision_is_bounded_and_deterministic() {
    // The pure steal rule: never below the home threshold, never onto a
    // replica at least as deep as home, always the shallowest target.
    prop_check(
        "work-steal decision bounds",
        400,
        |rng| {
            let nd = 1 + rng.usize_below(8);
            let depths: Vec<u64> = (0..nd).map(|_| rng.below(16)).collect();
            let home = rng.usize_below(nd) as u64;
            let threshold = 1 + rng.below(8);
            (home, depths, threshold)
        },
        |(home, depths, threshold)| {
            let home = *home as usize;
            let t = (*threshold as usize).max(1);
            let depths: Vec<usize> = depths.iter().map(|&d| d as usize).collect();
            if depths.is_empty() || home >= depths.len() {
                return ok(); // shrunk out of the generator's invariant
            }
            let (target, stolen) = dispatch_decision(home, &depths, t);
            prop_assert!(
                (target, stolen) == dispatch_decision(home, &depths, t),
                "decision must be deterministic"
            );
            prop_assert!(target < depths.len(), "target out of range");
            if depths[home] < t {
                prop_assert!(
                    target == home && !stolen,
                    "stole below the threshold (home depth {} < {t})",
                    depths[home]
                );
            }
            if stolen {
                prop_assert!(target != home, "a steal must leave home");
                prop_assert!(depths[home] >= t, "steal below threshold");
                prop_assert!(
                    depths[target] < depths[home],
                    "stole onto a no-shallower replica ({} >= {})",
                    depths[target],
                    depths[home]
                );
                prop_assert!(
                    depths.iter().all(|&d| d >= depths[target]),
                    "steal must take the shallowest replica"
                );
            } else {
                prop_assert!(target == home, "an unstolen request must stay home");
            }
            ok()
        },
    )
}

#[test]
fn cluster_id_stride_routes_cancels_home_and_one_replica_degenerates() {
    // Replica r of n mints ids r+1, r+1+n, ... (EngineConfig id striding):
    // cancel routing must recover the minting replica for every id, and the
    // 1-replica fleet must behave exactly like a bare engine — ids 1,2,3..
    // all route to replica 0 and no depth can trigger a steal.
    prop_check(
        "id-stride cancel routing and the bare-engine degenerate",
        300,
        |rng| {
            let n = 1 + rng.usize_below(8);
            let mints = 1 + rng.usize_below(64);
            (n as u64, mints as u64)
        },
        |(n, mints)| {
            let n = (*n as usize).max(1);
            for r in 0..n {
                let mut id = (r + 1) as u64;
                for _ in 0..*mints {
                    prop_assert!(
                        replica_of_id(id, n) == r,
                        "id {id} routed to {} not its minting replica {r}/{n}",
                        replica_of_id(id, n)
                    );
                    id += n as u64;
                }
            }
            for id in 1..=1 + *mints {
                prop_assert!(replica_of_id(id, 1) == 0, "bare ids must route to 0");
            }
            prop_assert!(
                dispatch_decision(0, &[*mints as usize], 1) == (0, false),
                "a 1-replica fleet can never steal"
            );
            ok()
        },
    )
}

#[test]
fn gamma_resolve_is_bounded_for_any_config_and_history() {
    // The per-class depth controller's core contract (coordinator/gamma):
    // for ANY tuning (including degenerate alphas and huge/negative
    // headroom), ANY recorded history over ANY class stream, resolve()
    // returns 0 exactly when cap == 0 and a value in [1, cap] otherwise —
    // and a disabled controller always returns the full cap.
    prop_check(
        "gamma resolve bounds",
        400,
        |rng| {
            let enabled = rng.below(4) != 0;
            let alpha = rng.below(101) as f64 / 100.0;
            let headroom = rng.below(41) as f64 - 20.0; // [-20, 20]
            let steps: Vec<(u64, u64, u64)> = (0..rng.usize_below(60))
                .map(|_| {
                    let class = rng.below(6);
                    let drafted = rng.below(10);
                    let accepted = rng.below(drafted + 1);
                    (class, drafted, accepted)
                })
                .collect();
            (enabled, alpha, headroom, steps)
        },
        |(enabled, alpha, headroom, steps)| {
            let mut g = GammaController::new(GammaConfig {
                enabled: *enabled,
                alpha: *alpha,
                headroom: *headroom,
            });
            for &(class, drafted, accepted) in steps {
                g.record(&format!("c{class}"), drafted as usize, accepted as usize);
                for class in 0..6 {
                    let name = format!("c{class}");
                    for cap in 0..9usize {
                        let r = g.resolve(&name, cap);
                        if cap == 0 {
                            prop_assert!(r == 0, "cap 0 must resolve 0, got {r}");
                        } else if !enabled {
                            prop_assert!(r == cap, "disabled must pass cap through");
                        } else {
                            prop_assert!(
                                (1..=cap).contains(&r),
                                "resolve {r} out of [1, {cap}] (a={alpha}, h={headroom})"
                            );
                        }
                        // The admission prior mirrors resolve's gating: only
                        // enabled controllers with evidence seed drafters.
                        match g.prior(&name) {
                            Some(p) => prop_assert!(
                                *enabled && p.is_finite(),
                                "prior must imply enabled+finite"
                            ),
                            None => {}
                        }
                    }
                }
            }
            ok()
        },
    )
}

#[test]
fn gamma_depth_recovers_after_any_collapse() {
    // No absorbing floor: however long acceptance collapses, a healthy
    // stream afterwards must climb the class back to (near) the cap.
    prop_check(
        "gamma collapse recovery",
        300,
        |rng| {
            let collapse = 1 + rng.usize_below(200);
            let cap = 2 + rng.usize_below(7);
            (collapse as u64, cap as u64)
        },
        |(collapse, cap)| {
            let cap = *cap as usize;
            let mut g = GammaController::new(GammaConfig::default());
            for _ in 0..20 {
                g.record("c", cap, cap);
            }
            prop_assert!(g.resolve("c", cap) == cap, "healthy class must draft deep");
            for _ in 0..*collapse {
                g.record("c", cap, 0);
            }
            let throttled = g.resolve("c", cap);
            prop_assert!(
                (1..=cap).contains(&throttled),
                "throttled depth out of bounds: {throttled}"
            );
            for _ in 0..200 {
                g.record("c", cap, cap);
            }
            prop_assert!(
                g.resolve("c", cap) == cap,
                "depth failed to recover after {collapse}-step collapse: {}",
                g.resolve("c", cap)
            );
            ok()
        },
    )
}

#[test]
fn gamma_class_map_stays_bounded_under_any_tag_stream() {
    // The class key is the client-supplied task tag: any unbounded stream
    // of novel tags must fold into the shared overflow class instead of
    // growing the map past its cap (same rule as the governor's map).
    prop_check(
        "gamma class-map bound",
        200,
        |rng| {
            let tags: Vec<u64> = (0..300 + rng.usize_below(300))
                .map(|_| rng.below(1 << 48))
                .collect();
            tags
        },
        |tags| {
            let mut g = GammaController::new(GammaConfig::default());
            for &t in tags {
                g.record(&format!("tag-{t}"), 4, 2);
            }
            let n = g.classes().count();
            prop_assert!(n <= 257, "class map grew unbounded: {n}");
            // Every tag still resolves in bounds through the overflow fold.
            prop_assert!(
                (1..=8).contains(&g.resolve("yet-another-novel-tag", 8)),
                "overflow-folded tag must still resolve in bounds"
            );
            ok()
        },
    )
}

//! Artifact-free benchmark emitter: drives the deterministic mock-chunk
//! sim (no PJRT artifacts needed) through both engine shapes and writes a
//! `BENCH_mock_sim.json` artifact — throughput-ish numbers (modeled decode
//! seconds, chunk efficiency, call counts) CI uploads on every run, so the
//! machine-readable bench trail exists even where the compiled model does
//! not. `QUASAR_BENCH_DIR` overrides the output directory (default
//! `target/bench`).

mod common;

use std::path::PathBuf;
use std::sync::Arc;

use quasar::bench::BenchReport;
use quasar::coordinator::{pack_prefill_riders, plan_step, CallLog, FnKind, GammaConfig,
                          GammaController, PlanCtx, PlanRow, PrefillPending, VariantCtx};
use quasar::trace::{FlightRecorder, TraceHandle};
use quasar::util::json;
use quasar::util::rng::Pcg;

use common::sim::{check_equivalent, run_equivalence, sim_perf, Sim, SIM_CHUNK, SIM_L,
                  SIM_VOCAB};

/// Useful positions over executed positions, the engine's chunk-efficiency
/// definition applied to the sim's call log.
fn chunk_efficiency(log: &CallLog) -> f64 {
    let useful: usize = log.records.iter().map(|r| r.useful_tokens).sum();
    let executed: usize = log.records.iter().map(|r| r.batch * r.chunk_len).sum();
    useful as f64 / executed.max(1) as f64
}

#[test]
fn bench_mock_sim_emits_json() {
    let (n_req, steps) = (4usize, 48usize);
    let t0 = std::time::Instant::now();
    // KV-bound pricing regime (sel 0): the planner shrinks buckets, so the
    // elastic log prices strictly cheaper and the saving field is non-trivial.
    let (mono, ela) = run_equivalence(n_req, 0, 0xBE9C, steps);
    check_equivalent(&mono, &ela).expect("mono/elastic sim equivalence");
    let wall_s = t0.elapsed().as_secs_f64();

    let tokens_out: u64 = ela
        .reqs
        .iter()
        .map(|r| (r.committed.len() - 1) as u64) // minus the 1-token prompt
        .sum();
    let modeled_mono_s = mono.perf.decode_time(&mono.log, None);
    let modeled_ela_s = ela.perf.decode_time(&ela.log, None);
    assert!(modeled_mono_s > 0.0 && modeled_ela_s > 0.0);

    let mut r = BenchReport::new("mock_sim");
    r.num("requests", n_req as f64)
        .num("steps", steps as f64)
        .num("verify_chunk", SIM_CHUNK as f64)
        .num("tokens", tokens_out as f64)
        .num("wall_s", wall_s)
        .num("modeled_mono_s", modeled_mono_s)
        .num("modeled_elastic_s", modeled_ela_s)
        .num(
            "elastic_saving_frac",
            1.0 - modeled_ela_s / modeled_mono_s.max(1e-12),
        )
        .num(
            "modeled_throughput_tok_s",
            tokens_out as f64 / modeled_ela_s.max(1e-12),
        )
        .num("chunk_efficiency_mono", chunk_efficiency(&mono.log))
        .num("chunk_efficiency_elastic", chunk_efficiency(&ela.log))
        .num("calls_mono", mono.log.records.len() as f64)
        .num("calls_elastic", ela.log.records.len() as f64);

    let dir = std::env::var("QUASAR_BENCH_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/bench"));
    let path = r.write_to(&dir).expect("write bench json");

    // The artifact must round-trip: CI parses it, so a malformed emit is a
    // test failure here rather than a broken upload there.
    let v = json::parse_file(&path).expect("parse bench json");
    assert_eq!(v.get("scenario").unwrap().as_str().unwrap(), "mock_sim");
    assert!(v.get("tokens").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("chunk_efficiency_elastic").unwrap().as_f64().unwrap() > 0.0);
    println!("bench_json={}", path.display());
}

/// The load-adaptive prefill-chunk satellite, priced on the sim's cost
/// model: under a deep queue a dedicated prefill chunk sheds to the
/// (smaller) exported verify program, so the modeled time of a step that
/// carries one — the stall every co-running decode row waits out — is
/// strictly smaller. That worst-case single-step stall bounds decode TPOT
/// jitter, so shedding smooths TPOT while the admission backlog drains.
#[test]
fn shed_load_caps_the_dedicated_prefill_stall() {
    let perf = sim_perf(0);
    let buckets = [1usize, 2, 4];
    let variants = [VariantCtx {
        name: "fp32",
        verify_buckets: &buckets,
        decode_buckets: &buckets,
    }];
    let ctx = PlanCtx {
        perf: &perf,
        variants: &variants,
        n_layers: SIM_L,
        full_bucket: 4,
        verify_chunk: SIM_CHUNK,
        elastic: true,
    };
    // Exported admission window, well above the verify chunk.
    let prefill_chunk = 16usize;
    // Four decode-only rows fill the bucket exactly: no spare slot to ride,
    // so the pending admission must fall back to a dedicated chunk.
    let rows: Vec<PlanRow> = (0..4).map(|_| PlanRow::new(0, 0)).collect();
    let pending = [PrefillPending { remaining: 64, variant: 0 }];

    let step = |shed: bool| {
        let mut plan = plan_step(&ctx, &rows).expect("plan");
        pack_prefill_riders(&ctx, &mut plan, &pending, prefill_chunk, shed);
        let dedicated: Vec<_> = plan
            .sub_batches
            .iter()
            .filter(|sb| sb.rows.is_empty() && !sb.riders.is_empty())
            .collect();
        assert_eq!(dedicated.len(), 1, "one pending row, one dedicated chunk");
        (plan.modeled_s, dedicated[0].fn_kind, dedicated[0].chunk,
         dedicated[0].riders[0].take)
    };

    let (calm_s, calm_kind, calm_chunk, calm_take) = step(false);
    let (shed_s, shed_kind, shed_chunk, shed_take) = step(true);
    assert_eq!(calm_kind, FnKind::Prefill);
    assert_eq!((calm_chunk, calm_take), (prefill_chunk, prefill_chunk));
    assert_eq!(shed_kind, FnKind::Verify);
    assert_eq!((shed_chunk, shed_take), (SIM_CHUNK, SIM_CHUNK));
    assert!(
        shed_s < calm_s,
        "shed step must stall decode less: shed {shed_s} vs calm {calm_s}"
    );
    println!("calm_stall_s={calm_s:.9}");
    println!("shed_stall_s={shed_s:.9}");
}

/// Per-class gamma controller differential on the mock sim: drive two real
/// verify pipelines with the same proposal pool — one drafting the full
/// static cap every step (the pre-PR path), one truncating each draft to
/// the class-resolved depth — through a healthy warm-up and then a total
/// acceptance collapse (every proposal out-of-vocab, so the verifier must
/// reject it). Claims, in order:
///
/// * a disabled controller resolves the full cap on *every* step — the
///   `--adaptive-gamma off` path is the static path, bit for bit;
/// * depth choices are lossless: both committed streams follow the same
///   greedy chain (one is a prefix of the other, and the collapse phase
///   commits the same token count in both runs);
/// * on collapse the controller strictly shrinks drafted-but-rejected
///   tokens without reducing committed throughput per verified position
///   (the modeled verification cost: each executed position is work).
#[test]
fn gamma_controller_sheds_rejected_draft_work_losslessly() {
    let (n_req, full) = (2usize, 4usize);
    let cap = SIM_CHUNK - 1; // the sim's verify chunk leaves room for 4 drafts
    let (warm, collapse) = (10usize, 50usize);
    let mut stat = Sim::new(n_req, full, sim_perf(0), true);
    let mut adp = Sim::new(n_req, full, sim_perf(0), true);
    let mut off = GammaController::new(GammaConfig::off());
    let mut ctl = GammaController::new(GammaConfig::default());
    let mut rng = Pcg::seeded(0x9A44);

    // Collapse-phase draft accounting (the controller's lever).
    let (mut stat_drafted, mut stat_rejected, mut stat_positions) = (0usize, 0usize, 0usize);
    let (mut adp_drafted, mut adp_rejected, mut adp_positions) = (0usize, 0usize, 0usize);
    let (mut stat_committed, mut adp_committed) = (0usize, 0usize);
    let mut depth_shrank = false;

    for t in 0..warm + collapse {
        // One proposal pool per row per step: healthy steps propose
        // in-vocab tokens (partial acceptance), collapsed steps propose
        // out-of-vocab junk the greedy verifier rejects at position 0.
        let pool: Vec<Vec<i32>> = (0..n_req)
            .map(|_| {
                (0..cap)
                    .map(|_| {
                        if t < warm { rng.below(SIM_VOCAB as u64) as i32 } else { 99 }
                    })
                    .collect()
            })
            .collect();
        // (a) the disabled controller IS the static path: full cap always.
        assert_eq!(off.resolve("chat", cap), cap, "off-controller must not clamp");
        let g_adp = ctl.resolve("chat", cap);
        assert!((1..=cap).contains(&g_adp));
        if g_adp < cap {
            depth_shrank = true;
        }
        let stat_drafts = pool.clone();
        let adp_drafts: Vec<Vec<i32>> = pool.iter().map(|p| p[..g_adp].to_vec()).collect();

        let before_s: Vec<usize> = stat.reqs.iter().map(|r| r.committed.len()).collect();
        let before_a: Vec<usize> = adp.reqs.iter().map(|r| r.committed.len()).collect();
        stat.step(&stat_drafts);
        adp.step(&adp_drafts);
        for i in 0..n_req {
            // commit() appends `accepted + 1` (bonus token rides along).
            let acc_s = stat.reqs[i].committed.len() - before_s[i] - 1;
            let acc_a = adp.reqs[i].committed.len() - before_a[i] - 1;
            // Both controllers observe their own run, exactly as the engine
            // records every committed step regardless of mode.
            off.record("chat", stat_drafts[i].len(), acc_s);
            ctl.record("chat", adp_drafts[i].len(), acc_a);
            if t >= warm {
                stat_drafted += stat_drafts[i].len();
                stat_rejected += stat_drafts[i].len() - acc_s;
                stat_positions += stat_drafts[i].len() + 1;
                stat_committed += acc_s + 1;
                adp_drafted += adp_drafts[i].len();
                adp_rejected += adp_drafts[i].len() - acc_a;
                adp_positions += adp_drafts[i].len() + 1;
                adp_committed += acc_a + 1;
            }
        }
    }

    assert!(depth_shrank, "collapse never moved the resolved depth below cap");
    // Lossless: both runs walk the same greedy chain — the shorter stream
    // is a prefix of the longer (they can only differ by warm-up steps
    // where the static run accepted past the adaptive depth).
    for (i, (s, a)) in stat.reqs.iter().zip(&adp.reqs).enumerate() {
        let n = s.committed.len().min(a.committed.len());
        assert_eq!(
            s.committed[..n],
            a.committed[..n],
            "req {i}: depth policy changed the greedy stream"
        );
    }
    // Collapse phase: every junk proposal is rejected, so both runs commit
    // exactly one (bonus) token per row per step — identical throughput...
    assert_eq!(stat_committed, n_req * collapse);
    assert_eq!(adp_committed, stat_committed, "controller reduced committed tokens");
    // ...while the controller drafts (and pays verification for) strictly
    // less rejected work than the static cap.
    assert_eq!(stat_rejected, stat_drafted, "collapse phase must reject everything");
    assert!(
        adp_rejected < stat_rejected,
        "controller must shed rejected draft work: adaptive {adp_rejected} vs \
         static {stat_rejected}"
    );
    // Modeled cost: committed tokens per executed verify position — the
    // adaptive run pays fewer positions for the same commits.
    assert!(adp_positions < stat_positions);
    let stat_eff = stat_committed as f64 / stat_positions as f64;
    let adp_eff = adp_committed as f64 / adp_positions as f64;
    assert!(
        adp_eff > stat_eff,
        "controller must raise committed-per-position: {adp_eff:.3} vs {stat_eff:.3}"
    );
    // The learned floor matches the controller's contract: ewma ~ 0 plus
    // headroom 2 under total rejection.
    assert_eq!(ctl.resolve("chat", cap), 2, "post-collapse resolved depth");

    // Machine-readable trail for the CI smoke, same channel as the mock
    // sim bench artifact.
    let mut r = BenchReport::new("mock_sim_gamma");
    r.num("warm_steps", warm as f64)
        .num("collapse_steps", collapse as f64)
        .num("static_rejected", stat_rejected as f64)
        .num("adaptive_rejected", adp_rejected as f64)
        .num("static_positions", stat_positions as f64)
        .num("adaptive_positions", adp_positions as f64)
        .num("static_committed_per_position", stat_eff)
        .num("adaptive_committed_per_position", adp_eff);
    let dir = std::env::var("QUASAR_BENCH_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/bench"));
    let path = r.write_to(&dir).expect("write gamma bench json");
    println!("bench_json={}", path.display());
}

/// Flight-recorder differential: an armed trace handle must be a pure tap.
/// Two elastic sims consume identical seeded drafts — one with the recorder
/// armed, one with the default disabled handle — and must produce
/// bit-identical committed streams, identical call logs, and identical
/// modeled decode time (the recorder books zero modeled cost). The armed
/// recorder must actually have captured events; the disabled handle drains
/// nothing because it holds no ring at all.
#[test]
fn trace_recording_never_changes_the_sim() {
    let (n_req, steps, full) = (4usize, 32usize, 4usize);
    let recorder = Arc::new(FlightRecorder::new(true));
    let mut armed = Sim::new(n_req, full, sim_perf(0), true);
    armed.trace = TraceHandle::new(Arc::clone(&recorder), 0);
    let mut silent = Sim::new(n_req, full, sim_perf(0), true);
    assert!(!silent.trace.enabled(), "sim default must be trace-off");

    let mut rng = Pcg::seeded(0x7ACE);
    for _ in 0..steps {
        let drafts: Vec<Vec<i32>> = (0..n_req)
            .map(|_| {
                let len = rng.usize_below(SIM_CHUNK);
                (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
            })
            .collect();
        armed.step(&drafts);
        silent.step(&drafts);
    }

    for (i, (a, s)) in armed.reqs.iter().zip(&silent.reqs).enumerate() {
        assert_eq!(
            a.committed, s.committed,
            "req {i}: tracing changed the committed stream"
        );
        assert_eq!(a.cached, s.cached, "req {i}: tracing changed the cache extent");
    }
    assert_eq!(
        armed.log.records.len(),
        silent.log.records.len(),
        "tracing changed the call pattern"
    );
    let armed_s = armed.perf.decode_time(&armed.log, None);
    let silent_s = silent.perf.decode_time(&silent.log, None);
    assert_eq!(
        armed_s.to_bits(),
        silent_s.to_bits(),
        "tracing must add zero modeled cost"
    );

    let (events, dropped) = recorder.drain();
    assert!(
        !events.is_empty(),
        "armed recorder captured no events from {steps} elastic steps"
    );
    // 32 steps of Plan + ChunkExec + 4 Commits stay far under one ring's
    // capacity, so nothing may have been overwritten.
    assert_eq!(dropped, 0, "ring wrapped under a trivial load");
    // Timestamps come from one monotonic clock, so the drained (merged)
    // stream is ordered.
    for w in events.windows(2) {
        assert!(w[0].ts_us <= w[1].ts_us, "drained events out of ts order");
    }
}

//! Artifact-free benchmark emitter: drives the deterministic mock-chunk
//! sim (no PJRT artifacts needed) through both engine shapes and writes a
//! `BENCH_mock_sim.json` artifact — throughput-ish numbers (modeled decode
//! seconds, chunk efficiency, call counts) CI uploads on every run, so the
//! machine-readable bench trail exists even where the compiled model does
//! not. `QUASAR_BENCH_DIR` overrides the output directory (default
//! `target/bench`).

mod common;

use std::path::PathBuf;

use quasar::bench::BenchReport;
use quasar::coordinator::CallLog;
use quasar::util::json;

use common::sim::{check_equivalent, run_equivalence, SIM_CHUNK};

/// Useful positions over executed positions, the engine's chunk-efficiency
/// definition applied to the sim's call log.
fn chunk_efficiency(log: &CallLog) -> f64 {
    let useful: usize = log.records.iter().map(|r| r.useful_tokens).sum();
    let executed: usize = log.records.iter().map(|r| r.batch * r.chunk_len).sum();
    useful as f64 / executed.max(1) as f64
}

#[test]
fn bench_mock_sim_emits_json() {
    let (n_req, steps) = (4usize, 48usize);
    let t0 = std::time::Instant::now();
    // KV-bound pricing regime (sel 0): the planner shrinks buckets, so the
    // elastic log prices strictly cheaper and the saving field is non-trivial.
    let (mono, ela) = run_equivalence(n_req, 0, 0xBE9C, steps);
    check_equivalent(&mono, &ela).expect("mono/elastic sim equivalence");
    let wall_s = t0.elapsed().as_secs_f64();

    let tokens_out: u64 = ela
        .reqs
        .iter()
        .map(|r| (r.committed.len() - 1) as u64) // minus the 1-token prompt
        .sum();
    let modeled_mono_s = mono.perf.decode_time(&mono.log, None);
    let modeled_ela_s = ela.perf.decode_time(&ela.log, None);
    assert!(modeled_mono_s > 0.0 && modeled_ela_s > 0.0);

    let mut r = BenchReport::new("mock_sim");
    r.num("requests", n_req as f64)
        .num("steps", steps as f64)
        .num("verify_chunk", SIM_CHUNK as f64)
        .num("tokens", tokens_out as f64)
        .num("wall_s", wall_s)
        .num("modeled_mono_s", modeled_mono_s)
        .num("modeled_elastic_s", modeled_ela_s)
        .num(
            "elastic_saving_frac",
            1.0 - modeled_ela_s / modeled_mono_s.max(1e-12),
        )
        .num(
            "modeled_throughput_tok_s",
            tokens_out as f64 / modeled_ela_s.max(1e-12),
        )
        .num("chunk_efficiency_mono", chunk_efficiency(&mono.log))
        .num("chunk_efficiency_elastic", chunk_efficiency(&ela.log))
        .num("calls_mono", mono.log.records.len() as f64)
        .num("calls_elastic", ela.log.records.len() as f64);

    let dir = std::env::var("QUASAR_BENCH_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/bench"));
    let path = r.write_to(&dir).expect("write bench json");

    // The artifact must round-trip: CI parses it, so a malformed emit is a
    // test failure here rather than a broken upload there.
    let v = json::parse_file(&path).expect("parse bench json");
    assert_eq!(v.get("scenario").unwrap().as_str().unwrap(), "mock_sim");
    assert!(v.get("tokens").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("chunk_efficiency_elastic").unwrap().as_f64().unwrap() > 0.0);
    println!("bench_json={}", path.display());
}

//! Drafter-trait contract: every drafting strategy must tolerate the
//! engine's edge inputs without panicking and keep its outputs inside the
//! caps the engine hands it.
//!
//! The contract (one clause per regression this suite pins):
//!
//! * `draft(0, _)` is an empty draft, never a panic — the adaptive depth
//!   clamp used to hit `clamp(1, 0)` when a row had no KV room;
//! * `begin(&[])` (empty prompt) succeeds, and the following draft is
//!   empty — there is no token to continue from;
//! * a draft never exceeds the gamma cap, for any acceptance history;
//! * `observe_outcome` accepts any `(drafted, accepted)` pair with
//!   `accepted <= drafted` — including `(0, 0)`, the no-draft step —
//!   without panicking or pushing the next depth out of bounds;
//! * `seed_depth_prior` with extreme priors keeps depth within `[1, cap]`.
//!
//! Vanilla and ngram run everywhere; the pruned drafter costs real forward
//! passes, so its leg is artifact-gated like the integration scenarios.

mod common;

use quasar::spec::{Drafter, NgramConfig, NgramDrafter, PrunedDrafter, VanillaDrafter};

/// Drive one drafter through the full contract. `ctx` must make the
/// drafter actually propose tokens (a repetitive context for ngram); the
/// vanilla drafter proposes nothing and passes vacuously.
fn check_contract(d: &mut dyn Drafter, ctx: &[i32]) {
    // Empty prompt: begin succeeds, drafts are empty at any cap.
    d.begin(&[]).unwrap();
    assert!(d.draft(0, 0.0).unwrap().is_empty(), "{}: gamma 0 on empty", d.name());
    assert!(d.draft(8, 0.0).unwrap().is_empty(), "{}: empty context", d.name());

    // Real context: gamma 0 still empty, and every draft respects the cap.
    d.begin(ctx).unwrap();
    assert!(d.draft(0, 0.0).unwrap().is_empty(), "{}: gamma 0", d.name());
    for cap in [1usize, 2, 3, 5, 8] {
        let n = d.draft(cap, 0.0).unwrap().tokens.len();
        assert!(n <= cap, "{}: drafted {n} > cap {cap}", d.name());
    }

    // Outcome bounds: any accepted <= drafted pair, including the no-draft
    // step, and pathological streaks in both directions.
    d.observe_outcome(0, 0);
    for _ in 0..50 {
        d.observe_outcome(8, 0); // total rejection
    }
    assert!(d.draft(8, 0.0).unwrap().tokens.len() <= 8, "{}: post-collapse", d.name());
    assert!(d.draft(0, 0.0).unwrap().is_empty(), "{}: gamma 0 post-collapse", d.name());
    for _ in 0..50 {
        d.observe_outcome(8, 8); // perfect acceptance
    }
    assert!(d.draft(3, 0.0).unwrap().tokens.len() <= 3, "{}: post-streak cap", d.name());

    // Extreme cross-request priors stay clamped to the per-step cap.
    d.begin(ctx).unwrap();
    d.seed_depth_prior(1e9);
    assert!(d.draft(4, 0.0).unwrap().tokens.len() <= 4, "{}: huge prior", d.name());
    d.begin(ctx).unwrap();
    d.seed_depth_prior(0.0);
    assert!(d.draft(0, 0.0).unwrap().is_empty(), "{}: zero prior, zero cap", d.name());

    // Commits keep the contract intact.
    d.observe_commit(&[1, 2, 1, 2]).unwrap();
    assert!(d.draft(2, 0.0).unwrap().tokens.len() <= 2, "{}: post-commit cap", d.name());
}

/// A context repetitive enough that the ngram index always finds a
/// continuation — the cap assertions then bite rather than pass vacuously.
fn repetitive_ctx() -> Vec<i32> {
    std::iter::repeat([5, 6, 7]).take(12).flatten().collect()
}

#[test]
fn vanilla_meets_the_drafter_contract() {
    check_contract(&mut VanillaDrafter, &repetitive_ctx());
}

#[test]
fn ngram_meets_the_drafter_contract_adaptive_and_static() {
    for adaptive in [true, false] {
        for gamma in [0usize, 1, 5, 8] {
            let mut d = NgramDrafter::new(NgramConfig { gamma, adaptive, ..Default::default() });
            check_contract(&mut d, &repetitive_ctx());
        }
    }
}

#[test]
fn pruned_meets_the_drafter_contract() {
    let Some(root) = common::artifacts_root() else { return };
    let (_manifest, mr) = common::load_model(&root);
    for variant in ["pruned90", "pruned50"] {
        let Ok(mut d) = PrunedDrafter::new(std::rc::Rc::clone(&mr), variant, 7) else {
            eprintln!("[skip] no {variant} artifact in this set");
            continue;
        };
        // The pruned drafter runs real forward passes: keep the context a
        // golden prompt so prefill shapes match the compiled artifact.
        let prompts = common::golden_prompts(&mr);
        check_contract(&mut d, &prompts[0]);
    }
}

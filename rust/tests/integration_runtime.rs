//! Integration tests over real AOT artifacts: load the manifest, compile
//! HLO-text programs on the PJRT CPU client, and check the engine's numerics
//! against python-computed goldens.
//!
//! Artifact root resolution: `QUASAR_ARTIFACTS` env var, else `artifacts/`.
//! Tests skip (pass with a notice) when artifacts are absent so `cargo test`
//! works before `make artifacts`.

use std::path::PathBuf;
use std::rc::Rc;

use quasar::coordinator::{
    DrafterKind, Engine, EngineConfig, FnKind, GenParams, GovernorConfig, PrefixCacheConfig,
};
use quasar::metrics::names;
use quasar::perfmodel::PerfModel;
use quasar::runtime::{Manifest, ModelRuntime, XlaRuntime};
use quasar::spec::NgramConfig;
use quasar::util::json;

fn artifacts_root() -> Option<PathBuf> {
    let root = std::env::var("QUASAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("[skip] no artifacts at {root:?} — run `make artifacts`");
        None
    }
}

fn first_model(manifest: &Manifest) -> String {
    manifest.models.keys().next().expect("at least one model").clone()
}

fn load_model(root: &PathBuf) -> (Manifest, Rc<ModelRuntime>) {
    let rt = Rc::new(XlaRuntime::cpu().expect("pjrt cpu client"));
    let manifest = Manifest::load(root).expect("manifest");
    let name = first_model(&manifest);
    let mr = Rc::new(ModelRuntime::load(rt, &manifest, &name).expect("model"));
    (manifest, mr)
}

/// One PJRT client per process: xla_extension SIGSEGVs when a second CPU
/// client is created after the first is dropped, so all scenarios share one
/// `ModelRuntime` under a single #[test].
#[test]
fn integration_scenarios() {
    // big stack: the HLO text parser recurses deeply (util::bigstack docs)
    quasar::util::bigstack::run(integration_scenarios_inner)
}

fn integration_scenarios_inner() {
    let Some(root) = artifacts_root() else { return };
    let (manifest, mr) = load_model(&root);
    eprintln!("== prefill_logits_match_python_goldens");
    prefill_logits_match_python_goldens(&mr);
    eprintln!("== speculative_greedy_equals_vanilla_greedy");
    speculative_greedy_equals_vanilla_greedy(&mr);
    eprintln!("== batched_serving_matches_single_request");
    batched_serving_matches_single_request(&mr);
    eprintln!("== elastic_planner_matches_monolithic_and_prices_lower");
    elastic_planner_matches_monolithic_and_prices_lower(&manifest, &mr);
    eprintln!("== governed_precision_matches_fp32_and_prices_lower");
    governed_precision_matches_fp32_and_prices_lower(&manifest, &mr);
    eprintln!("== prefix_cache_reuse_is_bit_identical_and_prices_admission_lower");
    prefix_cache_reuse_is_bit_identical_and_prices_admission_lower(&manifest, &mr);
    eprintln!("== prompt_truncation_is_flagged_not_silent");
    prompt_truncation_is_flagged_not_silent(&mr);
    eprintln!("== pruned_drafter_runs_and_verifier_stays_lossless");
    pruned_drafter_runs_and_verifier_stays_lossless(&mr);
}

fn prefill_logits_match_python_goldens(mr: &Rc<ModelRuntime>) {
    // The asserted L2<->L3 numerics contract: the logits rust computes from
    // the exported HLO must match what python/jax computed from the same
    // parameters, for both verifier variants. (Greedy *tokens* can
    // legitimately flip on near-ties because jax's XLA and the crate's XLA
    // 0.5.1 fuse differently — see goldens.json generation in aot.py.)
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let cfg = mr.cfg().clone();

    for variant in ["fp32", "w8a8"] {
        for g in goldens.as_arr().unwrap() {
            let prompt = g.get("prompt_ids").unwrap().as_i32_vec().unwrap();
            let expect: Vec<f64> = g
                .get(&format!("prefill_logits_{variant}"))
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            let task = g.get("task").unwrap().as_str().unwrap();

            let mut toks = vec![0i32; cfg.prefill_len];
            toks[..prompt.len()].copy_from_slice(&prompt);
            let (k, v) = mr.empty_cache(cfg.n_layers, 1);
            let out = mr
                .run_chunk(variant, "prefill", 1, &toks, &k, &v, &[0])
                .expect("prefill");
            let row = out.logits.row(&[0, prompt.len() - 1]);
            assert_eq!(row.len(), expect.len());
            let scale = expect.iter().fold(1f64, |a, b| a.max(b.abs()));
            for (i, (&r, &e)) in row.iter().zip(&expect).enumerate() {
                let err = (r as f64 - e).abs() / scale;
                assert!(
                    err < 2e-3,
                    "{variant}/{task}: logit {i} diverges: rust {r} vs python {e} (rel {err:.2e})"
                );
            }
        }
    }
}

fn speculative_greedy_equals_vanilla_greedy(mr: &Rc<ModelRuntime>) {
    // Lossless property at T=0: ngram-speculated output must be identical
    // to plain autoregressive output, for both verifier variants.
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompt = goldens.idx(0).unwrap().get("prompt_ids").unwrap().as_i32_vec().unwrap();

    for variant in ["fp32", "w8a8"] {
        let gen = |drafter: DrafterKind| {
            let cfg = EngineConfig {
                verifier: variant.into(),
                drafter,
                batch: 1,
                gamma: 4,
                seed: 3,
                policy: Default::default(),
                elastic: true,
                governor: Default::default(),
                prefix: Default::default(),
            };
            let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
            engine.submit(
                prompt.clone(),
                GenParams { max_new: 32, stop_at_eos: false, ..GenParams::default() },
                "t",
            );
            engine.run_to_completion().unwrap().remove(0)
        };
        let vanilla = gen(DrafterKind::Vanilla);
        let spec = gen(DrafterKind::Ngram(NgramConfig {
            gamma: 4,
            adaptive: false,
            ..Default::default()
        }));
        assert_eq!(vanilla.tokens, spec.tokens, "{variant}: speculation changed greedy output");
        assert!(spec.stats.mean_acceptance_len() >= 1.0);
    }
}

fn batched_serving_matches_single_request(mr: &Rc<ModelRuntime>) {
    // b=4 continuous batching must produce the same greedy tokens as b=1.
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompts: Vec<Vec<i32>> = goldens
        .as_arr()
        .unwrap()
        .iter()
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect();

    let run = |batch: usize, prompts: &[Vec<i32>]| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig { gamma: 3, adaptive: false, ..Default::default() }),
            batch,
            gamma: 3,
            seed: 1,
            policy: Default::default(),
            elastic: true,
            governor: Default::default(),
            prefix: Default::default(),
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        let mut ids = Vec::new();
        for p in prompts {
            ids.push(engine.submit(
                p.clone(),
                GenParams { max_new: 24, stop_at_eos: false, ..GenParams::default() },
                "t",
            ));
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };

    // duplicate prompts so the b=4 group is fully loaded
    let mut many = prompts.clone();
    many.extend(prompts.clone());
    let single: Vec<_> = run(1, &many);
    let batched: Vec<_> = run(4, &many);
    assert_eq!(single, batched, "batched vs single greedy outputs diverge");
}

fn elastic_planner_matches_monolithic_and_prices_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    // A batch-4 group served below capacity with staggered budgets: the
    // elastic planner must execute smaller buckets (occupancy < 4, and a
    // drain tail at occupancy 1), commit greedy tokens bit-identical to the
    // monolithic configured-bucket engine, and price the run lower on the
    // simulated device.
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompts: Vec<Vec<i32>> = goldens
        .as_arr()
        .unwrap()
        .iter()
        .take(3)
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect();

    let run = |elastic: bool| {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig {
                gamma: 3,
                adaptive: false,
                ..Default::default()
            }),
            batch: 4,
            gamma: 3,
            seed: 2,
            policy: Default::default(),
            elastic,
            governor: Default::default(),
            prefix: Default::default(),
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        for (i, p) in prompts.iter().enumerate() {
            engine.submit(
                p.clone(),
                GenParams {
                    max_new: 8 + 8 * i, // staggered finishes -> draining tail
                    stop_at_eos: false,
                    ..GenParams::default()
                },
                "t",
            );
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let tokens: Vec<Vec<i32>> = done.into_iter().map(|c| c.tokens).collect();
        (tokens, engine.call_log.clone())
    };

    let (mono_tokens, mono_log) = run(false);
    let (ela_tokens, ela_log) = run(true);
    assert_eq!(mono_tokens, ela_tokens, "elastic planning changed greedy output");

    let full = 4usize;
    assert!(
        mono_log.records.iter().all(|r| r.fn_kind == FnKind::Prefill || r.batch == full),
        "monolithic engine must stay at the configured bucket"
    );
    assert!(
        ela_log
            .records
            .iter()
            .any(|r| r.fn_kind != FnKind::Prefill && r.batch < full),
        "elastic engine never used a smaller bucket"
    );

    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());
    let (t_mono, t_ela) = (perf.run_time(&mono_log, None), perf.run_time(&ela_log, None));
    assert!(
        t_ela < t_mono,
        "elastic modeled time {t_ela} not below monolithic {t_mono}"
    );
    eprintln!(
        "   modeled run: monolithic {t_mono:.6}s -> elastic {t_ela:.6}s \
         ({:.1}% saved), chunk efficiency {:.2} -> {:.2}",
        100.0 * (1.0 - t_ela / t_mono),
        mono_log.chunk_efficiency(),
        ela_log.chunk_efficiency(),
    );
}

/// The deterministic-seed governor smoke scenario (also driven by CI):
///
/// 1. **Healthy + sampled audits** — a governed w8a8 engine must commit
///    token streams bit-identical to the fp32-pinned engine, never demote,
///    and price strictly lower on the simulated device (the audit stream is
///    part of its decode time).
/// 2. **Audit machinery at rate 1.0** — shadow calls are recorded, the
///    measured top-1 agreement is perfect on the healthy verifier, and the
///    audits do not perturb the committed stream (audits cost traffic, not
///    tokens).
/// 3. **Adversarially-degraded verifier** — with the request class force-fed
///    failing audits (as a degraded variant would generate), the class
///    demotes and end-to-end output equals pure fp32, with every non-audit
///    decode/verify/prefill call on the fp32 artifacts.
fn governed_precision_matches_fp32_and_prices_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompts: Vec<Vec<i32>> = goldens
        .as_arr()
        .unwrap()
        .iter()
        .take(3)
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect();

    let mk = |verifier: &str, governor: GovernorConfig| EngineConfig {
        verifier: verifier.into(),
        drafter: DrafterKind::Ngram(NgramConfig {
            gamma: 3,
            adaptive: false,
            ..Default::default()
        }),
        batch: 4,
        gamma: 3,
        seed: 11,
        policy: Default::default(),
        elastic: true,
        governor,
        prefix: Default::default(),
    };
    let run = |mut engine: Engine| {
        for (i, p) in prompts.iter().enumerate() {
            engine.submit(
                p.clone(),
                GenParams {
                    max_new: 12 + 6 * i,
                    stop_at_eos: false,
                    ..GenParams::default()
                },
                "t",
            );
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let tokens: Vec<Vec<i32>> = done.into_iter().map(|c| c.tokens).collect();
        (tokens, engine)
    };
    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());

    // Baseline: fp32-pinned engine.
    let (fp32_tokens, fp32_engine) =
        run(Engine::new(Rc::clone(&mr), mk("fp32", GovernorConfig::default())).unwrap());

    // 1. Audit machinery at rate 1.0: every eligible sub-batch shadowed.
    // This run also *measures* whether this artifact set's w8a8 verifier is
    // healthy (perfect top-1 agreement) — the repo's goldens caveat allows
    // greedy near-tie flips, and the bit-identity guarantee is conditional
    // on health by design (paper §4.5), so the healthy-path assertions
    // below only apply when the measurement says they must hold.
    let audit_cfg = GovernorConfig { enabled: true, audit_rate: 1.0, ..Default::default() };
    let (audited_tokens, audited_engine) =
        run(Engine::new(Rc::clone(&mr), mk("w8a8", audit_cfg)).unwrap());
    let audits = audited_engine.call_log.calls(FnKind::Audit);
    assert!(audits > 0, "audit_rate 1.0 recorded no shadow calls");
    assert!(
        perf.audit_time(&audited_engine.call_log) > 0.0,
        "audit overhead must be priced"
    );
    let agreement = audited_engine
        .metrics
        .hist(quasar::metrics::names::GOVERNOR_AGREEMENT)
        .expect("agreement histogram");
    let healthy = audited_engine.governor().demotions == 0 && agreement.mean() > 0.9999;

    if healthy {
        assert_eq!(
            audited_tokens, fp32_tokens,
            "audits perturbed the committed stream"
        );
        // 2. Healthy governed w8a8 with a light sampled audit stream: the
        // audit overhead must stay well inside the W8A8 weight-traffic
        // saving, and output must stay bit-identical to the fp32 pin.
        let gov_cfg = GovernorConfig {
            enabled: true,
            audit_rate: 0.0625,
            ..Default::default()
        };
        let (gov_tokens, gov_engine) =
            run(Engine::new(Rc::clone(&mr), mk("w8a8", gov_cfg)).unwrap());
        assert_eq!(
            gov_tokens, fp32_tokens,
            "healthy governed w8a8 diverged from the fp32-pinned engine"
        );
        assert_eq!(gov_engine.governor().demotions, 0, "healthy verifier demoted");
        let (t_gov, t_fp32) = (
            perf.decode_time(&gov_engine.call_log, None),
            perf.decode_time(&fp32_engine.call_log, None),
        );
        assert!(
            t_gov < t_fp32,
            "governed w8a8 decode time {t_gov} (audits included) not below fp32 {t_fp32}"
        );
        assert!(
            gov_engine
                .call_log
                .records
                .iter()
                .any(|r| r.fn_kind == FnKind::Verify && r.variant == "w8a8"),
            "governed engine never executed the quantized verifier"
        );
        eprintln!(
            "   healthy: decode {t_fp32:.6}s (fp32) -> {t_gov:.6}s (governed w8a8), \
             {audits} audits at rate 1.0, agreement {:.4}",
            agreement.mean()
        );
    } else {
        // Quantization flips top-1 somewhere on this artifact set, so no
        // cross-variant bit-identity is owed (the guarantee is conditional
        // on health, §4.5). If agreement sank below the floor for long
        // enough, demotion must have fired; a mild drift above the floor
        // legitimately demotes nothing. The deterministic demotion path is
        // asserted unconditionally in part 3 below.
        if agreement.mean() < audited_engine.governor().cfg().floor {
            assert!(
                audited_engine.governor().demotions >= 1,
                "mean agreement {:.4} sat below the floor but nothing demoted",
                agreement.mean()
            );
        }
        eprintln!(
            "   [notice] w8a8 flips top-1 on these artifacts (agreement {:.4}, \
             demotions {}); healthy-path bit-identity assertions skipped",
            agreement.mean(),
            audited_engine.governor().demotions
        );
    }

    // 3. Adversarially-degraded w8a8: force the class's audit stream below
    // the floor (what a broken quantized variant would produce), then run.
    // Every commit-path call must be fp32 and output must equal pure fp32.
    let degraded_cfg = GovernorConfig {
        enabled: true,
        audit_rate: 1.0,
        probe_after_steps: 10_000, // keep probes out of this short run
        ..Default::default()
    };
    let mut engine = Engine::new(Rc::clone(&mr), mk("w8a8", degraded_cfg)).unwrap();
    let min_audits = engine.governor().cfg().min_audits;
    for _ in 0..min_audits {
        engine.governor_mut().record_audit("t", 0.0, -1.0);
    }
    assert_eq!(engine.governor().demotions, 1, "forced bad audits must demote");
    let (demoted_tokens, demoted_engine) = run(engine);
    assert_eq!(
        demoted_tokens, fp32_tokens,
        "demoted class output must equal the fp32-pinned engine"
    );
    assert!(
        demoted_engine
            .call_log
            .records
            .iter()
            .filter(|r| r.fn_kind != FnKind::Audit)
            .all(|r| r.variant == "fp32"),
        "a demoted class must never execute the quantized verifier"
    );
}

/// The prefix-cache acceptance gate: over a shared-prefix workload (every
/// goldens prompt submitted twice, so each duplicate's admission can reuse
/// the first's committed prefix), the warm engine must (1) commit token
/// streams bit-identical to the cold (cache-off) engine, (2) actually hit
/// the cache, and (3) price modeled admission strictly lower, because each
/// hit's prefill call carries only the executed suffix tokens.
fn prefix_cache_reuse_is_bit_identical_and_prices_admission_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompts: Vec<Vec<i32>> = goldens
        .as_arr()
        .unwrap()
        .iter()
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect();
    // Duplicate the set: the second copy's admissions share full prefixes.
    let mut many = prompts.clone();
    many.extend(prompts.clone());

    let run = |prefix: PrefixCacheConfig| {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig {
                gamma: 3,
                adaptive: false,
                ..Default::default()
            }),
            batch: 4,
            gamma: 3,
            seed: 17,
            policy: Default::default(),
            elastic: true,
            governor: Default::default(),
            prefix,
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        for p in &many {
            engine.submit(
                p.clone(),
                GenParams { max_new: 16, stop_at_eos: false, ..GenParams::default() },
                "t",
            );
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let tokens: Vec<Vec<i32>> = done.into_iter().map(|c| c.tokens).collect();
        (tokens, engine)
    };

    let (cold_tokens, cold_engine) = run(PrefixCacheConfig::off());
    let warm_cfg = PrefixCacheConfig { min_prefix: 2, ..Default::default() };
    let (warm_tokens, warm_engine) = run(warm_cfg);

    assert_eq!(
        cold_tokens, warm_tokens,
        "prefix reuse changed the committed stream"
    );
    assert_eq!(cold_engine.prefix_cache().stats().hits, 0);
    let ps = warm_engine.prefix_cache().stats();
    assert!(ps.hits > 0, "duplicated prompts produced no prefix hits");
    assert!(ps.hit_tokens > 0, "hits served no tokens");
    assert!(ps.segments > 0 && ps.resident_bytes > 0);
    assert_eq!(ps.leases, 0, "admission leaked a prefix lease");
    // The gauge pipeline the stats endpoint reads must agree with the cache.
    assert_eq!(
        warm_engine.metrics.gauge(names::PREFIX_HITS) as u64,
        ps.hits,
        "published hit gauge diverged from the cache's own counter"
    );
    let (hits, hit_tokens) = (ps.hits, ps.hit_tokens);

    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());
    let (t_cold, t_warm) = (
        perf.prefill_time(&cold_engine.call_log),
        perf.prefill_time(&warm_engine.call_log),
    );
    assert!(
        t_warm < t_cold,
        "warm modeled admission {t_warm} not below cold {t_cold}"
    );
    // Decode-phase pricing is untouched by admission reuse.
    let (d_cold, d_warm) = (
        perf.decode_time(&cold_engine.call_log, None),
        perf.decode_time(&warm_engine.call_log, None),
    );
    assert!((d_cold - d_warm).abs() < 1e-12, "decode pricing drifted");
    eprintln!(
        "   modeled admission: cold {t_cold:.6}s -> warm {t_warm:.6}s \
         ({:.1}% saved), {hits} hits, {hit_tokens} tokens from cache",
        100.0 * (1.0 - t_warm / t_cold)
    );
}

/// An over-long prompt must be visibly truncated: flagged on the
/// completion's stats, counted in the metrics registry, and still served.
fn prompt_truncation_is_flagged_not_silent(mr: &Rc<ModelRuntime>) {
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompt = goldens.idx(0).unwrap().get("prompt_ids").unwrap().as_i32_vec().unwrap();
    let p = mr.cfg().prefill_len;

    let mut engine = Engine::new(Rc::clone(&mr), EngineConfig::ngram(1, 3)).unwrap();
    // Tile the golden prompt past the prefill window.
    let long: Vec<i32> = prompt.iter().cycle().take(p + 7).copied().collect();
    engine.submit(
        long,
        GenParams { max_new: 4, stop_at_eos: false, ..GenParams::default() },
        "t",
    );
    engine.submit(
        prompt,
        GenParams { max_new: 4, stop_at_eos: false, ..GenParams::default() },
        "t",
    );
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done[0].stats.prompt_truncated, 1, "truncation not flagged");
    assert_eq!(done[0].prompt_len, p, "prompt not cut to the prefill window");
    assert!(!done[0].tokens.is_empty(), "truncated request still serves");
    assert_eq!(done[1].stats.prompt_truncated, 0, "short prompt falsely flagged");
    assert_eq!(engine.metrics.counter(names::PROMPT_TRUNCATED), 1);
}

fn pruned_drafter_runs_and_verifier_stays_lossless(mr: &Rc<ModelRuntime>) {
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompt = goldens.idx(0).unwrap().get("prompt_ids").unwrap().as_i32_vec().unwrap();

    let gen = |drafter: DrafterKind| {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter,
            batch: 1,
            gamma: 3,
            seed: 5,
            policy: Default::default(),
            elastic: true,
            governor: Default::default(),
            prefix: Default::default(),
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        engine.submit(
            prompt.clone(),
            GenParams { max_new: 16, stop_at_eos: false, ..GenParams::default() },
            "t",
        );
        engine.run_to_completion().unwrap().remove(0)
    };
    let vanilla = gen(DrafterKind::Vanilla);
    let pruned = gen(DrafterKind::Pruned("pruned75".into()));
    assert_eq!(
        vanilla.tokens, pruned.tokens,
        "pruned drafting must not change greedy output (verifier decides)"
    );
}

//! Integration tests over real AOT artifacts: load the manifest, compile
//! HLO-text programs on the PJRT CPU client, and check the engine's numerics
//! against python-computed goldens. Shared setup (artifact gating, model
//! loading, the `TestRig` engine builder) lives in `tests/common`.
//!
//! Artifact root resolution: `QUASAR_ARTIFACTS` env var, else `artifacts/`.
//! Tests skip (pass with a notice) when artifacts are absent so `cargo test`
//! works before `make artifacts`.

mod common;

use std::rc::Rc;

use common::{artifacts_root, golden_prompts, load_model, TestRig};
use quasar::coordinator::{
    DrafterKind, Engine, FnKind, GenParams, GovernorConfig, PrefixCacheConfig,
};
use quasar::metrics::names;
use quasar::perfmodel::PerfModel;
use quasar::runtime::{Manifest, ModelRuntime};

/// One PJRT client per process: xla_extension SIGSEGVs when a second CPU
/// client is created after the first is dropped, so all scenarios share one
/// `ModelRuntime` under a single #[test].
#[test]
fn integration_scenarios() {
    // big stack: the HLO text parser recurses deeply (util::bigstack docs)
    quasar::util::bigstack::run(integration_scenarios_inner)
}

fn integration_scenarios_inner() {
    let Some(root) = artifacts_root() else { return };
    let (manifest, mr) = load_model(&root);
    eprintln!("== prefill_logits_match_python_goldens");
    prefill_logits_match_python_goldens(&mr);
    eprintln!("== speculative_greedy_equals_vanilla_greedy");
    speculative_greedy_equals_vanilla_greedy(&mr);
    eprintln!("== batched_serving_matches_single_request");
    batched_serving_matches_single_request(&mr);
    eprintln!("== elastic_planner_matches_monolithic_and_prices_lower");
    elastic_planner_matches_monolithic_and_prices_lower(&manifest, &mr);
    eprintln!("== governed_precision_matches_fp32_and_prices_lower");
    governed_precision_matches_fp32_and_prices_lower(&manifest, &mr);
    eprintln!("== prefix_cache_reuse_is_bit_identical_and_prices_admission_lower");
    prefix_cache_reuse_is_bit_identical_and_prices_admission_lower(&manifest, &mr);
    eprintln!("== paged_store_pins_pages_shares_them_and_serves_mid_stream");
    paged_store_pins_pages_shares_them_and_serves_mid_stream(&mr);
    eprintln!("== paged_rows_match_copy_rows_and_cut_residency");
    paged_rows_match_copy_rows_and_cut_residency(&mr);
    eprintln!("== chunked_prefill_matches_monolithic_and_avoids_stalls");
    chunked_prefill_matches_monolithic_and_avoids_stalls(&mr);
    eprintln!("== warm_admission_gates_on_suffix_not_prompt");
    warm_admission_gates_on_suffix_not_prompt(&mr);
    eprintln!("== prompt_truncation_is_flagged_not_silent");
    prompt_truncation_is_flagged_not_silent(&mr);
    eprintln!("== pruned_drafter_runs_and_verifier_stays_lossless");
    pruned_drafter_runs_and_verifier_stays_lossless(&mr);
}

fn prefill_logits_match_python_goldens(mr: &Rc<ModelRuntime>) {
    // The asserted L2<->L3 numerics contract: the logits rust computes from
    // the exported HLO must match what python/jax computed from the same
    // parameters, for both verifier variants. (Greedy *tokens* can
    // legitimately flip on near-ties because jax's XLA and the crate's XLA
    // 0.5.1 fuse differently — see goldens.json generation in aot.py.)
    let mr = mr.clone();
    let goldens = quasar::util::json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let cfg = mr.cfg().clone();

    for variant in ["fp32", "w8a8"] {
        for g in goldens.as_arr().unwrap() {
            let prompt = g.get("prompt_ids").unwrap().as_i32_vec().unwrap();
            let expect: Vec<f64> = g
                .get(&format!("prefill_logits_{variant}"))
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            let task = g.get("task").unwrap().as_str().unwrap();

            let mut toks = vec![0i32; cfg.prefill_len];
            toks[..prompt.len()].copy_from_slice(&prompt);
            let (k, v) = mr.empty_cache(cfg.n_layers, 1);
            let out = mr
                .run_chunk(variant, "prefill", 1, &toks, &k, &v, &[0])
                .expect("prefill");
            let row = out.logits.row(&[0, prompt.len() - 1]);
            assert_eq!(row.len(), expect.len());
            let scale = expect.iter().fold(1f64, |a, b| a.max(b.abs()));
            for (i, (&r, &e)) in row.iter().zip(&expect).enumerate() {
                let err = (r as f64 - e).abs() / scale;
                assert!(
                    err < 2e-3,
                    "{variant}/{task}: logit {i} diverges: rust {r} vs python {e} (rel {err:.2e})"
                );
            }
        }
    }
}

fn speculative_greedy_equals_vanilla_greedy(mr: &Rc<ModelRuntime>) {
    // Lossless property at T=0: ngram-speculated output must be identical
    // to plain autoregressive output, for both verifier variants — and
    // speculation must actually be live (mean acceptance length >= 1).
    let prompt = golden_prompts(mr).remove(0);
    for variant in ["fp32", "w8a8"] {
        let rig = TestRig::new().verifier(variant).batch(1).gamma(4).seed(3);
        let (vanilla, _) = rig.clone().vanilla().run(mr, &[prompt.clone()], 32);
        let (spec, _) = rig.run_completions(mr, &[prompt.clone()], &|_| 32);
        assert_eq!(
            vanilla[0], spec[0].tokens,
            "{variant}: speculation changed greedy output"
        );
        assert!(
            spec[0].stats.mean_acceptance_len() >= 1.0,
            "{variant}: speculative decoding degenerated (L < 1)"
        );
    }
}

fn batched_serving_matches_single_request(mr: &Rc<ModelRuntime>) {
    // b=4 continuous batching must produce the same greedy tokens as b=1.
    let prompts = golden_prompts(mr);
    // duplicate prompts so the b=4 group is fully loaded
    let mut many = prompts.clone();
    many.extend(prompts.clone());
    let rig = TestRig::new().gamma(3).seed(1);
    let (single, _) = rig.clone().batch(1).run(mr, &many, 24);
    let (batched, _) = rig.batch(4).run(mr, &many, 24);
    assert_eq!(single, batched, "batched vs single greedy outputs diverge");
}

fn elastic_planner_matches_monolithic_and_prices_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    // A batch-4 group served below capacity with staggered budgets: the
    // elastic planner must execute smaller buckets (occupancy < 4, and a
    // drain tail at occupancy 1), commit greedy tokens bit-identical to the
    // monolithic configured-bucket engine, and price the run lower on the
    // simulated device.
    let prompts: Vec<Vec<i32>> = golden_prompts(mr).into_iter().take(3).collect();
    let rig = TestRig::new().gamma(3).batch(4).seed(2);
    // staggered finishes -> draining tail
    let stagger = |i: usize| 8 + 8 * i;
    let (mono_tokens, mono_engine) =
        rig.clone().elastic(false).run_with(mr, &prompts, &stagger);
    let (ela_tokens, ela_engine) = rig.run_with(mr, &prompts, &stagger);
    assert_eq!(mono_tokens, ela_tokens, "elastic planning changed greedy output");
    let (mono_log, ela_log) = (mono_engine.call_log, ela_engine.call_log);

    let full = 4usize;
    assert!(
        mono_log.records.iter().all(|r| r.fn_kind == FnKind::Prefill || r.batch == full),
        "monolithic engine must stay at the configured bucket"
    );
    assert!(
        ela_log
            .records
            .iter()
            .any(|r| r.fn_kind != FnKind::Prefill && r.batch < full),
        "elastic engine never used a smaller bucket"
    );

    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());
    let (t_mono, t_ela) = (perf.run_time(&mono_log, None), perf.run_time(&ela_log, None));
    assert!(
        t_ela < t_mono,
        "elastic modeled time {t_ela} not below monolithic {t_mono}"
    );
    eprintln!(
        "   modeled run: monolithic {t_mono:.6}s -> elastic {t_ela:.6}s \
         ({:.1}% saved), chunk efficiency {:.2} -> {:.2}",
        100.0 * (1.0 - t_ela / t_mono),
        mono_log.chunk_efficiency(),
        ela_log.chunk_efficiency(),
    );
}

/// The deterministic-seed governor smoke scenario (also driven by CI):
///
/// 1. **Healthy + sampled audits** — a governed w8a8 engine must commit
///    token streams bit-identical to the fp32-pinned engine, never demote,
///    and price strictly lower on the simulated device (the audit stream is
///    part of its decode time).
/// 2. **Audit machinery at rate 1.0** — shadow calls are recorded, the
///    measured top-1 agreement is perfect on the healthy verifier, and the
///    audits do not perturb the committed stream (audits cost traffic, not
///    tokens).
/// 3. **Adversarially-degraded verifier** — with the request class force-fed
///    failing audits (as a degraded variant would generate), the class
///    demotes and end-to-end output equals pure fp32, with every non-audit
///    decode/verify/prefill call on the fp32 artifacts.
fn governed_precision_matches_fp32_and_prices_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    let prompts: Vec<Vec<i32>> = golden_prompts(mr).into_iter().take(3).collect();
    let rig = |verifier: &str, governor: GovernorConfig| {
        TestRig::new().verifier(verifier).gamma(3).batch(4).seed(11).governor(governor)
    };
    let run = |mut engine: Engine| {
        for (i, p) in prompts.iter().enumerate() {
            engine.submit(
                p.clone(),
                GenParams {
                    max_new: 12 + 6 * i,
                    stop_at_eos: false,
                    ..GenParams::default()
                },
                "t",
            );
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let tokens: Vec<Vec<i32>> = done.into_iter().map(|c| c.tokens).collect();
        (tokens, engine)
    };
    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());

    // Baseline: fp32-pinned engine.
    let (fp32_tokens, fp32_engine) = run(rig("fp32", GovernorConfig::default()).engine(mr));

    // 1. Audit machinery at rate 1.0: every eligible sub-batch shadowed.
    // This run also *measures* whether this artifact set's w8a8 verifier is
    // healthy (perfect top-1 agreement) — the repo's goldens caveat allows
    // greedy near-tie flips, and the bit-identity guarantee is conditional
    // on health by design (paper §4.5), so the healthy-path assertions
    // below only apply when the measurement says they must hold.
    let audit_cfg = GovernorConfig { enabled: true, audit_rate: 1.0, ..Default::default() };
    let (audited_tokens, audited_engine) = run(rig("w8a8", audit_cfg).engine(mr));
    let audits = audited_engine.call_log.calls(FnKind::Audit);
    assert!(audits > 0, "audit_rate 1.0 recorded no shadow calls");
    assert!(
        perf.audit_time(&audited_engine.call_log) > 0.0,
        "audit overhead must be priced"
    );
    let agreement = audited_engine
        .metrics
        .hist(quasar::metrics::names::GOVERNOR_AGREEMENT)
        .expect("agreement histogram");
    let healthy = audited_engine.governor().demotions == 0 && agreement.mean() > 0.9999;

    if healthy {
        assert_eq!(
            audited_tokens, fp32_tokens,
            "audits perturbed the committed stream"
        );
        // 2. Healthy governed w8a8 with a light sampled audit stream: the
        // audit overhead must stay well inside the W8A8 weight-traffic
        // saving, and output must stay bit-identical to the fp32 pin.
        let gov_cfg = GovernorConfig {
            enabled: true,
            audit_rate: 0.0625,
            ..Default::default()
        };
        let (gov_tokens, gov_engine) = run(rig("w8a8", gov_cfg).engine(mr));
        assert_eq!(
            gov_tokens, fp32_tokens,
            "healthy governed w8a8 diverged from the fp32-pinned engine"
        );
        assert_eq!(gov_engine.governor().demotions, 0, "healthy verifier demoted");
        let (t_gov, t_fp32) = (
            perf.decode_time(&gov_engine.call_log, None),
            perf.decode_time(&fp32_engine.call_log, None),
        );
        assert!(
            t_gov < t_fp32,
            "governed w8a8 decode time {t_gov} (audits included) not below fp32 {t_fp32}"
        );
        assert!(
            gov_engine
                .call_log
                .records
                .iter()
                .any(|r| r.fn_kind == FnKind::Verify && r.variant == "w8a8"),
            "governed engine never executed the quantized verifier"
        );
        eprintln!(
            "   healthy: decode {t_fp32:.6}s (fp32) -> {t_gov:.6}s (governed w8a8), \
             {audits} audits at rate 1.0, agreement {:.4}",
            agreement.mean()
        );
    } else {
        // Quantization flips top-1 somewhere on this artifact set, so no
        // cross-variant bit-identity is owed (the guarantee is conditional
        // on health, §4.5). If agreement sank below the floor for long
        // enough, demotion must have fired; a mild drift above the floor
        // legitimately demotes nothing. The deterministic demotion path is
        // asserted unconditionally in part 3 below.
        if agreement.mean() < audited_engine.governor().cfg().floor {
            assert!(
                audited_engine.governor().demotions >= 1,
                "mean agreement {:.4} sat below the floor but nothing demoted",
                agreement.mean()
            );
        }
        eprintln!(
            "   [notice] w8a8 flips top-1 on these artifacts (agreement {:.4}, \
             demotions {}); healthy-path bit-identity assertions skipped",
            agreement.mean(),
            audited_engine.governor().demotions
        );
    }

    // 3. Adversarially-degraded w8a8: force the class's audit stream below
    // the floor (what a broken quantized variant would produce), then run.
    // Every commit-path call must be fp32 and output must equal pure fp32.
    let degraded_cfg = GovernorConfig {
        enabled: true,
        audit_rate: 1.0,
        probe_after_steps: 10_000, // keep probes out of this short run
        ..Default::default()
    };
    let mut engine = rig("w8a8", degraded_cfg).engine(mr);
    let min_audits = engine.governor().cfg().min_audits;
    for _ in 0..min_audits {
        engine.governor_mut().record_audit("t", 0.0, -1.0);
    }
    assert_eq!(engine.governor().demotions, 1, "forced bad audits must demote");
    let (demoted_tokens, demoted_engine) = run(engine);
    assert_eq!(
        demoted_tokens, fp32_tokens,
        "demoted class output must equal the fp32-pinned engine"
    );
    assert!(
        demoted_engine
            .call_log
            .records
            .iter()
            .filter(|r| r.fn_kind != FnKind::Audit)
            .all(|r| r.variant == "fp32"),
        "a demoted class must never execute the quantized verifier"
    );
}

/// The prefix-cache acceptance gate: over a shared-prefix workload (every
/// goldens prompt submitted twice, so each duplicate's admission can reuse
/// the first's committed prefix), the warm engine must (1) commit token
/// streams bit-identical to the cold (cache-off) engine, (2) actually hit
/// the cache, and (3) price modeled admission strictly lower, because each
/// hit's prefill call carries only the executed suffix tokens.
fn prefix_cache_reuse_is_bit_identical_and_prices_admission_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    let prompts = golden_prompts(mr);
    // Duplicate the set: the second copy's admissions share full prefixes.
    let mut many = prompts.clone();
    many.extend(prompts.clone());

    let rig = TestRig::new().gamma(3).batch(4).seed(17);
    let (cold_tokens, cold_engine) =
        rig.clone().prefix(PrefixCacheConfig::off()).run(mr, &many, 16);
    let warm_cfg = PrefixCacheConfig { min_prefix: 2, ..Default::default() };
    let (warm_tokens, warm_engine) = rig.prefix(warm_cfg).run(mr, &many, 16);

    assert_eq!(
        cold_tokens, warm_tokens,
        "prefix reuse changed the committed stream"
    );
    assert_eq!(cold_engine.prefix_cache().stats().hits, 0);
    let ps = warm_engine.prefix_cache().stats();
    assert!(ps.hits > 0, "duplicated prompts produced no prefix hits");
    assert!(ps.hit_tokens > 0, "hits served no tokens");
    assert!(ps.segments > 0 && ps.resident_bytes > 0);
    assert!(ps.resident_pages > 0, "paged store holds pages, not rows");
    assert_eq!(ps.leases, 0, "admission leaked a prefix lease");
    // The gauge pipeline the stats endpoint reads must agree with the cache.
    assert_eq!(
        warm_engine.metrics.gauge(names::PREFIX_HITS) as u64,
        ps.hits,
        "published hit gauge diverged from the cache's own counter"
    );
    assert_eq!(
        warm_engine.metrics.gauge(names::PREFIX_RESIDENT_PAGES) as usize,
        ps.resident_pages,
        "published page gauge diverged from the cache's own counter"
    );
    let (hits, hit_tokens) = (ps.hits, ps.hit_tokens);

    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());
    let (t_cold, t_warm) = (
        perf.prefill_time(&cold_engine.call_log),
        perf.prefill_time(&warm_engine.call_log),
    );
    assert!(
        t_warm < t_cold,
        "warm modeled admission {t_warm} not below cold {t_cold}"
    );
    // Decode-phase pricing is untouched by admission reuse.
    let (d_cold, d_warm) = (
        perf.decode_time(&cold_engine.call_log, None),
        perf.decode_time(&warm_engine.call_log, None),
    );
    assert!((d_cold - d_warm).abs() < 1e-12, "decode pricing drifted");
    eprintln!(
        "   modeled admission: cold {t_cold:.6}s -> warm {t_warm:.6}s \
         ({:.1}% saved), {hits} hits, {hit_tokens} tokens from cache",
        100.0 * (1.0 - t_warm / t_cold)
    );
}

/// The paged-store acceptance gate, on real artifacts:
///
/// 1. **Page-granular residency** — one cached prompt pins exactly
///    `ceil(len/page_tokens)` pool pages, not a `max_seq` row.
/// 2. **Page sharing** — two admissions diverging after a shared prefix
///    reference the same physical pages (share ratio > 1) with zero pool
///    copies for the shared extent, and a duplicate admission copies
///    nothing at all.
/// 3. **Mid-stream snapshots** — a multi-turn resubmit
///    (`prompt ++ answer ++ follow-up`) admits against the finished
///    request's extended run, hitting past the original prompt, and the
///    committed stream stays bit-identical to a cold engine replaying the
///    same two submissions.
/// 4. **Boot warm-up** — `Engine::warm_prefix` caches a template without
///    touching lookup counters, and the very first admission after it hits.
fn paged_store_pins_pages_shares_them_and_serves_mid_stream(mr: &Rc<ModelRuntime>) {
    let prompts = golden_prompts(mr);
    let p0 = prompts[0].clone();
    // Small pages so even short golden prompts span several and share at
    // least one full page across divergent siblings.
    let page = 4usize;
    let mcfg = mr.cfg().clone();
    let page_pair = 2 * mcfg.n_layers * mcfg.n_heads * page * mcfg.head_dim
        * std::mem::size_of::<f32>();
    let pcfg = |mid_stream: bool| PrefixCacheConfig {
        min_prefix: 2,
        page_tokens: page,
        mid_stream,
        ..Default::default()
    };

    // 1 + 2a. One prompt cached (mid-stream off), then a duplicate: pages
    // tile the prompt, and the duplicate admission copies nothing.
    let rig = TestRig::new().gamma(3).batch(4).seed(21).prefix(pcfg(false));
    let (_, engine) = rig.clone().run(mr, &[p0.clone(), p0.clone()], 8);
    let ps = engine.prefix_cache().stats();
    let want_pages = p0.len().div_ceil(page);
    assert_eq!(ps.segments, 1, "duplicate key must not add a run");
    assert_eq!(
        ps.resident_pages, want_pages,
        "a cached prefix pins ceil(len/page_tokens) pages"
    );
    assert_eq!(
        ps.resident_bytes,
        want_pages * page_pair,
        "residency is page-granular"
    );
    assert!(
        ps.resident_bytes < mr.cache_row_bytes(mcfg.n_layers),
        "paged residency must undercut the old whole-row segment"
    );
    assert_eq!(
        ps.copied_pages, want_pages as u64,
        "the duplicate admission must not copy pool pages"
    );
    assert!(ps.hits >= 1, "duplicate admission must hit");

    // 2b. Two prompts diverging after a shared prefix: the shared full
    // pages are referenced by both runs, not copied — and outputs stay
    // bit-identical to a cold engine.
    let mut pa = p0.clone();
    let mut pb = p0.clone();
    pa.push(5);
    pb.push(6); // distinct single-token bodies after the shared "template"
    let pair = [pa.clone(), pb.clone()];
    let (warm_tokens, engine) = rig.clone().run(mr, &pair, 8);
    let (cold_tokens, _) =
        rig.clone().prefix(PrefixCacheConfig::off()).run(mr, &pair, 8);
    assert_eq!(warm_tokens, cold_tokens, "page sharing changed the stream");
    let ps = engine.prefix_cache().stats();
    assert_eq!(ps.segments, 2);
    assert!(
        ps.shared_pages >= (p0.len() / page) as u64,
        "divergent siblings must share the template's full pages"
    );
    assert!(
        ps.page_share_ratio() > 1.0,
        "one physical page must back both runs (ratio {})",
        ps.page_share_ratio()
    );
    assert!(
        (ps.copied_pages as usize) < 2 * pa.len().div_ceil(page),
        "the second admission must not re-copy the shared prefix"
    );

    // 3. Mid-stream: turn 1, then a follow-up over the full transcript.
    let params = |max_new: usize| GenParams {
        max_new,
        stop_at_eos: false,
        ..GenParams::default()
    };
    let rig_ms = TestRig::new().gamma(3).batch(1).seed(22).prefix(pcfg(true));
    let mut warm = rig_ms.engine(mr);
    warm.submit(p0.clone(), params(24), "t");
    let c1 = warm.run_to_completion().unwrap().remove(0);
    assert!(!c1.tokens.is_empty());
    let mut follow = p0.clone();
    follow.extend_from_slice(&c1.tokens);
    follow.push(7); // the next user turn
    warm.submit(follow.clone(), params(8), "t");
    let c2_warm = warm.run_to_completion().unwrap().remove(0);
    let ps = warm.prefix_cache().stats();
    assert!(
        ps.mid_stream_hit_tokens > 0,
        "follow-up admission must hit the mid-stream run"
    );
    assert!(
        ps.hit_tokens as usize > p0.len(),
        "mid-stream hit must reach past the original prompt \
         ({} hit tokens vs {}-token prompt)",
        ps.hit_tokens,
        p0.len()
    );
    // Bit-identity across the whole conversation: a cold engine replaying
    // both submissions commits the same streams.
    let mut cold = rig_ms.clone().prefix(PrefixCacheConfig::off()).engine(mr);
    cold.submit(p0.clone(), params(24), "t");
    let c1_cold = cold.run_to_completion().unwrap().remove(0);
    assert_eq!(c1.tokens, c1_cold.tokens);
    cold.submit(follow, params(8), "t");
    let c2_cold = cold.run_to_completion().unwrap().remove(0);
    assert_eq!(
        c2_warm.tokens, c2_cold.tokens,
        "mid-stream reuse changed the committed stream"
    );

    // 4. Boot warm-up: cache the template before any traffic; the first
    // admission hits and commits the same tokens as a cold first turn.
    let mut warmed = TestRig::new().gamma(3).batch(1).seed(23).prefix(pcfg(true)).engine(mr);
    let cached = warmed.warm_prefix(&[(p0.clone(), "t".to_string())]).unwrap();
    assert_eq!(cached, 1);
    let ps0 = warmed.prefix_cache().stats();
    assert_eq!((ps0.hits, ps0.misses), (0, 0), "warm-up is not lookup traffic");
    assert!(ps0.resident_pages > 0);
    warmed.submit(p0.clone(), params(8), "t");
    let cw = warmed.run_to_completion().unwrap().remove(0);
    let ps1 = warmed.prefix_cache().stats();
    assert_eq!(ps1.hits, 1, "first admission after warm-up must hit");
    assert_eq!(
        ps1.hit_tokens as usize,
        p0.len() - 1,
        "warmed template serves the whole prompt (capped at len-1)"
    );
    let (cold_first, _) = TestRig::new()
        .gamma(3)
        .batch(1)
        .seed(23)
        .prefix(PrefixCacheConfig::off())
        .run(mr, &[p0.clone()], 8);
    assert_eq!(cw.tokens, cold_first[0], "warmed admission changed the stream");
    eprintln!(
        "   paged: {} pages/prompt, share ratio {:.2}, {} mid-stream hit tokens",
        want_pages,
        ps.page_share_ratio(),
        ps.mid_stream_hit_tokens
    );
}

/// The zero-copy paged-row acceptance gate: the page-table backend must be
/// a pure representation change against the copy-based slab rows.
///
/// 1. **Bit-identity** — over a shared-prefix workload (goldens duplicated,
///    batch 4, mid-stream on) both backends commit identical greedy streams.
/// 2. **Zero full-page copies** — every admission leases its resident full
///    pages by reference (cold admissions share with their own just-inserted
///    run), so `row_copied_pages` stays 0; only non-page-aligned tails copy.
/// 3. **Strictly lower residency** — the paged engine's peak resident KV
///    undercuts the copy engine's, which always carries the whole
///    batch x max_seq slab.
/// 4. **Lease hygiene** — after the drain every row page reference is
///    released (`row_page_refs == 0`).
/// 5. **Multi-turn** — a two-turn conversation (follow-up resubmits the
///    transcript) commits the same streams on both backends.
fn paged_rows_match_copy_rows_and_cut_residency(mr: &Rc<ModelRuntime>) {
    let prompts = golden_prompts(mr);
    let mut many = prompts.clone();
    many.extend(prompts.clone());
    let pcfg = PrefixCacheConfig {
        min_prefix: 2,
        page_tokens: 4,
        mid_stream: true,
        ..Default::default()
    };
    let rig = TestRig::new().gamma(3).batch(4).seed(29).prefix(pcfg.clone());
    let (paged_tokens, paged_engine) = rig.clone().run(mr, &many, 16);
    let (copy_tokens, copy_engine) = rig.clone().paged_rows(false).run(mr, &many, 16);
    assert_eq!(
        paged_tokens, copy_tokens,
        "paged rows changed the committed stream"
    );

    let ps = paged_engine.prefix_cache().stats();
    assert_eq!(
        ps.row_copied_pages, 0,
        "an admission re-copied full resident pages instead of leasing them"
    );
    assert!(
        ps.row_shared_pages > 0,
        "no admission leased pages by reference"
    );
    assert_eq!(
        ps.row_page_refs, 0,
        "a finished row leaked page leases"
    );
    assert_eq!(
        copy_engine.prefix_cache().stats().row_shared_pages,
        0,
        "the copy backend must not touch the row-lease path"
    );

    let paged_peak = paged_engine.metrics.gauge(names::KV_RESIDENT_PEAK_BYTES);
    let copy_peak = copy_engine.metrics.gauge(names::KV_RESIDENT_PEAK_BYTES);
    assert!(paged_peak > 0 && copy_peak > 0, "peak gauges unpublished");
    assert!(
        paged_peak < copy_peak,
        "paged peak resident KV {paged_peak} not below copy {copy_peak}"
    );

    // Multi-turn differential: turn 2 resubmits the full transcript; both
    // backends must walk the same conversation.
    let p0 = prompts[0].clone();
    let params = |max_new: usize| GenParams {
        max_new,
        stop_at_eos: false,
        ..GenParams::default()
    };
    let turn_pair = |paged: bool| {
        let mut engine = TestRig::new()
            .gamma(3)
            .batch(1)
            .seed(31)
            .prefix(pcfg.clone())
            .paged_rows(paged)
            .engine(mr);
        engine.submit(p0.clone(), params(16), "t");
        let c1 = engine.run_to_completion().unwrap().remove(0);
        let mut follow = p0.clone();
        follow.extend_from_slice(&c1.tokens);
        follow.push(7);
        engine.submit(follow, params(8), "t");
        let c2 = engine.run_to_completion().unwrap().remove(0);
        (c1.tokens, c2.tokens)
    };
    assert_eq!(
        turn_pair(true),
        turn_pair(false),
        "paged rows changed the multi-turn conversation"
    );
    eprintln!(
        "   paged vs copy: peak resident {paged_peak} vs {copy_peak} bytes \
         ({:.1}% cut), {} shared pages, {} tail copies, 0 full-page copies",
        100.0 * (1.0 - paged_peak as f64 / copy_peak as f64),
        ps.row_shared_pages,
        ps.row_tail_copies
    );
}

/// The chunked-admission acceptance gate (the continuous-batching
/// tentpole): splitting admission prefill into planner-packed chunks that
/// ride spare decode slots must be a pure scheduling change.
///
/// 1. **Bit-identity** — same staggered workload, same seed: the chunked
///    engine commits exactly the monolithic engine's greedy streams.
/// 2. **Fewer stalls** — the monolithic engine's admission prefills run
///    while other rows sit decoding (`decode_stall_steps > 0`); the
///    chunked engine rides those chunks in the decode steps it executes
///    anyway and must count strictly fewer.
/// 3. **Priced savings** — every ridden chunk banks the avoided
///    dedicated-call price into the `prefill_stall_saved_s` histogram.
fn chunked_prefill_matches_monolithic_and_avoids_stalls(mr: &Rc<ModelRuntime>) {
    let mcfg = mr.cfg().clone();
    let mut many = golden_prompts(mr);
    // One prompt spanning several prefill windows, so a chunked admission
    // accumulates its row across multiple rides before the first token.
    let long_len = (mcfg.prefill_len + 8).min(mcfg.max_seq.saturating_sub(24));
    let long: Vec<i32> = many[0].iter().cycle().take(long_len).copied().collect();
    many.push(long);
    let second = many.clone();
    many.extend(second);
    // Distinct budgets stagger the finishes, so later admissions always
    // find other rows mid-decode.
    let stagger = |i: usize| 6 + 3 * (i % 5);
    let rig = TestRig::new().gamma(3).batch(4).seed(37);
    let (mono_tokens, mono_engine) = rig.clone().run_with(mr, &many, &stagger);
    let (chunk_tokens, chunk_engine) =
        rig.chunked_prefill(true).run_with(mr, &many, &stagger);
    assert_eq!(
        mono_tokens, chunk_tokens,
        "chunked admission changed the committed stream"
    );

    let (mono_stalls, chunk_stalls) = (
        mono_engine.metrics.counter(names::DECODE_STALL_STEPS),
        chunk_engine.metrics.counter(names::DECODE_STALL_STEPS),
    );
    assert!(
        mono_stalls > 0,
        "staggered admissions never stalled the monolithic engine (workload too light)"
    );
    assert!(
        chunk_stalls < mono_stalls,
        "chunked prefill did not cut decode stalls ({chunk_stalls} vs {mono_stalls})"
    );
    assert!(
        chunk_engine.metrics.counter(names::PREFILL_CHUNKS) as usize >= many.len(),
        "every admission must flow through the chunk counter"
    );
    assert_eq!(
        mono_engine.metrics.gauge(names::PREFILL_INFLIGHT_ROWS),
        0,
        "monolithic admission must never leave a row mid-prefill"
    );
    let saved = chunk_engine
        .metrics
        .hist(names::PREFILL_STALL_SAVED_S)
        .map(|h| h.sum())
        .unwrap_or(0.0);
    assert!(saved > 0.0, "ridden chunks must bank modeled stall savings");
    eprintln!(
        "   stalls: monolithic {mono_stalls} -> chunked {chunk_stalls}, \
         {} chunks, {saved:.6}s modeled stall saved",
        chunk_engine.metrics.counter(names::PREFILL_CHUNKS)
    );
}

/// Admission-capacity regression: a warm request is gated on its
/// post-splice *suffix*, not the raw prompt length — a shared template
/// longer than one prefill window admits untruncated, the duplicate's
/// splice covers all but the final token, and the warm admission executes
/// strictly fewer prefill windows than the cold replay.
fn warm_admission_gates_on_suffix_not_prompt(mr: &Rc<ModelRuntime>) {
    let mcfg = mr.cfg().clone();
    let base = golden_prompts(mr).remove(0);
    let len = (mcfg.prefill_len + 8).min(mcfg.max_seq.saturating_sub(16));
    assert!(
        len > mcfg.prefill_len,
        "artifact max_seq leaves no room for a multi-window template"
    );
    let long: Vec<i32> = base.iter().cycle().take(len).copied().collect();
    let pair = [long.clone(), long.clone()];
    let pcfg = PrefixCacheConfig { min_prefix: 2, page_tokens: 4, ..Default::default() };
    let rig = TestRig::new().gamma(3).batch(1).seed(41);
    let (warm_tokens, warm_engine) = rig.clone().prefix(pcfg).run(mr, &pair, 8);
    let (cold_tokens, cold_engine) =
        rig.prefix(PrefixCacheConfig::off()).run(mr, &pair, 8);
    assert_eq!(warm_tokens, cold_tokens, "suffix-gated admission changed the stream");
    assert_eq!(
        warm_engine.metrics.counter(names::PROMPT_TRUNCATED),
        0,
        "a multi-window template must admit untruncated"
    );
    let ps = warm_engine.prefix_cache().stats();
    assert!(ps.hits >= 1, "the duplicate template produced no hit");
    assert_eq!(
        ps.hit_tokens as usize,
        len - 1,
        "the splice must cover the whole template (capped at len-1)"
    );
    let (warm_prefills, cold_prefills) = (
        warm_engine.call_log.calls(FnKind::Prefill),
        cold_engine.call_log.calls(FnKind::Prefill),
    );
    assert!(
        warm_prefills < cold_prefills,
        "warm admission must run fewer prefill windows ({warm_prefills} vs {cold_prefills})"
    );
    eprintln!(
        "   {len}-token template: {cold_prefills} cold prefill windows -> \
         {warm_prefills} warm, {} hit tokens",
        ps.hit_tokens
    );
}

/// An over-long prompt must be visibly truncated: flagged on the
/// completion's stats, counted in the metrics registry, and still served.
/// The cap is the context window (`max_seq - 2`), not the prefill window —
/// a prompt spanning several prefill windows admits whole, fed chunk by
/// chunk by the admission window loop.
fn prompt_truncation_is_flagged_not_silent(mr: &Rc<ModelRuntime>) {
    let prompt = golden_prompts(mr).remove(0);
    let mcfg = mr.cfg().clone();
    let cap = mcfg.max_seq - 2;

    let mut engine = TestRig::new().batch(1).gamma(3).engine(mr);
    // Tile the golden prompt past the whole context window.
    let long: Vec<i32> = prompt.iter().cycle().take(mcfg.max_seq + 5).copied().collect();
    engine.submit(
        long,
        GenParams { max_new: 4, stop_at_eos: false, ..GenParams::default() },
        "t",
    );
    // Longer than one prefill window but inside the context cap: served
    // whole through the multi-window admission loop, never cut.
    let multi: Vec<i32> = prompt
        .iter()
        .cycle()
        .take((mcfg.prefill_len + 7).min(cap))
        .copied()
        .collect();
    let multi_len = multi.len();
    engine.submit(
        multi,
        GenParams { max_new: 4, stop_at_eos: false, ..GenParams::default() },
        "t",
    );
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done[0].stats.prompt_truncated, 1, "truncation not flagged");
    assert_eq!(done[0].prompt_len, cap, "prompt not cut to the context cap");
    assert!(!done[0].tokens.is_empty(), "truncated request still serves");
    assert_eq!(done[1].stats.prompt_truncated, 0, "multi-window prompt falsely flagged");
    assert_eq!(done[1].prompt_len, multi_len, "multi-window prompt must admit whole");
    assert!(!done[1].tokens.is_empty(), "multi-window prompt still serves");
    assert_eq!(engine.metrics.counter(names::PROMPT_TRUNCATED), 1);
}

fn pruned_drafter_runs_and_verifier_stays_lossless(mr: &Rc<ModelRuntime>) {
    let prompt = golden_prompts(mr).remove(0);
    let rig = TestRig::new().batch(1).gamma(3).seed(5);
    let (vanilla, _) = rig.clone().vanilla().run(mr, &[prompt.clone()], 16);
    let (pruned, _) = rig
        .drafter(DrafterKind::Pruned("pruned75".into()))
        .run(mr, &[prompt.clone()], 16);
    assert_eq!(
        vanilla, pruned,
        "pruned drafting must not change greedy output (verifier decides)"
    );
}

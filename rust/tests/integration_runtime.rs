//! Integration tests over real AOT artifacts: load the manifest, compile
//! HLO-text programs on the PJRT CPU client, and check the engine's numerics
//! against python-computed goldens.
//!
//! Artifact root resolution: `QUASAR_ARTIFACTS` env var, else `artifacts/`.
//! Tests skip (pass with a notice) when artifacts are absent so `cargo test`
//! works before `make artifacts`.

use std::path::PathBuf;
use std::rc::Rc;

use quasar::coordinator::{DrafterKind, Engine, EngineConfig, FnKind, GenParams};
use quasar::perfmodel::PerfModel;
use quasar::runtime::{Manifest, ModelRuntime, XlaRuntime};
use quasar::spec::NgramConfig;
use quasar::util::json;

fn artifacts_root() -> Option<PathBuf> {
    let root = std::env::var("QUASAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("[skip] no artifacts at {root:?} — run `make artifacts`");
        None
    }
}

fn first_model(manifest: &Manifest) -> String {
    manifest.models.keys().next().expect("at least one model").clone()
}

fn load_model(root: &PathBuf) -> (Manifest, Rc<ModelRuntime>) {
    let rt = Rc::new(XlaRuntime::cpu().expect("pjrt cpu client"));
    let manifest = Manifest::load(root).expect("manifest");
    let name = first_model(&manifest);
    let mr = Rc::new(ModelRuntime::load(rt, &manifest, &name).expect("model"));
    (manifest, mr)
}

/// One PJRT client per process: xla_extension SIGSEGVs when a second CPU
/// client is created after the first is dropped, so all scenarios share one
/// `ModelRuntime` under a single #[test].
#[test]
fn integration_scenarios() {
    // big stack: the HLO text parser recurses deeply (util::bigstack docs)
    quasar::util::bigstack::run(integration_scenarios_inner)
}

fn integration_scenarios_inner() {
    let Some(root) = artifacts_root() else { return };
    let (manifest, mr) = load_model(&root);
    eprintln!("== prefill_logits_match_python_goldens");
    prefill_logits_match_python_goldens(&mr);
    eprintln!("== speculative_greedy_equals_vanilla_greedy");
    speculative_greedy_equals_vanilla_greedy(&mr);
    eprintln!("== batched_serving_matches_single_request");
    batched_serving_matches_single_request(&mr);
    eprintln!("== elastic_planner_matches_monolithic_and_prices_lower");
    elastic_planner_matches_monolithic_and_prices_lower(&manifest, &mr);
    eprintln!("== pruned_drafter_runs_and_verifier_stays_lossless");
    pruned_drafter_runs_and_verifier_stays_lossless(&mr);
}

fn prefill_logits_match_python_goldens(mr: &Rc<ModelRuntime>) {
    // The asserted L2<->L3 numerics contract: the logits rust computes from
    // the exported HLO must match what python/jax computed from the same
    // parameters, for both verifier variants. (Greedy *tokens* can
    // legitimately flip on near-ties because jax's XLA and the crate's XLA
    // 0.5.1 fuse differently — see goldens.json generation in aot.py.)
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let cfg = mr.cfg().clone();

    for variant in ["fp32", "w8a8"] {
        for g in goldens.as_arr().unwrap() {
            let prompt = g.get("prompt_ids").unwrap().as_i32_vec().unwrap();
            let expect: Vec<f64> = g
                .get(&format!("prefill_logits_{variant}"))
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            let task = g.get("task").unwrap().as_str().unwrap();

            let mut toks = vec![0i32; cfg.prefill_len];
            toks[..prompt.len()].copy_from_slice(&prompt);
            let (k, v) = mr.empty_cache(cfg.n_layers, 1);
            let out = mr
                .run_chunk(variant, "prefill", 1, &toks, &k, &v, &[0])
                .expect("prefill");
            let row = out.logits.row(&[0, prompt.len() - 1]);
            assert_eq!(row.len(), expect.len());
            let scale = expect.iter().fold(1f64, |a, b| a.max(b.abs()));
            for (i, (&r, &e)) in row.iter().zip(&expect).enumerate() {
                let err = (r as f64 - e).abs() / scale;
                assert!(
                    err < 2e-3,
                    "{variant}/{task}: logit {i} diverges: rust {r} vs python {e} (rel {err:.2e})"
                );
            }
        }
    }
}

fn speculative_greedy_equals_vanilla_greedy(mr: &Rc<ModelRuntime>) {
    // Lossless property at T=0: ngram-speculated output must be identical
    // to plain autoregressive output, for both verifier variants.
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompt = goldens.idx(0).unwrap().get("prompt_ids").unwrap().as_i32_vec().unwrap();

    for variant in ["fp32", "w8a8"] {
        let gen = |drafter: DrafterKind| {
            let cfg = EngineConfig {
                verifier: variant.into(),
                drafter,
                batch: 1,
                gamma: 4,
                seed: 3,
                policy: Default::default(),
                elastic: true,
            };
            let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
            engine.submit(
                prompt.clone(),
                GenParams { max_new: 32, stop_at_eos: false, ..GenParams::default() },
                "t",
            );
            engine.run_to_completion().unwrap().remove(0)
        };
        let vanilla = gen(DrafterKind::Vanilla);
        let spec = gen(DrafterKind::Ngram(NgramConfig {
            gamma: 4,
            adaptive: false,
            ..Default::default()
        }));
        assert_eq!(vanilla.tokens, spec.tokens, "{variant}: speculation changed greedy output");
        assert!(spec.stats.mean_acceptance_len() >= 1.0);
    }
}

fn batched_serving_matches_single_request(mr: &Rc<ModelRuntime>) {
    // b=4 continuous batching must produce the same greedy tokens as b=1.
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompts: Vec<Vec<i32>> = goldens
        .as_arr()
        .unwrap()
        .iter()
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect();

    let run = |batch: usize, prompts: &[Vec<i32>]| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig { gamma: 3, adaptive: false, ..Default::default() }),
            batch,
            gamma: 3,
            seed: 1,
            policy: Default::default(),
            elastic: true,
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        let mut ids = Vec::new();
        for p in prompts {
            ids.push(engine.submit(
                p.clone(),
                GenParams { max_new: 24, stop_at_eos: false, ..GenParams::default() },
                "t",
            ));
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };

    // duplicate prompts so the b=4 group is fully loaded
    let mut many = prompts.clone();
    many.extend(prompts.clone());
    let single: Vec<_> = run(1, &many);
    let batched: Vec<_> = run(4, &many);
    assert_eq!(single, batched, "batched vs single greedy outputs diverge");
}

fn elastic_planner_matches_monolithic_and_prices_lower(
    manifest: &Manifest,
    mr: &Rc<ModelRuntime>,
) {
    // A batch-4 group served below capacity with staggered budgets: the
    // elastic planner must execute smaller buckets (occupancy < 4, and a
    // drain tail at occupancy 1), commit greedy tokens bit-identical to the
    // monolithic configured-bucket engine, and price the run lower on the
    // simulated device.
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompts: Vec<Vec<i32>> = goldens
        .as_arr()
        .unwrap()
        .iter()
        .take(3)
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect();

    let run = |elastic: bool| {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig {
                gamma: 3,
                adaptive: false,
                ..Default::default()
            }),
            batch: 4,
            gamma: 3,
            seed: 2,
            policy: Default::default(),
            elastic,
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        for (i, p) in prompts.iter().enumerate() {
            engine.submit(
                p.clone(),
                GenParams {
                    max_new: 8 + 8 * i, // staggered finishes -> draining tail
                    stop_at_eos: false,
                    ..GenParams::default()
                },
                "t",
            );
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let tokens: Vec<Vec<i32>> = done.into_iter().map(|c| c.tokens).collect();
        (tokens, engine.call_log.clone())
    };

    let (mono_tokens, mono_log) = run(false);
    let (ela_tokens, ela_log) = run(true);
    assert_eq!(mono_tokens, ela_tokens, "elastic planning changed greedy output");

    let full = 4usize;
    assert!(
        mono_log.records.iter().all(|r| r.fn_kind == FnKind::Prefill || r.batch == full),
        "monolithic engine must stay at the configured bucket"
    );
    assert!(
        ela_log
            .records
            .iter()
            .any(|r| r.fn_kind != FnKind::Prefill && r.batch < full),
        "elastic engine never used a smaller bucket"
    );

    let perf = PerfModel::new(manifest.cost_model.clone(), mr.cfg().clone());
    let (t_mono, t_ela) = (perf.run_time(&mono_log, None), perf.run_time(&ela_log, None));
    assert!(
        t_ela < t_mono,
        "elastic modeled time {t_ela} not below monolithic {t_mono}"
    );
    eprintln!(
        "   modeled run: monolithic {t_mono:.6}s -> elastic {t_ela:.6}s \
         ({:.1}% saved), chunk efficiency {:.2} -> {:.2}",
        100.0 * (1.0 - t_ela / t_mono),
        mono_log.chunk_efficiency(),
        ela_log.chunk_efficiency(),
    );
}

fn pruned_drafter_runs_and_verifier_stays_lossless(mr: &Rc<ModelRuntime>) {
    let mr = mr.clone();
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    let prompt = goldens.idx(0).unwrap().get("prompt_ids").unwrap().as_i32_vec().unwrap();

    let gen = |drafter: DrafterKind| {
        let cfg = EngineConfig {
            verifier: "fp32".into(),
            drafter,
            batch: 1,
            gamma: 3,
            seed: 5,
            policy: Default::default(),
            elastic: true,
        };
        let mut engine = Engine::new(Rc::clone(&mr), cfg).unwrap();
        engine.submit(
            prompt.clone(),
            GenParams { max_new: 16, stop_at_eos: false, ..GenParams::default() },
            "t",
        );
        engine.run_to_completion().unwrap().remove(0)
    };
    let vanilla = gen(DrafterKind::Vanilla);
    let pruned = gen(DrafterKind::Pruned("pruned75".into()));
    assert_eq!(
        vanilla.tokens, pruned.tokens,
        "pruned drafting must not change greedy output (verifier decides)"
    );
}

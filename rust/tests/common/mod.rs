//! Shared test harness for the integration and property suites.
//!
//! Two backends, one rig:
//!
//! * **Artifact-gated** — [`artifacts_root`] / [`load_model`] /
//!   [`golden_prompts`] resolve the AOT artifact set (skipping cleanly when
//!   absent) and [`TestRig`] builds real [`Engine`]s from a small builder
//!   instead of each test hand-rolling an `EngineConfig` literal.
//! * **Mock-chunk backed** — [`sim`] hosts the deterministic mock
//!   transformer chunk and the minimal engine around it that the property
//!   suites drive when no PJRT artifacts exist: real `BatchGroup` / tensor
//!   movement and the real step planner, with logits that depend on the
//!   whole cache prefix so any row-map / gather / position bug changes the
//!   committed stream.
//!
//! Not every test crate uses every item — hence the file-wide
//! `dead_code` allowance (each `tests/*.rs` is its own crate).
#![allow(dead_code)]

use std::path::PathBuf;
use std::rc::Rc;

use quasar::coordinator::{
    Completion, DrafterKind, Engine, EngineConfig, GenParams, GovernorConfig,
    PrefixCacheConfig, SchedPolicy,
};
use quasar::runtime::{Manifest, ModelRuntime, XlaRuntime};
use quasar::spec::NgramConfig;
use quasar::util::json;

/// Artifact root resolution: `QUASAR_ARTIFACTS` env var, else `artifacts/`.
/// Tests skip (pass with a notice) when artifacts are absent so
/// `cargo test` works before `make artifacts`.
pub fn artifacts_root() -> Option<PathBuf> {
    let root = std::env::var("QUASAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("[skip] no artifacts at {root:?} — run `make artifacts`");
        None
    }
}

/// Load the manifest and its first model on a fresh PJRT CPU client.
/// xla_extension tolerates exactly one client per process, so callers share
/// the returned runtime across every scenario of their `#[test]`.
pub fn load_model(root: &PathBuf) -> (Manifest, Rc<ModelRuntime>) {
    let rt = Rc::new(XlaRuntime::cpu().expect("pjrt cpu client"));
    let manifest = Manifest::load(root).expect("manifest");
    let name = manifest.models.keys().next().expect("at least one model").clone();
    let mr = Rc::new(ModelRuntime::load(rt, &manifest, &name).expect("model"));
    (manifest, mr)
}

/// The goldens' prompt token ids — the deterministic seeded workload the
/// integration scenarios share.
pub fn golden_prompts(mr: &Rc<ModelRuntime>) -> Vec<Vec<i32>> {
    let goldens = json::parse_file(&mr.entry.goldens_path).expect("goldens");
    goldens
        .as_arr()
        .expect("goldens array")
        .iter()
        .map(|g| g.get("prompt_ids").unwrap().as_i32_vec().unwrap())
        .collect()
}

/// Engine builder for the integration scenarios: sane speculative defaults
/// (fp32 verifier, non-adaptive ngram drafter, batch 4, elastic planning,
/// governor off, prefix cache at its default), each knob overridable in one
/// chained call. Replaces the per-test `EngineConfig` literals.
#[derive(Clone)]
pub struct TestRig {
    pub verifier: String,
    pub drafter: DrafterKind,
    pub batch: usize,
    pub gamma: usize,
    pub seed: u64,
    pub policy: SchedPolicy,
    pub elastic: bool,
    pub governor: GovernorConfig,
    pub prefix: PrefixCacheConfig,
    pub paged_rows: bool,
    pub chunked_prefill: bool,
    pub adaptive_gamma: bool,
}

impl Default for TestRig {
    fn default() -> Self {
        TestRig::new()
    }
}

impl TestRig {
    pub fn new() -> Self {
        TestRig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig {
                gamma: 3,
                adaptive: false,
                ..Default::default()
            }),
            batch: 4,
            gamma: 3,
            seed: 1,
            policy: SchedPolicy::default(),
            elastic: true,
            governor: GovernorConfig::default(),
            prefix: PrefixCacheConfig::default(),
            paged_rows: true,
            // Deterministic scenarios default to the monolithic admission
            // path; the chunked-vs-monolithic differential scenarios opt in.
            chunked_prefill: false,
            // Static draft depth, matching the rig's non-adaptive drafter:
            // every deterministic scenario pins the per-class controller
            // off; the gamma differential scenarios opt in.
            adaptive_gamma: false,
        }
    }

    /// Per-class adaptive draft depth (`coordinator::gamma`): `false` (rig
    /// default) pins every draft at the configured gamma.
    pub fn adaptive_gamma(mut self, adaptive_gamma: bool) -> Self {
        self.adaptive_gamma = adaptive_gamma;
        self
    }

    pub fn verifier(mut self, v: &str) -> Self {
        self.verifier = v.into();
        self
    }

    /// Speculation depth: sets both the engine cap and the ngram drafter's
    /// depth (non-adaptive, like every deterministic scenario).
    pub fn gamma(mut self, gamma: usize) -> Self {
        self.gamma = gamma;
        if matches!(self.drafter, DrafterKind::Ngram(_)) {
            self.drafter = DrafterKind::Ngram(NgramConfig {
                gamma,
                adaptive: false,
                ..Default::default()
            });
        }
        self
    }

    pub fn drafter(mut self, d: DrafterKind) -> Self {
        self.drafter = d;
        self
    }

    /// Autoregressive baseline (no speculation).
    pub fn vanilla(mut self) -> Self {
        self.drafter = DrafterKind::Vanilla;
        self.gamma = 0;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn elastic(mut self, elastic: bool) -> Self {
        self.elastic = elastic;
        self
    }

    pub fn governor(mut self, governor: GovernorConfig) -> Self {
        self.governor = governor;
        self
    }

    pub fn prefix(mut self, prefix: PrefixCacheConfig) -> Self {
        self.prefix = prefix;
        self
    }

    /// Row backend: `true` (default) leases page-tables over the shared
    /// pool, `false` keeps the copy-based slab rows — the A/B reference
    /// the differential scenarios compare against.
    pub fn paged_rows(mut self, paged_rows: bool) -> Self {
        self.paged_rows = paged_rows;
        self
    }

    /// Admission prefill mode: `true` parks admitted rows as resumable
    /// `Prefilling` state fed in chunks riding spare decode/verify slots,
    /// `false` (rig default) keeps the monolithic suffix prefill — the A/B
    /// reference the chunked differential scenarios compare against.
    pub fn chunked_prefill(mut self, chunked_prefill: bool) -> Self {
        self.chunked_prefill = chunked_prefill;
        self
    }

    pub fn config(&self) -> EngineConfig {
        EngineConfig {
            verifier: self.verifier.clone(),
            drafter: self.drafter.clone(),
            batch: self.batch,
            gamma: self.gamma,
            seed: self.seed,
            policy: self.policy,
            elastic: self.elastic,
            governor: self.governor.clone(),
            prefix: self.prefix.clone(),
            paged_rows: self.paged_rows,
            chunked_prefill: self.chunked_prefill,
            adaptive_gamma: self.adaptive_gamma,
            replica: 0,
            replicas: 1,
            trace: false,
        }
    }

    pub fn engine(&self, mr: &Rc<ModelRuntime>) -> Engine {
        Engine::new(Rc::clone(mr), self.config()).expect("engine")
    }

    /// Submit every prompt (per-index `max_new`, greedy, no eos stop, task
    /// tag `"t"`), drain, and return the completions sorted by request id
    /// alongside the engine — for tests that assert on speculative stats,
    /// not just token streams.
    pub fn run_completions(
        &self,
        mr: &Rc<ModelRuntime>,
        prompts: &[Vec<i32>],
        max_new: &dyn Fn(usize) -> usize,
    ) -> (Vec<Completion>, Engine) {
        let mut engine = self.engine(mr);
        for (i, p) in prompts.iter().enumerate() {
            engine.submit(
                p.clone(),
                GenParams { max_new: max_new(i), stop_at_eos: false, ..GenParams::default() },
                "t",
            );
        }
        let mut done = engine.run_to_completion().expect("run to completion");
        done.sort_by_key(|c| c.id);
        (done, engine)
    }

    /// [`TestRig::run_completions`], reduced to the generated token streams.
    pub fn run_with(
        &self,
        mr: &Rc<ModelRuntime>,
        prompts: &[Vec<i32>],
        max_new: &dyn Fn(usize) -> usize,
    ) -> (Vec<Vec<i32>>, Engine) {
        let (done, engine) = self.run_completions(mr, prompts, max_new);
        (done.into_iter().map(|c| c.tokens).collect(), engine)
    }

    /// [`TestRig::run_with`] at one uniform `max_new`.
    pub fn run(
        &self,
        mr: &Rc<ModelRuntime>,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> (Vec<Vec<i32>>, Engine) {
        self.run_with(mr, prompts, &|_| max_new)
    }
}

/// Mock-chunk backend: a deterministic "transformer" over real
/// `BatchGroup` / `Tensor` movement and the real step planner, for property
/// suites that must run without PJRT artifacts.
pub mod sim {
    use std::collections::BTreeMap;

    use quasar::coordinator::{
        plan_step, BatchGroup, CallLog, CallRecord, FnKind, PlanCtx, PlanRow, VariantCtx,
    };
    use quasar::perfmodel::PerfModel;
    use quasar::prop_assert;
    use quasar::runtime::{CostModelCfg, ModelCfg, Tensor};
    use quasar::spec::{verify_draft, Draft};
    use quasar::trace::{EventKind, TraceHandle, FUNC_AUDIT, FUNC_DECODE, FUNC_PREFILL,
                        FUNC_VERIFY};
    use quasar::util::prop::ok;
    use quasar::util::rng::Pcg;

    pub const SIM_L: usize = 2;
    pub const SIM_H: usize = 2;
    pub const SIM_S: usize = 64;
    pub const SIM_HD: usize = 2;
    pub const SIM_VOCAB: usize = 4;
    pub const SIM_CHUNK: usize = 5; // verify chunk (gamma 4)

    pub fn sim_device(bf16_ops: f64, launch_s: f64) -> CostModelCfg {
        CostModelCfg {
            device: "sim".into(),
            hbm_bw_bytes_per_s: 1.6e12,
            int8_ops_per_s: 2.0 * bf16_ops,
            bf16_ops_per_s: bf16_ops,
            bytes_per_weight: BTreeMap::from([("fp32".to_string(), 2.0)]),
            kernel_launch_s: launch_s,
            drafter_cost_per_token_s: 1e-6,
        }
    }

    pub fn sim_model_cfg(d_model: usize, max_seq: usize) -> ModelCfg {
        ModelCfg {
            name: "sim".into(), vocab_size: 64, d_model, n_layers: SIM_L,
            n_heads: 8, ffn_dim: 2 * d_model, max_seq, prefill_len: 16,
            gamma_max: SIM_CHUNK - 1, head_dim: 64,
        }
    }

    /// Three pricing regimes so the planner's *choice* varies across cases
    /// while correctness must not: KV-bound (shrinks), compute-starved
    /// (splits), weight-bound (stays monolithic-shaped).
    pub fn sim_perf(sel: u64) -> PerfModel {
        match sel % 3 {
            0 => PerfModel::new(sim_device(188e12, 2e-5), sim_model_cfg(32, 4096)),
            1 => PerfModel::new(sim_device(1e12, 1e-9), sim_model_cfg(32, 4096)),
            _ => PerfModel::new(sim_device(188e12, 2e-5), sim_model_cfg(2048, 64)),
        }
    }

    pub fn tset(t: &mut Tensor<f32>, idx: &[usize], val: f32) {
        let strides = t.strides();
        let off: usize = idx.iter().zip(&strides).map(|(&i, &s)| i * s).sum();
        t.data[off] = val;
    }

    /// Deterministic row-independent "transformer chunk": writes each row's
    /// tokens into the cache at `pos..pos+chunk` (every layer/head/dim
    /// carries the token value) and emits one-hot logits whose argmax
    /// depends on the row's entire cache prefix — so a wrong row map, stale
    /// gather, or wrong position offset changes the output stream. `flip`
    /// models a *degraded quantized variant*: same KV writes, but every
    /// argmax shifted by one — zero top-1 agreement with the reference,
    /// which is what the fidelity governor must catch.
    pub fn mock_chunk(
        k: &mut Tensor<f32>,
        v: &mut Tensor<f32>,
        tokens: &[i32],
        pos: &[i32],
        bucket: usize,
        chunk: usize,
        flip: bool,
    ) -> Tensor<f32> {
        let mut logits = Tensor::<f32>::zeros(&[bucket, chunk, SIM_VOCAB]);
        for r in 0..bucket {
            let p0 = pos[r] as usize;
            for j in 0..chunk {
                let t = tokens[r * chunk + j] as f32;
                for l in 0..SIM_L {
                    for h in 0..SIM_H {
                        for d in 0..SIM_HD {
                            tset(k, &[l, r, h, p0 + j, d], t);
                            tset(v, &[l, r, h, p0 + j, d], t + 0.5);
                        }
                    }
                }
                let prefix: f32 = (0..=p0 + j).map(|p| k.at(&[0, r, 0, p, 0])).sum();
                // rem_euclid: padding rows of a dirty scratch can sum negative
                let mut next = (prefix as i64 * 31 + (p0 + j) as i64 * 7)
                    .rem_euclid(SIM_VOCAB as i64) as usize;
                if flip {
                    next = (next + 1) % SIM_VOCAB;
                }
                tset(&mut logits, &[r, j, next], 1.0);
            }
        }
        logits
    }

    pub struct SimReq {
        pub row: usize,
        pub committed: Vec<i32>,
        pub cached: usize,
    }

    /// Minimal engine over the mock chunk: monolithic mode reproduces the
    /// pre-planner step (one full-bucket call, whole-cache adopt), elastic
    /// mode runs the real plan -> gather -> execute -> scatter pipeline.
    pub struct Sim {
        pub group: BatchGroup,
        pub reqs: Vec<SimReq>,
        pub log: CallLog,
        pub perf: PerfModel,
        pub full: usize,
        pub elastic: bool,
        /// Degraded-variant mode: the mock chunk flips every argmax (see
        /// [`mock_chunk`]). Toggled per step by the governed-sim test.
        pub flip: bool,
        /// Flight-recorder tap for the elastic pipeline: disabled by default
        /// so the sim stays cost-free; the trace differential test swaps in
        /// an armed handle and asserts the committed streams don't move.
        pub trace: TraceHandle,
    }

    impl Sim {
        pub fn new(n_req: usize, full: usize, perf: PerfModel, elastic: bool) -> Sim {
            let mut group = BatchGroup::new(SIM_L, full, SIM_H, SIM_S, SIM_HD);
            let mut reqs = Vec::new();
            for i in 0..n_req {
                let prompt_tok = (i % SIM_VOCAB) as i32;
                let mut k1 = Tensor::<f32>::zeros(&[SIM_L, 1, SIM_H, SIM_S, SIM_HD]);
                let mut v1 = k1.clone();
                for l in 0..SIM_L {
                    for h in 0..SIM_H {
                        for d in 0..SIM_HD {
                            tset(&mut k1, &[l, 0, h, 0, d], prompt_tok as f32);
                            tset(&mut v1, &[l, 0, h, 0, d], prompt_tok as f32 + 0.5);
                        }
                    }
                }
                // length-bounded lease: only position 0 holds committed KV
                let row = group.join_prefix(i, &k1, &v1, 1).unwrap();
                reqs.push(SimReq { row, committed: vec![prompt_tok], cached: 1 });
            }
            Sim {
                group,
                reqs,
                log: CallLog::default(),
                perf,
                full,
                elastic,
                flip: false,
                trace: TraceHandle::disabled(),
            }
        }

        fn commit(req: &mut SimReq, draft: &[i32], logits: &Tensor<f32>, lrow: usize) {
            let d = Draft::point_mass(draft.to_vec());
            let out = verify_draft(&d, |j| logits.row(&[lrow, j]), 0.0, &mut Pcg::seeded(0));
            let mut commit: Vec<i32> = d.tokens[..out.accepted].to_vec();
            commit.push(out.next_token);
            req.cached += commit.len();
            req.committed.extend_from_slice(&commit);
        }

        fn record(&mut self, fn_kind: FnKind, bucket: usize, chunk: usize, rows: usize,
                  tokens_used: usize, useful: usize) {
            self.log.record(CallRecord {
                variant: "fp32".into(),
                fn_kind,
                batch: bucket,
                n_layers: SIM_L,
                active_rows: rows,
                tokens_used,
                chunk_len: chunk,
                useful_tokens: useful,
                wall_s: 0.0,
            });
        }

        pub fn step(&mut self, drafts: &[Vec<i32>]) {
            assert_eq!(drafts.len(), self.reqs.len());
            if self.elastic {
                self.step_elastic(drafts)
            } else {
                self.step_mono(drafts)
            }
        }

        /// Seed-engine shape: one call at the configured bucket, token
        /// block indexed by group row, whole-cache adopt.
        fn step_mono(&mut self, drafts: &[Vec<i32>]) {
            let any = drafts.iter().any(|d| !d.is_empty());
            let (fn_kind, chunk) =
                if any { (FnKind::Verify, SIM_CHUNK) } else { (FnKind::Decode, 1) };
            let b = self.full;
            let mut tokens = vec![0i32; b * chunk];
            let mut pos = vec![0i32; b];
            for (req, draft) in self.reqs.iter().zip(drafts) {
                tokens[req.row * chunk] = *req.committed.last().unwrap();
                for (j, &t) in draft.iter().enumerate().take(chunk - 1) {
                    tokens[req.row * chunk + 1 + j] = t;
                }
                pos[req.row] = req.cached as i32;
            }
            let mut k = self.group.k.clone();
            let mut v = self.group.v.clone();
            let logits = mock_chunk(&mut k, &mut v, &tokens, &pos, b, chunk, self.flip);
            self.group.k = k; // whole-cache adopt, garbage rows included
            self.group.v = v;
            // The adopt dirtied every row up to its chunk extent — leased
            // rows from their cached position, padding rows from zero.
            for r in 0..b {
                let wrote = self
                    .reqs
                    .iter()
                    .find(|req| req.row == r)
                    .map(|req| req.cached + chunk)
                    .unwrap_or(chunk);
                self.group.note_written(r, wrote.min(SIM_S));
            }
            let used = drafts.iter().map(|d| d.len() + 1).max().unwrap_or(1);
            let useful: usize = drafts.iter().map(|d| d.len() + 1).sum();
            self.record(fn_kind, b, chunk, self.reqs.len(), used, useful);
            for (i, draft) in drafts.iter().enumerate() {
                let lrow = self.reqs[i].row;
                Self::commit(&mut self.reqs[i], draft, &logits, lrow);
            }
        }

        /// The refactored shape: plan, then gather/execute/scatter per
        /// sub-batch against dirty scratch caches.
        fn step_elastic(&mut self, drafts: &[Vec<i32>]) {
            let rows: Vec<PlanRow> =
                drafts.iter().map(|d| PlanRow::new(d.len(), 0)).collect();
            let buckets = [1usize, 2, 4];
            let plan = {
                let variants = [VariantCtx {
                    name: "fp32",
                    verify_buckets: &buckets,
                    decode_buckets: &buckets,
                }];
                let ctx = PlanCtx {
                    perf: &self.perf,
                    variants: &variants,
                    n_layers: SIM_L,
                    full_bucket: self.full,
                    verify_chunk: SIM_CHUNK,
                    elastic: true,
                };
                plan_step(&ctx, &rows).unwrap()
            };
            assert!(plan.modeled_s <= plan.monolithic_s + 1e-15);
            self.trace.record(
                0,
                EventKind::Plan { subbatches: plan.sub_batches.len() as u32 },
            );
            for sb in &plan.sub_batches {
                let (bucket, chunk) = (sb.bucket, sb.chunk);
                let row_lens: Vec<(usize, usize)> = sb
                    .rows
                    .iter()
                    .map(|&di| (self.reqs[di].row, self.reqs[di].cached))
                    .collect();
                // dirty pooled scratch: the chunk reads only each row's
                // gathered committed prefix plus the positions it writes
                let mut sk = Tensor::<f32>::zeros(&[SIM_L, bucket, SIM_H, SIM_S, SIM_HD]);
                sk.data.iter_mut().for_each(|x| *x = -7.0);
                let mut sv = sk.clone();
                self.group.gather_rows(&row_lens, &mut sk, &mut sv).unwrap();
                let mut tokens = vec![0i32; bucket * chunk];
                let mut pos = vec![0i32; bucket];
                for (i, &di) in sb.rows.iter().enumerate() {
                    let req = &self.reqs[di];
                    tokens[i * chunk] = *req.committed.last().unwrap();
                    for (j, &t) in drafts[di].iter().enumerate().take(chunk - 1) {
                        tokens[i * chunk + 1 + j] = t;
                    }
                    pos[i] = req.cached as i32;
                }
                let logits =
                    mock_chunk(&mut sk, &mut sv, &tokens, &pos, bucket, chunk, self.flip);
                let write_back: Vec<(usize, usize)> = row_lens
                    .iter()
                    .map(|&(r, cached)| (r, (cached + chunk).min(SIM_S)))
                    .collect();
                self.group.scatter_rows(&write_back, &sk, &sv).unwrap();
                self.record(sb.fn_kind, bucket, chunk, sb.rows.len(), sb.tokens_used,
                            sb.useful_tokens);
                self.trace.record(
                    0,
                    EventKind::ChunkExec {
                        variant: self.trace.intern("fp32"),
                        func: match sb.fn_kind {
                            FnKind::Decode => FUNC_DECODE,
                            FnKind::Verify => FUNC_VERIFY,
                            FnKind::Prefill => FUNC_PREFILL,
                            FnKind::Audit => FUNC_AUDIT,
                        },
                        bucket: bucket as u16,
                        wall_us: 0,
                    },
                );
                for (i, &di) in sb.rows.iter().enumerate() {
                    let before = self.reqs[di].committed.len();
                    Self::commit(&mut self.reqs[di], &drafts[di], &logits, i);
                    // commit() appends `accepted + 1` tokens (the bonus/next
                    // token rides along), so recover the acceptance count.
                    let accepted = self.reqs[di].committed.len() - before - 1;
                    self.trace.record(
                        di as u64,
                        EventKind::Commit { accepted: accepted as u32 },
                    );
                }
            }
        }
    }

    /// Drive monolithic and elastic sims with identical drafts; compare
    /// streams and the committed cache prefix of every leased row.
    pub fn run_equivalence(n_req: usize, perf_sel: u64, seed: u64,
                           steps: usize) -> (Sim, Sim) {
        let full = 4usize;
        let mut mono = Sim::new(n_req, full, sim_perf(perf_sel), false);
        let mut ela = Sim::new(n_req, full, sim_perf(perf_sel), true);
        let mut rng = Pcg::seeded(seed ^ 0xE1A5);
        for _ in 0..steps {
            let drafts: Vec<Vec<i32>> = (0..n_req)
                .map(|_| {
                    let len = rng.usize_below(SIM_CHUNK);
                    (0..len).map(|_| rng.below(SIM_VOCAB as u64) as i32).collect()
                })
                .collect();
            mono.step(&drafts);
            ela.step(&drafts);
        }
        (mono, ela)
    }

    pub fn check_equivalent(mono: &Sim, ela: &Sim) -> Result<(), String> {
        for (i, (m, e)) in mono.reqs.iter().zip(&ela.reqs).enumerate() {
            prop_assert!(
                m.committed == e.committed,
                "req {i} streams diverged:\n  mono {:?}\n  ela  {:?}",
                m.committed, e.committed
            );
            prop_assert!(m.cached == e.cached, "req {i} cached diverged");
            // committed KV prefix must be bit-identical (positions beyond
            // `cached` hold unread speculative leftovers and may differ)
            for l in 0..SIM_L {
                for h in 0..SIM_H {
                    for p in 0..m.cached {
                        for d in 0..SIM_HD {
                            let a = mono.group.k.at(&[l, m.row, h, p, d]);
                            let b = ela.group.k.at(&[l, e.row, h, p, d]);
                            prop_assert!(a == b, "req {i} kv prefix diverged at {l}/{h}/{p}/{d}");
                            let a = mono.group.v.at(&[l, m.row, h, p, d]);
                            let b = ela.group.v.at(&[l, e.row, h, p, d]);
                            prop_assert!(a == b, "req {i} v prefix diverged at {l}/{h}/{p}/{d}");
                        }
                    }
                }
            }
        }
        ok()
    }
}

//! Roofline cost model of the simulated Ascend-910B2-class accelerator
//! (paper §3.4, Eqs. 11–13; DESIGN.md §1 substitution row 2).
//!
//! Measured quantities — acceptance lengths, call counts, per-call token
//! usage — come from *real* engine runs on real numerics; this module prices
//! each call on the target device, where the paper's bandwidth arithmetic
//! lives:
//!
//!   T_verify^BF16 ~ M·2B / BW + T_compute      (Eq. 11)
//!   T_verify^INT8 ~ M·1B / BW + T_compute      (Eq. 12)
//!   S = (gamma·alpha + 1) / (T_draft + T_verify)   (Eq. 13)
//!
//! We use the roofline refinement `max(T_mem, T_compute) + T_launch` rather
//! than the paper's additive approximation; in the memory-bound regime the
//! two coincide (attention/linear compute hides entirely under the weight
//! stream), and the max() form correctly caps the compute-bound end of the
//! Table-3 gamma sweep.

use crate::coordinator::{CallLog, CallRecord, FnKind};
use crate::runtime::{CostModelCfg, ModelCfg};
use crate::spec::drafter::DraftCost;

/// Priced breakdown of one call (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CallTime {
    pub weight_s: f64,
    pub kv_s: f64,
    pub act_s: f64,
    pub compute_s: f64,
    pub launch_s: f64,
}

impl CallTime {
    /// Roofline total: memory and compute overlap; launch does not.
    pub fn total(&self) -> f64 {
        (self.weight_s + self.kv_s + self.act_s).max(self.compute_s) + self.launch_s
    }

    /// The paper's additive form (Eq. 11/12), for the Fig-1 comparison.
    pub fn additive(&self) -> f64 {
        self.weight_s + self.kv_s + self.act_s + self.compute_s + self.launch_s
    }
}

/// Device + model pricing context.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub device: CostModelCfg,
    pub model: ModelCfg,
}

impl PerfModel {
    pub fn new(device: CostModelCfg, model: ModelCfg) -> Self {
        PerfModel { device, model }
    }

    fn bytes_per_weight(&self, variant: &str) -> f64 {
        self.device
            .bytes_per_weight
            .get(variant)
            .copied()
            .unwrap_or(2.0)
    }

    /// Parameters resident for a depth-`n_layers` variant of the model.
    pub fn variant_params(&self, n_layers: usize) -> f64 {
        let (d, f) = (self.model.d_model as f64, self.model.ffn_dim as f64);
        let per_layer = 4.0 * d * d + 3.0 * d * f + 2.0 * d;
        self.model.vocab_size as f64 * d + n_layers as f64 * per_layer + d
    }

    /// Price one engine call on the simulated device.
    pub fn price(&self, rec: &CallRecord) -> CallTime {
        self.price_parts(&rec.variant, rec.n_layers, rec.batch, rec.tokens_used)
    }

    /// Price a (variant, depth, batch, chunk-tokens) invocation.
    pub fn price_parts(&self, variant: &str, n_layers: usize, batch: usize,
                       tokens: usize) -> CallTime {
        let m = &self.model;
        let (d, f, h, s, hd, v) = (
            m.d_model as f64, m.ffn_dim as f64, m.n_heads as f64,
            m.max_seq as f64, m.head_dim as f64, m.vocab_size as f64,
        );
        let bw = self.device.hbm_bw_bytes_per_s;
        let tok = (batch * tokens) as f64;
        let l = n_layers as f64;

        // Weights stream once per forward pass regardless of batch/chunk —
        // the whole point of parallel verification (and of W8A8 halving it).
        let weight_bytes = self.variant_params(n_layers) * self.bytes_per_weight(variant);
        // KV cache reads: "BF16" cache, both K and V, all resident positions.
        let kv_bytes = 2.0 * l * batch as f64 * h * s * hd * 2.0;
        // Activations in/out of each sublayer (bf16).
        let act_bytes = tok * d * 2.0 * (8.0 * l + 2.0);

        // MACs: quantized variants run the linear GEMMs on the int8 path;
        // attention and the (kept-high-precision) unembedding stay bf16.
        let linear_macs = tok * l * (4.0 * d * d + 3.0 * d * f);
        let attn_macs = batch as f64 * l * h * tokens as f64 * s * hd * 2.0;
        let unembed_macs = tok * d * v;
        let (lin_ops, other_ops) = (linear_macs * 2.0, (attn_macs + unembed_macs) * 2.0);
        let lin_rate = if variant == "w8a8" {
            self.device.int8_ops_per_s
        } else {
            self.device.bf16_ops_per_s
        };
        CallTime {
            weight_s: weight_bytes / bw,
            kv_s: kv_bytes / bw,
            act_s: act_bytes / bw,
            compute_s: lin_ops / lin_rate + other_ops / self.device.bf16_ops_per_s,
            launch_s: self.device.kernel_launch_s,
        }
    }

    /// Price a candidate execution plan: one `(bucket, tokens_used)` pair
    /// per sub-batch, all at the same variant/depth. This is what the
    /// engine's elastic step planner minimizes — each extra sub-batch pays a
    /// fresh weight stream and launch, each larger bucket pays more KV and
    /// activation traffic (Eq. 11/12's `M·bytes/BW` term scales with the
    /// bucket actually executed, not the configured one).
    pub fn plan_cost(&self, variant: &str, n_layers: usize,
                     sub_batches: &[(usize, usize)]) -> f64 {
        sub_batches
            .iter()
            .map(|&(bucket, tokens)| {
                self.price_parts(variant, n_layers, bucket, tokens).total()
            })
            .sum()
    }

    /// Price the drafter's own work. N-gram lookups are host-side and cost
    /// `drafter_cost_per_token_s`; pruned-model drafting is priced as real
    /// forward passes of the *drafter's own artifact variant* at the
    /// drafter's depth — `drafter` is `(variant, n_layers)`, e.g.
    /// `("pruned75", 4)`, so a pruned variant with its own
    /// `bytes_per_weight` entry is no longer silently priced as fp32.
    pub fn price_draft_cost(&self, c: &DraftCost, drafter: Option<(&str, usize)>) -> f64 {
        let mut t = c.lookup_tokens as f64 * self.device.drafter_cost_per_token_s;
        if let Some((variant, nl)) = drafter {
            t += c.prefill_calls as f64
                * self.price_parts(variant, nl, 1, self.model.prefill_len).total();
            t += c.decode_calls as f64 * self.price_parts(variant, nl, 1, 1).total();
        }
        t
    }

    /// Modeled wall-clock of a whole run. `drafter` prices pruned-model
    /// drafting: `(artifact variant, depth)`, `None` for host-side drafters.
    pub fn run_time(&self, log: &CallLog, drafter: Option<(&str, usize)>) -> f64 {
        let calls: f64 = log.records.iter().map(|r| self.price(r).total()).sum();
        calls + self.price_draft_cost(&log.draft_cost, drafter)
    }

    /// Modeled admission (prefill-phase) seconds of a run — the traffic the
    /// prefix cache attacks. On a cache hit the recorded prefill call
    /// carries only the executed *suffix* tokens, so a warm run prices
    /// strictly below the same workload served cold.
    pub fn prefill_time(&self, log: &CallLog) -> f64 {
        log.records
            .iter()
            .filter(|r| r.fn_kind == FnKind::Prefill)
            .map(|r| self.price(r).total())
            .sum()
    }

    /// Modeled prefill seconds one prefix-cache hit saves: the full-prompt
    /// chunk price minus the suffix-only price actually paid. Weight and
    /// KV streams are per-call and cancel; the saving is the per-token
    /// activation traffic and compute of the skipped positions — strictly
    /// positive whenever the suffix is shorter than the prompt. The splice
    /// that realizes the hit is priced separately ([`PerfModel::splice_time`])
    /// so the engine can report the *net* saving.
    pub fn prefill_saved_s(&self, variant: &str, n_layers: usize,
                           prompt_tokens: usize, suffix_tokens: usize) -> f64 {
        (self.price_parts(variant, n_layers, 1, prompt_tokens).total()
            - self.price_parts(variant, n_layers, 1, suffix_tokens).total())
            .max(0.0)
    }

    /// Modeled seconds of dedicated-prefill stall one riding chunk avoids:
    /// the single-row call that would otherwise have run `take` suffix
    /// tokens as its own step-serializing prefill pass. When the chunk
    /// instead fills a spare slot of an already-planned decode/verify
    /// sub-batch, that sub-batch's bucket and chunk shape are unchanged
    /// (the rider obeys `take <= sb.chunk` and occupies a row the bucket
    /// already paid KV traffic for), so the whole dedicated call is the
    /// saving — booked to the `prefill_stall_saved_s` metric.
    pub fn prefill_stall_saved_s(&self, variant: &str, n_layers: usize,
                                 take: usize) -> f64 {
        if take == 0 {
            return 0.0;
        }
        self.price_parts(variant, n_layers, 1, take).total()
    }

    /// Bytes of one resident KV page *pair* (k + v, f32) holding
    /// `page_tokens` sequence positions at the given depth — the paged
    /// prefix cache's allocation unit: a cached prefix of `len` tokens
    /// pins `ceil(len/page_tokens)` of these, where the old segment store
    /// pinned a whole `max_seq` row.
    pub fn page_pair_bytes(&self, n_layers: usize, page_tokens: usize) -> f64 {
        2.0 * n_layers as f64 * self.model.n_heads as f64 * page_tokens as f64
            * self.model.head_dim as f64 * 4.0
    }

    /// Modeled seconds admission spends splicing a cached `tokens`-token
    /// prefix out of the paged store: `ceil(tokens/page_tokens)` pages each
    /// move through HBM once on the read side and once on the write side.
    /// Priced *per page, not per row* — a short shared prefix no longer
    /// pays a `max_seq`-row copy (set `page_tokens = max_seq` to recover
    /// the old whole-row splice price).
    pub fn splice_time(&self, n_layers: usize, tokens: usize, page_tokens: usize) -> f64 {
        if tokens == 0 || page_tokens == 0 {
            return 0.0;
        }
        let pages = tokens.div_ceil(page_tokens);
        2.0 * pages as f64 * self.page_pair_bytes(n_layers, page_tokens)
            / self.device.hbm_bw_bytes_per_s
    }

    /// Modeled seconds a bulk move of `pages` KV pages costs through HBM
    /// (read + write). The page-table row backend books this as
    /// `kv_copy_saved_s` wherever it *references* pages the slab backend
    /// would have copied: admission splice of shared pages, the committed
    /// prefix a delta-only scatter skips re-writing, and finish-time
    /// snapshots that refcount row pages instead of duplicating them.
    pub fn kv_move_time(&self, n_layers: usize, pages: usize, page_tokens: usize) -> f64 {
        2.0 * pages as f64 * self.page_pair_bytes(n_layers, page_tokens)
            / self.device.hbm_bw_bytes_per_s
    }

    /// Modeled decode-phase time only (prefill excluded): matches how the
    /// paper reports decoding speedup (prefill is identical across methods).
    /// Governor shadow audits *are* included — they are real decode-phase
    /// traffic the adaptive-precision policy pays for its safety net.
    pub fn decode_time(&self, log: &CallLog, drafter: Option<(&str, usize)>) -> f64 {
        let calls: f64 = log
            .records
            .iter()
            .filter(|r| r.fn_kind != FnKind::Prefill)
            .map(|r| self.price(r).total())
            .sum();
        calls + self.price_draft_cost(&log.draft_cost, drafter)
    }

    /// Modeled seconds spent on fidelity-governor shadow calls only (the
    /// audit overhead inside [`PerfModel::decode_time`]). Each audit is
    /// priced like any chunk call at the shadow variant's weight stream and
    /// the audited sub-batch's (bucket, tokens) shape.
    pub fn audit_time(&self, log: &CallLog) -> f64 {
        log.records
            .iter()
            .filter(|r| r.fn_kind == FnKind::Audit)
            .map(|r| self.price(r).total())
            .sum()
    }

    /// Eq. 13 closed form: speedup of speculation with acceptance rate
    /// `alpha`, depth `gamma`, per-step draft cost `t_draft`, against
    /// vanilla decoding at `t_decode` per token.
    pub fn eq13_speedup(&self, variant: &str, gamma: usize, alpha: f64,
                        t_draft: f64) -> f64 {
        let l = self.model.n_layers;
        let t_dec_bf16 = self.price_parts("fp32", l, 1, 1).total();
        let t_verify = self.price_parts(variant, l, 1, gamma + 1).total();
        let tokens_per_step = gamma as f64 * alpha + 1.0;
        (tokens_per_step / (t_draft + t_verify)) * t_dec_bf16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn device() -> CostModelCfg {
        CostModelCfg {
            device: "sim".into(),
            hbm_bw_bytes_per_s: 1.6e12,
            int8_ops_per_s: 376e12,
            bf16_ops_per_s: 188e12,
            bytes_per_weight: BTreeMap::from([
                ("fp32".to_string(), 2.0),
                ("w8a8".to_string(), 1.0),
                // quantized pruned drafter: its own (smaller) weight stream
                ("pruned75".to_string(), 1.0),
            ]),
            kernel_launch_s: 2e-5,
            drafter_cost_per_token_s: 1e-6,
        }
    }

    fn model() -> ModelCfg {
        ModelCfg {
            name: "m".into(), vocab_size: 320, d_model: 256, n_layers: 6,
            n_heads: 8, ffn_dim: 768, max_seq: 256, prefill_len: 128,
            gamma_max: 10, head_dim: 32,
        }
    }

    fn pm() -> PerfModel {
        PerfModel::new(device(), model())
    }

    #[test]
    fn w8a8_halves_weight_time_exactly() {
        let pm = pm();
        let a = pm.price_parts("fp32", 6, 1, 9);
        let b = pm.price_parts("w8a8", 6, 1, 9);
        assert!((a.weight_s / b.weight_s - 2.0).abs() < 1e-12);
        assert_eq!(a.kv_s, b.kv_s);
        assert!(b.compute_s < a.compute_s, "int8 compute is faster");
        assert!(b.total() < a.total());
    }

    #[test]
    fn decode_is_memory_bound_verify_gets_cheaper_per_token() {
        let pm = pm();
        let dec = pm.price_parts("fp32", 6, 1, 1);
        assert!(
            dec.weight_s + dec.kv_s + dec.act_s > dec.compute_s,
            "single-token decode must be memory-bound on this device"
        );
        // Verification amortizes the weight stream over gamma+1 tokens.
        let ver = pm.price_parts("fp32", 6, 1, 9);
        let per_tok_dec = dec.total();
        let per_tok_ver = ver.total() / 9.0;
        assert!(per_tok_ver < per_tok_dec * 0.5);
    }

    #[test]
    fn pruned_depth_scales_weight_bytes() {
        let pm = pm();
        let full = pm.price_parts("fp32", 6, 1, 1);
        let half = pm.price_parts("fp32", 3, 1, 1);
        assert!(half.weight_s < full.weight_s);
        assert!(half.weight_s > full.weight_s * 0.4, "embedding is shared");
    }

    #[test]
    fn eq13_monotone_in_alpha_and_beats_one_for_good_drafts() {
        let pm = pm();
        let s_low = pm.eq13_speedup("fp32", 5, 0.1, 5e-6);
        let s_high = pm.eq13_speedup("fp32", 5, 0.9, 5e-6);
        assert!(s_high > s_low);
        assert!(s_high > 1.5, "gamma=5 alpha=0.9 should speed up, got {s_high}");
        let s_quasar = pm.eq13_speedup("w8a8", 5, 0.9, 5e-6);
        assert!(s_quasar > s_high, "quasar verify is cheaper");
    }

    #[test]
    fn smaller_bucket_cuts_kv_traffic_and_plan_cost_prices_sub_batches() {
        let pm = pm();
        let b4 = pm.price_parts("fp32", 6, 4, 6);
        let b1 = pm.price_parts("fp32", 6, 1, 6);
        assert!((b4.kv_s / b1.kv_s - 4.0).abs() < 1e-9, "kv bytes scale with bucket");
        assert_eq!(b4.weight_s, b1.weight_s, "weights stream once regardless");
        assert!(b1.total() < b4.total());
        // plan_cost is the simple sum of its sub-batch call prices
        let split = pm.plan_cost("fp32", 6, &[(1, 6), (1, 1)]);
        let expect = pm.price_parts("fp32", 6, 1, 6).total()
            + pm.price_parts("fp32", 6, 1, 1).total();
        assert!((split - expect).abs() < 1e-15);
        // occupancy-1 shrink: one b1 verify call beats the monolithic b4 one
        assert!(pm.plan_cost("fp32", 6, &[(1, 6)]) < pm.plan_cost("fp32", 6, &[(4, 6)]));
        // ...while splitting always pays an extra weight stream + launch
        assert!(split > pm.plan_cost("fp32", 6, &[(1, 6)]));
    }

    #[test]
    fn run_time_sums_calls_and_draft_cost() {
        let pm = pm();
        let mut log = CallLog::default();
        log.record(CallRecord {
            variant: "fp32".into(), fn_kind: FnKind::Prefill, batch: 1,
            n_layers: 6, active_rows: 1, tokens_used: 100, chunk_len: 128,
            useful_tokens: 100, wall_s: 0.0,
        });
        log.record(CallRecord {
            variant: "fp32".into(), fn_kind: FnKind::Decode, batch: 1,
            n_layers: 6, active_rows: 1, tokens_used: 1, chunk_len: 1,
            useful_tokens: 1, wall_s: 0.0,
        });
        log.add_draft_cost(&DraftCost { lookup_tokens: 100, ..Default::default() });
        let total = pm.run_time(&log, None);
        let decode_only = pm.decode_time(&log, None);
        assert!(total > decode_only);
        let with_pruned = pm.run_time(
            &CallLog {
                draft_cost: DraftCost { decode_calls: 10, ..Default::default() },
                ..Default::default()
            },
            Some(("fp32", 3)),
        );
        assert!(with_pruned > 0.0);
    }

    #[test]
    fn draft_cost_prices_the_drafter_variant_not_fp32() {
        // Regression: `price_draft_cost` used to hardcode "fp32" for
        // pruned-model drafting, ignoring the drafter's own
        // `bytes_per_weight`. With pruned75 at 1 byte/weight the same call
        // counts must now price strictly below the fp32-priced equivalent.
        let pm = pm();
        let c = DraftCost { prefill_calls: 1, decode_calls: 20, ..Default::default() };
        let as_pruned = pm.price_draft_cost(&c, Some(("pruned75", 4)));
        let as_fp32 = pm.price_draft_cost(&c, Some(("fp32", 4)));
        assert!(
            as_pruned < as_fp32,
            "pruned75 (1 B/weight) priced {as_pruned} !< fp32 {as_fp32}"
        );
        // and the gap is exactly the per-call price difference
        let per_call = pm.price_parts("pruned75", 4, 1, 1).total();
        let per_call_fp32 = pm.price_parts("fp32", 4, 1, 1).total();
        assert!(per_call < per_call_fp32);
    }

    #[test]
    fn prefill_time_isolates_admission_and_prefix_hits_price_lower() {
        let pm = pm();
        let prefill = |tokens: usize| CallRecord {
            variant: "fp32".into(), fn_kind: FnKind::Prefill, batch: 1,
            n_layers: 6, active_rows: 1, tokens_used: tokens, chunk_len: 128,
            useful_tokens: tokens, wall_s: 0.0,
        };
        let mut cold = CallLog::default();
        cold.record(prefill(100));
        let mut warm = CallLog::default();
        warm.record(prefill(20)); // 80-token prefix served from cache
        let (t_cold, t_warm) = (pm.prefill_time(&cold), pm.prefill_time(&warm));
        assert!(t_warm < t_cold, "suffix-only prefill must price lower");
        // prefill_time + decode_time partition run_time
        let mut mixed = CallLog::default();
        mixed.record(prefill(100));
        mixed.record(CallRecord {
            fn_kind: FnKind::Decode, tokens_used: 1, chunk_len: 1,
            useful_tokens: 1, ..prefill(100)
        });
        let whole = pm.run_time(&mixed, None);
        assert!(
            (whole - pm.prefill_time(&mixed) - pm.decode_time(&mixed, None)).abs() < 1e-15
        );
        // prefill_saved_s is exactly the cold/warm gap for the same shapes
        let saved = pm.prefill_saved_s("fp32", 6, 100, 20);
        assert!((saved - (t_cold - t_warm)).abs() < 1e-15);
        assert!(saved > 0.0);
        assert_eq!(pm.prefill_saved_s("fp32", 6, 50, 50), 0.0, "no hit, no saving");
    }

    #[test]
    fn prefill_stall_saving_is_the_dedicated_call_price() {
        let pm = pm();
        // A riding chunk saves exactly the b1 call it would have run as a
        // dedicated pass — and a w8a8 chunk saves less than an fp32 one
        // (half the weight stream was going to stall the step).
        let saved = pm.prefill_stall_saved_s("fp32", 6, 16);
        assert!((saved - pm.price_parts("fp32", 6, 1, 16).total()).abs() < 1e-18);
        assert!(saved > 0.0);
        assert!(pm.prefill_stall_saved_s("w8a8", 6, 16) < saved);
        assert_eq!(pm.prefill_stall_saved_s("fp32", 6, 0), 0.0);
    }

    #[test]
    fn splice_is_priced_per_page_not_per_row() {
        let pm = pm();
        let (l, p) = (6usize, 16usize);
        // One page moves 2 * page_pair_bytes through HBM.
        let one = pm.splice_time(l, p, p);
        assert!((one - 2.0 * pm.page_pair_bytes(l, p) / 1.6e12).abs() < 1e-18);
        // Cost scales with page count (ceil), not with max_seq.
        assert!((pm.splice_time(l, 3 * p, p) / one - 3.0).abs() < 1e-9);
        assert!((pm.splice_time(l, 2 * p + 1, p) / one - 3.0).abs() < 1e-9, "ceil");
        // A short prefix priced per page undercuts the whole-row splice the
        // segment store paid (page_tokens = max_seq recovers that price).
        let max_seq = pm.model.max_seq;
        let row = pm.splice_time(l, p, max_seq);
        assert!(one < row, "per-page {one} not below per-row {row}");
        assert_eq!(pm.splice_time(l, 0, p), 0.0);
    }

    #[test]
    fn kv_move_time_prices_bulk_page_moves_linearly() {
        let pm = pm();
        let (l, p) = (6usize, 16usize);
        // n already-paged pages cost exactly the n-page splice: referencing
        // instead of moving them saves the full per-page HBM price.
        let one = pm.kv_move_time(l, 1, p);
        assert!((one - 2.0 * pm.page_pair_bytes(l, p) / 1.6e12).abs() < 1e-18);
        assert!((pm.kv_move_time(l, 5, p) / one - 5.0).abs() < 1e-9, "linear in pages");
        assert!((pm.kv_move_time(l, 3, p) - pm.splice_time(l, 3 * p, p)).abs() < 1e-18);
        assert_eq!(pm.kv_move_time(l, 0, p), 0.0);
    }

    #[test]
    fn audit_calls_are_priced_into_decode_time_and_isolated_by_audit_time() {
        let pm = pm();
        let verify = CallRecord {
            variant: "w8a8".into(), fn_kind: FnKind::Verify, batch: 1,
            n_layers: 6, active_rows: 1, tokens_used: 6, chunk_len: 9,
            useful_tokens: 6, wall_s: 0.0,
        };
        let audit = CallRecord {
            variant: "fp32".into(), fn_kind: FnKind::Audit, ..verify.clone()
        };
        let mut bare = CallLog::default();
        bare.record(verify.clone());
        let mut audited = CallLog::default();
        audited.record(verify);
        audited.record(audit.clone());
        let (t_bare, t_audited) = (pm.decode_time(&bare, None), pm.decode_time(&audited, None));
        assert!(t_audited > t_bare, "audit traffic must show up in decode time");
        let overhead = pm.audit_time(&audited);
        assert!((t_audited - t_bare - overhead).abs() < 1e-15);
        // the shadow runs the reference weights: priced as fp32, i.e. the
        // audit costs *more* than the w8a8 call it shadows
        assert!(overhead > t_bare);
        assert_eq!(pm.audit_time(&bare), 0.0);
    }
}

//! Minimal JSON parser/emitter.
//!
//! The offline image vendors only the `xla` crate's dependency tree, so
//! `serde`/`serde_json` are unavailable (DESIGN.md §1). This module covers
//! what the engine needs: parsing the artifact manifest, tokenizer, workload
//! and eval-set files emitted by `python/compile/aot.py`, and emitting
//! metrics / server responses.
//!
//! Full RFC 8259 value model (null/bool/number/string/array/object), UTF-8
//! input, `\uXXXX` escapes including surrogate pairs. Numbers are stored as
//! `f64` (the manifest never needs 64-bit integer precision beyond 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error with a short context description.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors (used pervasively when walking the manifest)
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || f.abs() > 2f64.powi(53) {
            return Err(JsonError(format!("expected integer, got {f}")));
        }
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| JsonError(format!("negative index {i}")))
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError(format!("expected object, got {other:?}"))),
        }
    }

    /// Field access with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError(format!("missing field '{key}'")))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Result<&Json> {
        let arr = self.as_arr()?;
        arr.get(i)
            .ok_or_else(|| JsonError(format!("index {i} out of {}", arr.len())))
    }

    /// Convenience: array of i64.
    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Convenience: array of i32 (token ids, shapes).
    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------------------
    // Builders (server responses, metrics dumps)
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
}

// ---------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after document"));
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| JsonError(format!("read {}: {e}", path.display())))?;
    parse(&text).map_err(|e| JsonError(format!("{}: {}", path.display(), e.0)))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid keyword (expected {kw})")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + (((hi - 0xD800) as u32) << 10)
                                + (lo - 0xDC00) as u32
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            hi as u32
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        let mut v = 0u16;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": -0.25}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -0.25);
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_i64().unwrap(), 1);
        assert_eq!(
            *v.get("a").unwrap().idx(1).unwrap().get("b").unwrap(),
            Json::Null
        );
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \u{e9} \u{1F600}");
        // raw multibyte utf-8 passthrough
        let v = parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "caf\u{e9}");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "01x", "\"\\q\"",
            "\"unterminated", "[1] extra", "{\"a\":1,}", "\"\\ud800\"",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"x"},"d":null,"e":true,"f":-1.5}"#,
            r#"[[],{},"",0]"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let emitted = v.to_string();
            assert_eq!(parse(&emitted).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn accessor_errors_are_descriptive() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let e = v.get("missing").unwrap_err();
        assert!(e.0.contains("missing"), "{e}");
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(Json::Num(1.5).as_i64().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn builders_emit_sorted_objects() {
        let v = Json::obj(vec![
            ("z", Json::num(1.0)),
            ("a", Json::str("s")),
        ]);
        assert_eq!(v.to_string(), r#"{"a":"s","z":1}"#);
    }
}

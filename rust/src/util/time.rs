//! Timing helpers: stopwatch, scoped timers and a tiny statistics type used
//! by the bench harness (criterion is not vendored offline).

use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Sample statistics over repeated timings.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    pub values: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn n(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn median(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = v.len() / 2;
        if v.len() % 2 == 0 {
            (v[mid - 1] + v[mid]) / 2.0
        } else {
            v[mid]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap = sw.lap_s();
        assert!(lap >= 0.004, "{lap}");
        assert!(sw.elapsed_s() < lap, "reset after lap");
    }

    #[test]
    fn samples_stats() {
        let mut s = Samples::default();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, t) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}

//! Property-testing micro-framework (proptest is not vendored offline).
//!
//! Usage:
//! ```ignore
//! prop_check("batcher never drops requests", 500, |rng| gen_case(rng),
//!            |case| { ...; ok() })
//! ```
//! On failure the framework greedily shrinks the case via [`Shrink`] before
//! panicking with the minimal reproducer's `Debug` form and the seed, so a
//! failing run is replayable with `QUASAR_PROP_SEED`.

use super::rng::Pcg;

/// Property outcome: `Ok(())` or a failure description.
pub type PropResult = Result<(), String>;

pub fn ok() -> PropResult {
    Ok(())
}

pub fn fail(msg: impl Into<String>) -> PropResult {
    Err(msg.into())
}

/// Ensure with message formatting.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Types that can propose strictly-smaller candidate values of themselves.
pub trait Shrink: Sized {
    fn shrink_candidates(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for usize {
    fn shrink_candidates(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for i32 {
    fn shrink_candidates(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - self.signum()]
        }
    }
}

impl Shrink for i64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - self.signum()]
        }
    }
}

impl Shrink for f64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        if *self == 0.0 {
            vec![]
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // drop halves, then single elements, then shrink one element
        out.push(self[..n / 2].to_vec());
        out.push(self[n / 2..].to_vec());
        if n <= 16 {
            for i in 0..n {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..n {
                for cand in self[i].shrink_candidates() {
                    let mut v = self.clone();
                    v[i] = cand;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink> Shrink for (A, B) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(
            self.1
                .shrink_candidates()
                .into_iter()
                .map(|b| (self.0.clone(), b)),
        );
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink, C: Clone + Shrink> Shrink for (A, B, C) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink_candidates()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink_candidates()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Run `iters` random cases of `prop` over values from `gen`; shrink and
/// panic on the first failure. The seed comes from `QUASAR_PROP_SEED` when
/// set (replay), else a fixed default (CI determinism).
pub fn prop_check<T, G, P>(name: &str, iters: usize, mut gen: G, mut prop: P)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> PropResult,
{
    let seed = std::env::var("QUASAR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Pcg::seeded(seed ^ fxhash(name));
    for i in 0..iters {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            let (minimal, min_msg) = shrink_loop(case, msg, &mut prop);
            panic!(
                "property '{name}' failed (iter {i}, seed {seed}):\n  {min_msg}\n  minimal case: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut case: T, mut msg: String, prop: &mut P) -> (T, String)
where
    T: Clone + std::fmt::Debug + Shrink,
    P: FnMut(&T) -> PropResult,
{
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in case.shrink_candidates() {
            if let Err(m) = prop(&cand) {
                case = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (case, msg)
}

/// Small string hash so each property gets its own stream.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check(
            "sum of two non-negatives is >= each",
            200,
            |rng| (rng.below(1000), rng.below(1000)),
            |&(a, b)| {
                prop_assert!(a + b >= a && a + b >= b, "overflowed");
                ok()
            },
        );
    }

    #[test]
    #[should_panic(expected = "minimal case")]
    fn failing_property_shrinks_and_panics() {
        prop_check(
            "all vecs shorter than 3 (false)",
            200,
            |rng| {
                (0..rng.usize_below(20))
                    .map(|_| rng.below(10))
                    .collect::<Vec<u64>>()
            },
            |v| {
                prop_assert!(v.len() < 3, "len {} >= 3", v.len());
                ok()
            },
        );
    }

    #[test]
    fn shrink_vec_reaches_small_case() {
        // the minimal failing vec for "no element >= 5" should be len-1
        let case: Vec<u64> = vec![1, 9, 3, 7, 2];
        let mut prop = |v: &Vec<u64>| -> PropResult {
            if v.iter().any(|&x| x >= 5) {
                Err("has big element".into())
            } else {
                Ok(())
            }
        };
        let (minimal, _) = shrink_loop(case, "seed".into(), &mut prop);
        assert_eq!(minimal.len(), 1);
        assert!(minimal[0] >= 5);
    }
}

//! Utility substrates hand-rolled for the offline environment (only the
//! `xla` crate's dependency tree is vendored — see DESIGN.md §1): JSON,
//! PRNG, property testing, CLI parsing, threading and timing.

pub mod bigstack;
pub mod cli;
pub mod hist;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threads;
pub mod time;

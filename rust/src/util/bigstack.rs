//! Big-stack entry helper.
//!
//! xla_extension 0.5.1's CPU client setup and HLO text parser recurse deeply
//! (observed SIGSEGV on default 8 MiB stacks when parsing modules with large
//! inline constants). Every binary/test that touches PJRT runs its body on a
//! dedicated thread with a generous stack via [`run`].

/// Run `f` on a 256 MiB-stack thread and propagate its result/panic.
pub fn run<T, F>(f: F) -> T
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    std::thread::Builder::new()
        .name("quasar-main".into())
        .stack_size(256 << 20)
        .spawn(f)
        .expect("spawn big-stack thread")
        .join()
        .unwrap_or_else(|e| std::panic::resume_unwind(e))
}

#[cfg(test)]
mod tests {
    #[test]
    fn returns_value() {
        assert_eq!(super::run(|| 7), 7);
    }

    #[test]
    #[should_panic(expected = "inner")]
    fn propagates_panic() {
        super::run(|| panic!("inner"));
    }
}

//! Streaming histogram / summary statistics for latency and length metrics.
//!
//! Log-bucketed (HdrHistogram-style, base-10 sub-decades) so p50/p95/p99 of
//! microsecond-to-second latencies are captured with ~4% relative error at a
//! fixed 256-bucket footprint, plus exact min/max/mean/count.

#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// log-spaced buckets covering [1e-7, 1e3) in 25-per-decade resolution
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const DECADES_LO: f64 = -7.0;
const PER_DECADE: usize = 25;
const N_BUCKETS: usize = 10 * PER_DECADE + 2; // + underflow/overflow

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v <= 0.0 {
            return 0; // underflow
        }
        let pos = (v.log10() - DECADES_LO) * PER_DECADE as f64;
        if pos < 0.0 {
            0
        } else if pos as usize + 1 >= N_BUCKETS {
            N_BUCKETS - 1 // overflow
        } else {
            pos as usize + 1
        }
    }

    fn bucket_value(i: usize) -> f64 {
        // representative (geometric-mid) value of bucket i
        10f64.powf(DECADES_LO + (i as f64 - 0.5) / PER_DECADE as f64)
    }

    pub fn record(&mut self, v: f64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Quantile in [0,1]; approximate via bucket representative values but
    /// exact at the extremes (clamped to observed min/max).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Raw per-bucket counts (index 0 = underflow, last = overflow); pairs
    /// with [`bucket_upper_bound`] for cumulative (Prometheus-style) export.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Inclusive upper bound of bucket `i` in the recorded unit: the
    /// underflow bucket tops out at the scale floor, the overflow bucket at
    /// +Inf.
    pub fn bucket_upper_bound(i: usize) -> f64 {
        if i == 0 {
            10f64.powf(DECADES_LO)
        } else if i + 1 >= N_BUCKETS {
            f64::INFINITY
        } else {
            10f64.powf(DECADES_LO + i as f64 / PER_DECADE as f64)
        }
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// One-line human summary (seconds assumed, printed in ms).
    pub fn summary_ms(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count,
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.p95() * 1e3,
            self.p99() * 1e3,
            self.max() * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn empty_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p99(), 0.0);
    }

    #[test]
    fn mean_min_max_exact() {
        let mut h = Histogram::new();
        for v in [0.001, 0.002, 0.003] {
            h.record(v);
        }
        assert!((h.mean() - 0.002).abs() < 1e-12);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.003);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        let mut rng = Pcg::seeded(2);
        let mut vals: Vec<f64> = (0..10_000)
            .map(|_| 0.0001 * (1.0 + 99.0 * rng.f64()))
            .collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.95, 0.99] {
            let exact = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.12, "q={q}: approx {approx} vs exact {exact}");
        }
    }

    #[test]
    fn merge_equals_combined_stream() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut rng = Pcg::seeded(3);
        for i in 0..2000 {
            let v = rng.f64() * 0.1;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert!((a.mean() - c.mean()).abs() < 1e-12);
        assert_eq!(a.p95(), c.p95());
    }

    #[test]
    fn bucket_bounds_are_monotone_and_cover_recordings() {
        let bounds: Vec<f64> = (0..N_BUCKETS).map(Histogram::bucket_upper_bound).collect();
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*bounds.last().unwrap(), f64::INFINITY);
        let mut h = Histogram::new();
        for v in [1e-9, 0.0004, 0.25, 7.5, 1e6] {
            h.record(v);
            // every recorded value lands in a bucket whose bound covers it
            let i = (0..N_BUCKETS)
                .find(|&i| h.bucket_counts()[i] > 0 && Histogram::bucket_upper_bound(i) >= v);
            assert!(i.is_some(), "no covering bucket for {v}");
        }
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), h.count());
    }

    #[test]
    fn extreme_values_clamp_not_panic() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e9);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0) <= 1e9);
    }
}

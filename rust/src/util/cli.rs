//! Tiny declarative CLI argument parser (clap is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative command-line parser.
#[derive(Debug, Clone)]
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: default.map(String::from),
        });
        self
    }

    /// Declare a boolean `--name` switch.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: false,
            default: None,
        });
        self
    }

    fn spec(&self, name: &str) -> Option<&Spec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// Parse; returns Err on unknown/malformed args, prints help and exits
    /// on `--help` when parsing real process args via [`Cli::parse_env`].
    pub fn parse(mut self, args: &[String]) -> Result<Parsed, CliError> {
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if name == "help" {
                    return Ok(Parsed { help: Some(self.help_text()), ..Parsed::empty() });
                }
                let spec = self
                    .spec(&name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?
                    .clone();
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| {
                                CliError(format!("--{name} requires a value"))
                            })?
                            .clone(),
                    };
                    self.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    self.flags.push(name);
                }
            } else {
                self.positional.push(arg.clone());
            }
        }
        // apply defaults
        for s in &self.specs {
            if s.takes_value && !self.values.contains_key(&s.name) {
                if let Some(d) = &s.default {
                    self.values.insert(s.name.clone(), d.clone());
                }
            }
        }
        Ok(Parsed {
            help: None,
            values: self.values,
            flags: self.flags,
            positional: self.positional,
        })
    }

    /// Parse `std::env::args()[1..]`, printing help/errors and exiting as a
    /// CLI binary should.
    pub fn parse_env(self) -> Parsed {
        let help = self.help_text();
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(p) => {
                if let Some(h) = &p.help {
                    println!("{h}");
                    std::process::exit(0);
                }
                p
            }
            Err(e) => {
                eprintln!("{e}\n\n{help}");
                std::process::exit(2);
            }
        }
    }

    pub fn help_text(&self) -> String {
        let mut out = format!("{} — {}\n\nOPTIONS:\n", self.program, self.about);
        for s in &self.specs {
            let head = if s.takes_value {
                format!("  --{} <value>", s.name)
            } else {
                format!("  --{}", s.name)
            };
            let dflt = s
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("{head:<28}{}{dflt}\n", s.help));
        }
        out.push_str("  --help                    show this message\n");
        out
    }
}

/// Result of parsing; typed accessors panic with a clear message on type
/// errors (these are programmer errors in bench/example code).
#[derive(Debug, Clone)]
pub struct Parsed {
    pub help: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    fn empty() -> Self {
        Parsed {
            help: None,
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("missing required --{name}"))
            .clone()
    }

    pub fn usize(&self, name: &str) -> usize {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an unsigned integer"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an unsigned integer"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list accessor.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("model", Some("qwen3-like"), "model name")
            .opt("steps", None, "step count")
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Parsed, CliError> {
        cli().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_apply() {
        let p = parse(&[]).unwrap();
        assert_eq!(p.str("model"), "qwen3-like");
        assert_eq!(p.get("steps"), None);
        assert!(!p.has("verbose"));
    }

    #[test]
    fn key_value_both_syntaxes() {
        let p = parse(&["--model", "pangu-like", "--steps=42", "--verbose"]).unwrap();
        assert_eq!(p.str("model"), "pangu-like");
        assert_eq!(p.usize("steps"), 42);
        assert!(p.has("verbose"));
    }

    #[test]
    fn positional_and_lists() {
        let p = cli()
            .opt("tasks", Some("a,b"), "")
            .parse(&["pos1".into(), "--tasks=x,y,z".into(), "pos2".into()])
            .unwrap();
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
        assert_eq!(p.list("tasks"), vec!["x", "y", "z"]);
    }

    #[test]
    fn errors_on_unknown_and_missing_value() {
        assert!(parse(&["--nope"]).is_err());
        assert!(parse(&["--steps"]).is_err());
        assert!(parse(&["--verbose=1"]).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cli().help_text();
        assert!(h.contains("--model"));
        assert!(h.contains("default: qwen3-like"));
        let p = parse(&["--help"]).unwrap();
        assert!(p.help.is_some());
    }
}

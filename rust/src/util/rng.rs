//! PCG64-family PRNG plus the sampling helpers the engine needs.
//!
//! `rand` is not vendored offline; this is a small, well-tested PCG-XSH-RR
//! implementation. Determinism matters: every benchmark and property test
//! seeds its own stream so paper tables regenerate bit-identically.

/// PCG-XSH-RR 64/32 with 64-bit output composed from two draws.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-seed constructor (stream derived from the seed).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` via Lemire-style rejection (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential inter-arrival sample with the given rate (per second).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -u.ln() / rate
    }

    /// Pick one index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total");
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split a child stream (for per-request / per-agent determinism).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ tag, tag.wrapping_mul(2654435761) | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::seeded(7);
        let mut b = Pcg::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seeded(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg::seeded(42);
        let n = 20_000;
        let mut sum = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            buckets[(x * 10.0) as usize] += 1;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        for (i, b) in buckets.iter().enumerate() {
            let frac = *b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.02, "bucket {i}: {frac}");
        }
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut rng = Pcg::seeded(1);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.below(3) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / 30_000.0;
            assert!((frac - 1.0 / 3.0).abs() < 0.02, "{frac}");
        }
    }

    #[test]
    fn weighted_tracks_weights() {
        let mut rng = Pcg::seeded(5);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert!((counts[2] as f64 / 30_000.0 - 0.6).abs() < 0.02);
        assert!((counts[1] as f64 / 30_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut rng = Pcg::seeded(9);
        let rate = 4.0;
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| rng.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg::seeded(3);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Pcg::seeded(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}

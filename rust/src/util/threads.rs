//! Thread-pool + channel utilities (tokio is not vendored offline;
//! DESIGN.md §1). The engine's concurrency is deliberately simple: a fixed
//! worker pool for request handling, `std::sync::mpsc` for queues, and a
//! scoped parallel-map used by benches and the eval suite.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    live: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "pool of zero workers");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let live = Arc::new(AtomicBool::new(true));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("quasar-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, live }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers gone");
    }

    pub fn is_live(&self) -> bool {
        self.live.load(Ordering::Relaxed)
    }

    /// Drop the sender and join all workers (runs automatically on drop).
    pub fn shutdown(&mut self) {
        self.live.store(false, Ordering::Relaxed);
        self.tx.take(); // closes the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Parallel map preserving input order. Spawns up to `n_threads` scoped
/// threads; panics in `f` propagate.
pub fn par_map<T, U, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = n_threads.clamp(1, n);
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let out = Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((idx, v)) => {
                        let r = f(v);
                        out.lock().unwrap()[idx] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("slot filled")).collect()
}

/// Bounded single-producer/single-consumer style queue wrapper around mpsc
/// with backpressure accounting (the router's admission path).
pub struct BoundedQueue<T> {
    tx: Sender<T>,
    rx: Mutex<Receiver<T>>,
    cap: usize,
    len: Arc<Mutex<usize>>,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        let (tx, rx) = channel();
        BoundedQueue { tx, rx: Mutex::new(rx), cap, len: Arc::new(Mutex::new(0)) }
    }

    /// Try to enqueue; `Err(item)` when full (caller applies backpressure).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut len = self.len.lock().unwrap();
        if *len >= self.cap {
            return Err(item);
        }
        *len += 1;
        self.tx.send(item).map_err(|e| {
            *self.len.lock().unwrap() -= 1;
            e.0
        })
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let rx = self.rx.lock().unwrap();
        match rx.try_recv() {
            Ok(v) => {
                *self.len.lock().unwrap() -= 1;
                Some(v)
            }
            Err(_) => None,
        }
    }

    pub fn len(&self) -> usize {
        *self.len.lock().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop joins
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map((0..50).collect::<Vec<i64>>(), 8, |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single_thread() {
        let empty: Vec<i32> = vec![];
        assert!(par_map(empty, 4, |x: i32| x).is_empty());
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
        assert!(q.is_empty());
    }
}

//! Small host-side dense tensor used at the rust/XLA boundary: logits,
//! token blocks and KV caches live in this form between PJRT calls.

use anyhow::{bail, Result};

/// Row-major dense tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T> {
    pub data: Vec<T>,
    pub dims: Vec<usize>,
}

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(dims: &[usize]) -> Self {
        Tensor { data: vec![T::default(); dims.iter().product()], dims: dims.to_vec() }
    }

    pub fn from_vec(data: Vec<T>, dims: &[usize]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if data.len() != n {
            bail!("tensor data len {} != product of dims {:?}", data.len(), dims);
        }
        Ok(Tensor { data, dims: dims.to_vec() })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Strides in elements (row-major).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.dims.len()];
        for i in (0..self.dims.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.dims[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> T {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.dims).enumerate() {
            debug_assert!(x < d, "index {x} >= dim {d} at axis {i}");
            off = off * d + x;
        }
        self.data[off]
    }

    /// Contiguous slice of the last axis at the given leading indices.
    pub fn row(&self, lead: &[usize]) -> &[T] {
        let last = *self.dims.last().expect("rank >= 1");
        let mut off = 0;
        for (&x, &d) in lead.iter().zip(&self.dims) {
            off = off * d + x;
        }
        let start = off * last;
        &self.data[start..start + last]
    }

    /// Copy a row-slice along axis 1 of a rank>=2 tensor from another tensor
    /// whose shape matches except axis 1 (used to splice one request's KV
    /// rows into a batch cache: layout `[L, B, ...]`, axis 1 = batch row).
    pub fn copy_axis1_row_from(&mut self, dst_row: usize, src: &Tensor<T>, src_row: usize) {
        assert!(self.rank() >= 2 && src.rank() == self.rank());
        assert_eq!(self.dims[0], src.dims[0], "axis0 mismatch");
        assert_eq!(&self.dims[2..], &src.dims[2..], "trailing dims mismatch");
        let inner: usize = self.dims[2..].iter().product();
        let (db, sb) = (self.dims[1], src.dims[1]);
        assert!(dst_row < db && src_row < sb);
        for a0 in 0..self.dims[0] {
            let d_off = (a0 * db + dst_row) * inner;
            let s_off = (a0 * sb + src_row) * inner;
            self.data[d_off..d_off + inner]
                .copy_from_slice(&src.data[s_off..s_off + inner]);
        }
    }

    /// Copy a set of axis-1 rows from `src` in one pass: `pairs[i] =
    /// (dst_row, src_row)`. The bulk form of [`Tensor::copy_axis1_row_from`]
    /// used by the KV gather/scatter path, where one chunk execution moves
    /// several batch rows between the resident group cache and a
    /// bucket-shaped scratch cache.
    pub fn copy_axis1_rows(&mut self, pairs: &[(usize, usize)], src: &Tensor<T>) {
        assert!(self.rank() >= 2 && src.rank() == self.rank());
        assert_eq!(self.dims[0], src.dims[0], "axis0 mismatch");
        assert_eq!(&self.dims[2..], &src.dims[2..], "trailing dims mismatch");
        let inner: usize = self.dims[2..].iter().product();
        let (db, sb) = (self.dims[1], src.dims[1]);
        for &(d, s) in pairs {
            assert!(d < db && s < sb, "row pair ({d},{s}) out of range ({db},{sb})");
        }
        for a0 in 0..self.dims[0] {
            for &(d, s) in pairs {
                let d_off = (a0 * db + d) * inner;
                let s_off = (a0 * sb + s) * inner;
                self.data[d_off..d_off + inner]
                    .copy_from_slice(&src.data[s_off..s_off + inner]);
            }
        }
    }

    /// Copy the first `n_seq` positions of the sequence axis (axis
    /// `rank-2`) from a same-shaped tensor, leaving later positions
    /// untouched. KV layout `[..., S, hd]`: the prefix-cache path moves
    /// only the committed positions of a row instead of the whole
    /// `max_seq` extent.
    pub fn copy_seq_prefix_from(&mut self, src: &Tensor<T>, n_seq: usize) {
        let r = self.rank();
        assert!(r >= 2, "need a trailing [S, inner] layout");
        assert_eq!(self.dims, src.dims, "shape mismatch");
        let seq = self.dims[r - 2];
        assert!(n_seq <= seq, "prefix {n_seq} exceeds seq {seq}");
        let inner = self.dims[r - 1];
        let outer: usize = self.dims[..r - 2].iter().product();
        let block = seq * inner;
        for o in 0..outer {
            let off = o * block;
            self.data[off..off + n_seq * inner]
                .copy_from_slice(&src.data[off..off + n_seq * inner]);
        }
    }

    /// Copy the first `n_seq` sequence positions of one axis-1 row from
    /// `src` (whose shape matches except axis 1), leaving the row's later
    /// positions untouched. The length-bounded form of
    /// [`Tensor::copy_axis1_row_from`] for `[L, B, ..., S, hd]` KV caches:
    /// an admission only has `prompt_len` valid positions, so splicing the
    /// full `max_seq` extent moves (and preserves) garbage.
    pub fn copy_axis1_row_seq_prefix_from(&mut self, dst_row: usize, src: &Tensor<T>,
                                          src_row: usize, n_seq: usize) {
        let r = self.rank();
        assert!(r >= 4 && src.rank() == r, "need a [_, B, ..., S, inner] layout");
        assert_eq!(&self.dims[2..], &src.dims[2..], "trailing dims mismatch");
        self.copy_axis1_row_seq_range_from(dst_row, 0, src, src_row, 0, n_seq)
    }

    /// Copy `n_seq` sequence positions from `src` (row `src_row`, starting
    /// at position `src_pos`) into this tensor's row `dst_row` starting at
    /// position `dst_pos`. Shapes must agree on every axis *except* axis 1
    /// (batch row) and the sequence axis (`rank - 2`), whose extents may
    /// differ as long as both ranges fit — the page-strided copy the paged
    /// prefix cache is built on: a `[L, 1, H, page, hd]` pool page reads
    /// from / writes into any offset of a `[L, B, H, max_seq, hd]` cache
    /// row.
    pub fn copy_axis1_row_seq_range_from(&mut self, dst_row: usize, dst_pos: usize,
                                         src: &Tensor<T>, src_row: usize,
                                         src_pos: usize, n_seq: usize) {
        let r = self.rank();
        assert!(r >= 4 && src.rank() == r, "need a [_, B, ..., S, inner] layout");
        assert_eq!(self.dims[0], src.dims[0], "axis0 mismatch");
        assert_eq!(&self.dims[2..r - 2], &src.dims[2..r - 2], "mid dims mismatch");
        assert_eq!(self.dims[r - 1], src.dims[r - 1], "inner dim mismatch");
        let (dseq, sseq) = (self.dims[r - 2], src.dims[r - 2]);
        assert!(dst_pos + n_seq <= dseq, "dst range {dst_pos}+{n_seq} exceeds seq {dseq}");
        assert!(src_pos + n_seq <= sseq, "src range {src_pos}+{n_seq} exceeds seq {sseq}");
        let inner = self.dims[r - 1];
        let mid: usize = self.dims[2..r - 2].iter().product();
        let (db, sb) = (self.dims[1], src.dims[1]);
        assert!(dst_row < db && src_row < sb);
        for a0 in 0..self.dims[0] {
            for m in 0..mid {
                let d_off = (((a0 * db + dst_row) * mid + m) * dseq + dst_pos) * inner;
                let s_off = (((a0 * sb + src_row) * mid + m) * sseq + src_pos) * inner;
                self.data[d_off..d_off + n_seq * inner]
                    .copy_from_slice(&src.data[s_off..s_off + n_seq * inner]);
            }
        }
    }

    /// Copy a set of axis-1 rows from `src`, each bounded to its own
    /// sequence-prefix length: `triples[i] = (dst_row, src_row, n_seq)`.
    /// The length-aware form of [`Tensor::copy_axis1_rows`] the KV
    /// gather/scatter path uses so copy volume tracks each row's committed
    /// positions instead of the full `max_seq` extent.
    pub fn copy_axis1_rows_seq_prefix(&mut self, triples: &[(usize, usize, usize)],
                                      src: &Tensor<T>) {
        for &(d, s, n) in triples {
            self.copy_axis1_row_seq_range_from(d, 0, src, s, 0, n);
        }
    }

    /// Reset every element to the default (pooled-scratch reuse without
    /// reallocating).
    pub fn zero(&mut self) {
        self.data.iter_mut().for_each(|v| *v = T::default());
    }

    /// Zero `n_seq` sequence positions of one axis-1 row starting at
    /// `start` — the length-bounded form of [`Tensor::zero_axis1_row`] for
    /// `[L, B, ..., S, hd]` caches: a leaving request only ever wrote its
    /// committed prefix (plus speculative slack), so zeroing the full
    /// `max_seq` extent moves bandwidth over positions that are already
    /// zero by invariant.
    pub fn zero_axis1_row_seq_range(&mut self, row: usize, start: usize, n_seq: usize) {
        let r = self.rank();
        assert!(r >= 4, "need a [_, B, ..., S, inner] layout");
        let seq = self.dims[r - 2];
        assert!(start + n_seq <= seq, "range {start}+{n_seq} exceeds seq {seq}");
        let inner = self.dims[r - 1];
        let mid: usize = self.dims[2..r - 2].iter().product();
        let b = self.dims[1];
        assert!(row < b, "row {row} out of range for batch {b}");
        for a0 in 0..self.dims[0] {
            for m in 0..mid {
                let off = (((a0 * b + row) * mid + m) * seq + start) * inner;
                self.data[off..off + n_seq * inner]
                    .iter_mut()
                    .for_each(|v| *v = T::default());
            }
        }
    }

    /// Zero a batch row (cache eviction).
    pub fn zero_axis1_row(&mut self, row: usize) {
        let inner: usize = self.dims[2..].iter().product();
        let b = self.dims[1];
        for a0 in 0..self.dims[0] {
            let off = (a0 * b + row) * inner;
            self.data[off..off + inner]
                .iter_mut()
                .for_each(|v| *v = T::default());
        }
    }
}

impl Tensor<f32> {
    /// Argmax over the last axis at the given leading indices.
    pub fn argmax_last(&self, lead: &[usize]) -> usize {
        let row = self.row(lead);
        let mut best = 0;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_and_strides() {
        let t = Tensor::from_vec((0..24).collect::<Vec<i32>>(), &[2, 3, 4]).unwrap();
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.at(&[1, 2, 3]), 23);
        assert_eq!(t.at(&[0, 1, 0]), 4);
        assert_eq!(t.row(&[1, 0]), &[12, 13, 14, 15]);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(vec![1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn splice_axis1_row() {
        // dst [2 (L), 3 (B), 2], src [2, 1, 2]
        let mut dst = Tensor::<i32>::zeros(&[2, 3, 2]);
        let src = Tensor::from_vec(vec![10, 11, 20, 21], &[2, 1, 2]).unwrap();
        dst.copy_axis1_row_from(1, &src, 0);
        assert_eq!(dst.at(&[0, 1, 0]), 10);
        assert_eq!(dst.at(&[0, 1, 1]), 11);
        assert_eq!(dst.at(&[1, 1, 0]), 20);
        assert_eq!(dst.at(&[1, 1, 1]), 21);
        // untouched rows stay zero
        assert_eq!(dst.at(&[0, 0, 0]), 0);
        assert_eq!(dst.at(&[1, 2, 1]), 0);
        dst.zero_axis1_row(1);
        assert_eq!(dst.at(&[1, 1, 0]), 0);
    }

    #[test]
    fn bulk_row_copy_matches_single_row_copies() {
        let src = Tensor::from_vec((0..12).collect::<Vec<i32>>(), &[2, 3, 2]).unwrap();
        let mut bulk = Tensor::<i32>::zeros(&[2, 4, 2]);
        bulk.copy_axis1_rows(&[(0, 2), (3, 0)], &src);
        let mut single = Tensor::<i32>::zeros(&[2, 4, 2]);
        single.copy_axis1_row_from(0, &src, 2);
        single.copy_axis1_row_from(3, &src, 0);
        assert_eq!(bulk, single);
        assert_eq!(bulk.at(&[0, 0, 0]), 4, "row 2 of src landed in row 0");
        assert_eq!(bulk.at(&[1, 3, 1]), 7, "row 0 of src landed in row 3");
        assert_eq!(bulk.at(&[0, 1, 0]), 0, "unmapped rows untouched");
    }

    #[test]
    fn seq_prefix_copy_moves_only_leading_positions() {
        // [2 (L), 1 (B), 3 (S), 2 (hd)]: src holds s+1 at every position.
        let mut src = Tensor::<i32>::zeros(&[2, 1, 3, 2]);
        for l in 0..2 {
            for s in 0..3 {
                for d in 0..2 {
                    src.data[(l * 3 + s) * 2 + d] = s as i32 + 1;
                }
            }
        }
        let mut dst = Tensor::<i32>::zeros(&[2, 1, 3, 2]);
        dst.data.iter_mut().for_each(|x| *x = -1);
        dst.copy_seq_prefix_from(&src, 2);
        assert_eq!(dst.at(&[0, 0, 0, 0]), 1);
        assert_eq!(dst.at(&[1, 0, 1, 1]), 2);
        assert_eq!(dst.at(&[0, 0, 2, 0]), -1, "beyond the prefix untouched");
        // n_seq == seq degenerates to a full copy.
        dst.copy_seq_prefix_from(&src, 3);
        assert_eq!(dst, src);
    }

    #[test]
    fn axis1_row_seq_prefix_copy_bounds_the_splice() {
        // dst [2 (L), 3 (B), 1 (H), 4 (S), 2 (hd)], src single-row.
        let mut src = Tensor::<i32>::zeros(&[2, 1, 1, 4, 2]);
        for (i, x) in src.data.iter_mut().enumerate() {
            *x = i as i32 + 1; // everything non-zero
        }
        let mut dst = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        dst.data.iter_mut().for_each(|x| *x = -1);
        dst.copy_axis1_row_seq_prefix_from(1, &src, 0, 2);
        // positions 0..2 of row 1 match src, later positions untouched
        assert_eq!(dst.at(&[0, 1, 0, 0, 0]), src.at(&[0, 0, 0, 0, 0]));
        assert_eq!(dst.at(&[1, 1, 0, 1, 1]), src.at(&[1, 0, 0, 1, 1]));
        assert_eq!(dst.at(&[0, 1, 0, 2, 0]), -1);
        assert_eq!(dst.at(&[0, 0, 0, 0, 0]), -1, "other rows untouched");
        assert_eq!(dst.at(&[1, 2, 0, 3, 1]), -1);
        // full-length prefix equals the whole-row splice
        let mut a = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        a.copy_axis1_row_seq_prefix_from(2, &src, 0, 4);
        let mut b = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        b.copy_axis1_row_from(2, &src, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn seq_range_copy_moves_pages_between_mismatched_extents() {
        // src: a "row cache" [2 (L), 2 (B), 1 (H), 6 (S), 2 (hd)] whose row 1
        // holds 10*s + d at position s; dst: a "page" [2, 1, 1, 3, 2].
        let mut src = Tensor::<i32>::zeros(&[2, 2, 1, 6, 2]);
        for l in 0..2 {
            for s in 0..6 {
                for d in 0..2 {
                    let off = (((l * 2 + 1) * 6) + s) * 2 + d;
                    src.data[off] = (10 * s + d) as i32;
                }
            }
        }
        let mut page = Tensor::<i32>::zeros(&[2, 1, 1, 3, 2]);
        page.data.iter_mut().for_each(|x| *x = -1);
        // Pull src positions [2, 4) of row 1 into page positions [0, 2).
        page.copy_axis1_row_seq_range_from(0, 0, &src, 1, 2, 2);
        assert_eq!(page.at(&[0, 0, 0, 0, 0]), 20);
        assert_eq!(page.at(&[1, 0, 0, 1, 1]), 31);
        assert_eq!(page.at(&[0, 0, 0, 2, 0]), -1, "beyond the range untouched");
        // Push the page back into a different offset of a fresh row cache.
        let mut dst = Tensor::<i32>::zeros(&[2, 2, 1, 6, 2]);
        dst.copy_axis1_row_seq_range_from(0, 3, &page, 0, 0, 2);
        assert_eq!(dst.at(&[0, 0, 0, 3, 0]), 20);
        assert_eq!(dst.at(&[1, 0, 0, 4, 1]), 31);
        assert_eq!(dst.at(&[0, 0, 0, 2, 0]), 0, "below the offset untouched");
        assert_eq!(dst.at(&[0, 1, 0, 3, 0]), 0, "other rows untouched");
        // Round trip through equal extents matches the prefix copy.
        let mut a = Tensor::<i32>::zeros(&[2, 2, 1, 6, 2]);
        a.copy_axis1_row_seq_range_from(0, 0, &src, 1, 0, 4);
        let mut b = Tensor::<i32>::zeros(&[2, 2, 1, 6, 2]);
        b.copy_axis1_row_seq_prefix_from(0, &src, 1, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn bulk_prefix_copy_bounds_each_row_to_its_own_length() {
        // src [2 (L), 3 (B), 1 (H), 4 (S), 2 (hd)]: row r holds r+1.
        let mut src = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        for l in 0..2 {
            for b in 0..3 {
                for s in 0..4 {
                    for d in 0..2 {
                        src.data[(((l * 3 + b) * 4) + s) * 2 + d] = b as i32 + 1;
                    }
                }
            }
        }
        let mut dst = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        dst.data.iter_mut().for_each(|x| *x = -1);
        // dst row 0 <- src row 2 (3 positions), dst row 2 <- src row 0 (1).
        dst.copy_axis1_rows_seq_prefix(&[(0, 2, 3), (2, 0, 1)], &src);
        assert_eq!(dst.at(&[0, 0, 0, 0, 0]), 3);
        assert_eq!(dst.at(&[1, 0, 0, 2, 1]), 3);
        assert_eq!(dst.at(&[0, 0, 0, 3, 0]), -1, "beyond row 0's length untouched");
        assert_eq!(dst.at(&[0, 2, 0, 0, 0]), 1);
        assert_eq!(dst.at(&[0, 2, 0, 1, 0]), -1, "beyond row 2's length untouched");
        assert_eq!(dst.at(&[0, 1, 0, 0, 0]), -1, "unmapped row untouched");
        // Full-length triples match the unbounded bulk copy exactly.
        let mut a = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        a.copy_axis1_rows_seq_prefix(&[(0, 2, 4), (2, 0, 4)], &src);
        let mut b = Tensor::<i32>::zeros(&[2, 3, 1, 4, 2]);
        b.copy_axis1_rows(&[(0, 2), (2, 0)], &src);
        assert_eq!(a, b);
    }

    #[test]
    fn zero_axis1_row_seq_range_clears_only_the_range() {
        let mut t = Tensor::<i32>::zeros(&[2, 2, 1, 4, 2]);
        t.data.iter_mut().for_each(|x| *x = 9);
        t.zero_axis1_row_seq_range(1, 1, 2);
        assert_eq!(t.at(&[0, 1, 0, 0, 0]), 9, "below the range untouched");
        assert_eq!(t.at(&[0, 1, 0, 1, 0]), 0);
        assert_eq!(t.at(&[1, 1, 0, 2, 1]), 0);
        assert_eq!(t.at(&[0, 1, 0, 3, 0]), 9, "beyond the range untouched");
        assert_eq!(t.at(&[0, 0, 0, 1, 0]), 9, "other rows untouched");
        // Full-extent range matches zero_axis1_row.
        let mut a = t.clone();
        a.zero_axis1_row_seq_range(0, 0, 4);
        let mut b = t.clone();
        b.zero_axis1_row(0);
        assert_eq!(a, b);
    }

    #[test]
    fn zero_resets_all_elements() {
        let mut t = Tensor::from_vec(vec![1.0f32, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        t.zero();
        assert!(t.data.iter().all(|&v| v == 0.0));
        assert_eq!(t.dims, vec![2, 2]);
    }

    #[test]
    fn argmax_last() {
        let t = Tensor::from_vec(vec![0.1f32, 0.9, 0.5, 2.0, -1.0, 0.0], &[2, 3]).unwrap();
        assert_eq!(t.argmax_last(&[0]), 1);
        assert_eq!(t.argmax_last(&[1]), 0);
    }
}

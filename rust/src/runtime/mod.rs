//! Runtime layer: loads the AOT artifacts (`make artifacts`) and executes
//! them on the PJRT CPU client via the `xla` crate.
//!
//! Layering: `artifacts` (manifest contract) -> `client` (PJRT wrapper,
//! weight stores, chunk execution) -> `model` (per-model cache of weights +
//! compiled executables). `tensor` is the host-side array type crossing the
//! boundary.

pub mod artifacts;
pub mod client;
pub mod model;
pub mod tensor;

pub use artifacts::{ArtifactEntry, CostModelCfg, Manifest, ModelCfg, ModelEntry};
pub use client::{ChunkOutput, CompiledChunk, WeightStore, XlaRuntime};
pub use model::ModelRuntime;
pub use tensor::Tensor;

//! PJRT execution layer: compiles the AOT HLO-text artifacts and runs them
//! with device-resident weights.
//!
//! Key properties (see DESIGN.md §6 and /opt/xla-example/README.md):
//!  * HLO **text** interchange — `HloModuleProto::from_text_file` reassigns
//!    instruction ids, sidestepping the 64-bit-id proto incompatibility.
//!  * Weights are HLO *arguments*, uploaded once per variant as
//!    `PjRtBuffer`s (`WeightStore`) and shared by every executable of that
//!    variant — the request path never re-uploads them.
//!  * KV caches travel host<->device per call as raw f32 slices; on the CPU
//!    PJRT backend these are memcpys. `ChunkOutput` hands the advanced
//!    caches back as owned tensors so the KV manager can splice batch rows.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::FromRawBytes;

use super::artifacts::ArtifactEntry;
use super::tensor::Tensor;

/// Thin wrapper around the PJRT CPU client.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

/// Device-resident weight buffers, keyed by flattened arg name
/// (`layers.0.wq.ws`, ...).
///
/// The source literals are retained for the store's lifetime:
/// `buffer_from_host_literal` copies asynchronously on the CPU PJRT backend
/// and dropping the literal while the copy is in flight is a use-after-free
/// (observed as flaky SIGSEGV/SIGABRT when loading a second variant's
/// weights).
pub struct WeightStore {
    bufs: HashMap<String, xla::PjRtBuffer>,
    _literals: Vec<xla::Literal>,
    pub nbytes_host: usize,
}

/// One compiled (variant, fn, batch-bucket) program.
pub struct CompiledChunk {
    exe: xla::PjRtLoadedExecutable,
    pub entry: ArtifactEntry,
    pub cache_dims: Vec<usize>, // [L, B, H, S, hd]
    pub vocab: usize,
}

/// Host-side result of one chunk execution.
pub struct ChunkOutput {
    /// `[B, T, V]` next-token logits; row `i` conditions on token `i`.
    pub logits: Tensor<f32>,
    pub k: Tensor<f32>,
    pub v: Tensor<f32>,
}

impl XlaRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(XlaRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one variant's weight npz into device buffers.
    ///
    /// Goes through `Literal::read_npz` + `buffer_from_host_literal` rather
    /// than `PjRtBuffer::read_npz`: the latter has an element-type bug in
    /// xla 0.1.6 (`buffer_from_host_raw_bytes` passes the `ElementType`
    /// discriminant where XLA expects a `PrimitiveType` value, so F32
    /// arrives as F16 and S8 as PRED). The literal path converts correctly.
    pub fn load_weights(&self, path: &Path) -> Result<WeightStore> {
        let pairs = xla::Literal::read_npz(path, &())
            .map_err(to_anyhow)
            .with_context(|| format!("loading weights {}", path.display()))?;
        let meta = std::fs::metadata(path)?;
        let mut bufs = HashMap::new();
        let mut literals = Vec::with_capacity(pairs.len());
        for (name, lit) in pairs {
            let buf = self
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(to_anyhow)
                .with_context(|| format!("uploading weight '{name}'"))?;
            bufs.insert(name, buf);
            literals.push(lit); // keep alive: upload is async (see struct docs)
        }
        Ok(WeightStore { bufs, _literals: literals, nbytes_host: meta.len() as usize })
    }

    /// Compile one artifact (HLO text -> PJRT executable).
    pub fn compile(&self, entry: &ArtifactEntry, vocab: usize,
                   head_dim: usize, max_seq: usize, n_heads: usize)
                   -> Result<CompiledChunk> {
        let proto = xla::HloModuleProto::from_text_file(&entry.path)
            .map_err(to_anyhow)
            .with_context(|| format!("parsing {}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)
            .with_context(|| format!("compiling {}", entry.name))?;
        Ok(CompiledChunk {
            exe,
            cache_dims: vec![entry.n_layers, entry.batch, n_heads, max_seq, head_dim],
            vocab,
            entry: entry.clone(),
        })
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(to_anyhow)
    }
}

impl WeightStore {
    /// Resolve the ordered argument buffers for an artifact. Pruned variants
    /// reference a *subset* of the fp32 arg names, so lookups are by name.
    pub fn ordered_args<'a>(&'a self, names: &[String]) -> Result<Vec<&'a xla::PjRtBuffer>> {
        names
            .iter()
            .map(|n| {
                self.bufs
                    .get(n)
                    .ok_or_else(|| anyhow!("weight arg '{n}' missing from npz"))
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

impl CompiledChunk {
    pub fn batch(&self) -> usize {
        self.entry.batch
    }

    pub fn chunk_len(&self) -> usize {
        self.entry.chunk_len
    }

    /// Execute the chunk. `tokens` is `[B, T]` row-major, `pos` per-row
    /// write offsets, caches `[L, B, H, S, hd]`.
    pub fn run(&self, rt: &XlaRuntime, weights: &WeightStore, tokens: &[i32],
               k: &Tensor<f32>, v: &Tensor<f32>, pos: &[i32]) -> Result<ChunkOutput> {
        let (b, t) = (self.entry.batch, self.entry.chunk_len);
        if tokens.len() != b * t {
            bail!("tokens len {} != {}x{}", tokens.len(), b, t);
        }
        if pos.len() != b {
            bail!("pos len {} != batch {b}", pos.len());
        }
        if k.dims != self.cache_dims || v.dims != self.cache_dims {
            bail!("cache dims {:?}/{:?} != expected {:?}", k.dims, v.dims, self.cache_dims);
        }

        let tok_buf = rt.upload_i32(tokens, &[b, t])?;
        let k_buf = rt.upload_f32(&k.data, &k.dims)?;
        let v_buf = rt.upload_f32(&v.data, &v.dims)?;
        let pos_buf = rt.upload_i32(pos, &[b])?;

        let mut args: Vec<&xla::PjRtBuffer> =
            weights.ordered_args(&self.entry.weight_args)?;
        args.push(&tok_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&pos_buf);

        let outs = self.exe.execute_b(&args).map_err(to_anyhow)?;
        let first = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output buffer"))?;
        let lit = first.to_literal_sync().map_err(to_anyhow)?;
        let parts = lit.to_tuple().map_err(to_anyhow)?;
        if parts.len() != 3 {
            bail!("expected 3 outputs (logits, k, v), got {}", parts.len());
        }
        let mut it = parts.into_iter();
        let logits_lit = it.next().unwrap();
        let k_lit = it.next().unwrap();
        let v_lit = it.next().unwrap();

        let logits = Tensor::from_vec(
            logits_lit.to_vec::<f32>().map_err(to_anyhow)?,
            &[b, t, self.vocab],
        )?;
        let k_out = Tensor::from_vec(
            k_lit.to_vec::<f32>().map_err(to_anyhow)?,
            &self.cache_dims,
        )?;
        let v_out = Tensor::from_vec(
            v_lit.to_vec::<f32>().map_err(to_anyhow)?,
            &self.cache_dims,
        )?;
        Ok(ChunkOutput { logits, k: k_out, v: v_out })
    }
}

/// xla::Error does not implement std::error::Error -> map by display.
pub fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

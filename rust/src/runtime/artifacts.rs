//! Typed view of `artifacts/manifest.json` — the L2→L3 contract emitted by
//! `python/compile/aot.py` (DESIGN.md §6).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse_file, Json};

/// Architecture of one exported model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn_dim: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub gamma_max: usize,
    pub head_dim: usize,
}

impl ModelCfg {
    pub fn verify_len(&self) -> usize {
        self.gamma_max + 1
    }

    /// Parameter count of the full (unpruned) model.
    pub fn n_params(&self) -> usize {
        let (d, f) = (self.d_model, self.ffn_dim);
        let per_layer = 4 * d * d + 3 * d * f + 2 * d;
        self.vocab_size * d + self.n_layers * per_layer + d
    }
}

/// Analytic per-call cost exported by aot.py, feeding the roofline model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArtifactCost {
    pub weight_bytes_device: f64,
    pub kv_bytes: f64,
    pub act_bytes: f64,
    pub macs: f64,
    pub tokens_per_call: f64,
}

/// One exported HLO program.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub variant: String,
    pub fn_name: String,
    pub batch: usize,
    pub chunk_len: usize,
    pub n_layers: usize,
    pub path: PathBuf,
    pub weights_file: String,
    pub weight_args: Vec<String>,
    pub cost: ArtifactCost,
}

/// One model's artifact set.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub cfg: ModelCfg,
    pub weights: BTreeMap<String, String>, // variant-class -> npz path
    pub artifacts: Vec<ArtifactEntry>,
    pub goldens_path: PathBuf,
    pub calibration_path: PathBuf,
}

impl ModelEntry {
    pub fn artifact(&self, variant: &str, fn_name: &str, batch: usize) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.variant == variant && a.fn_name == fn_name && a.batch == batch)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact {variant}/{fn_name}/b{batch} for model {}",
                    self.cfg.name
                )
            })
    }

    /// The batch buckets available for a (variant, fn).
    pub fn buckets(&self, variant: &str, fn_name: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.variant == variant && a.fn_name == fn_name)
            .map(|a| a.batch)
            .collect();
        b.sort_unstable();
        b
    }

    /// Smallest exported bucket that fits `n` rows of a (variant, fn), or
    /// the largest available when every bucket is smaller (the caller must
    /// then split the group across calls). `None` when the (variant, fn) has
    /// no exported buckets at all.
    pub fn best_bucket(&self, variant: &str, fn_name: &str, n: usize) -> Option<usize> {
        crate::coordinator::plan::best_bucket(&self.buckets(variant, fn_name), n)
    }
}

/// Device constants for the simulated accelerator (DESIGN.md §1).
#[derive(Debug, Clone)]
pub struct CostModelCfg {
    pub device: String,
    pub hbm_bw_bytes_per_s: f64,
    pub int8_ops_per_s: f64,
    pub bf16_ops_per_s: f64,
    pub bytes_per_weight: BTreeMap<String, f64>,
    pub kernel_launch_s: f64,
    pub drafter_cost_per_token_s: f64,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub tokenizer_path: PathBuf,
    pub workloads_path: PathBuf,
    pub evalset_path: PathBuf,
    pub cost_model: CostModelCfg,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Self> {
        let j = parse_file(&root.join("manifest.json"))
            .context("loading manifest.json (run `make artifacts` first)")?;
        Self::from_json(root, &j)
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    pub fn from_json(root: &Path, j: &Json) -> Result<Self> {
        let version = j.get("version")?.as_i64()?;
        if version != 1 {
            return Err(anyhow!("unsupported manifest version {version}"));
        }
        let cm = j.get("cost_model")?;
        let cost_model = CostModelCfg {
            device: cm.get("device")?.as_str()?.to_string(),
            hbm_bw_bytes_per_s: cm.get("hbm_bw_bytes_per_s")?.as_f64()?,
            int8_ops_per_s: cm.get("int8_ops_per_s")?.as_f64()?,
            bf16_ops_per_s: cm.get("bf16_ops_per_s")?.as_f64()?,
            bytes_per_weight: cm
                .get("bytes_per_weight")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_f64()?)))
                .collect::<Result<_>>()?,
            kernel_launch_s: cm.get("kernel_launch_s")?.as_f64()?,
            drafter_cost_per_token_s: cm.get("drafter_cost_per_token_s")?.as_f64()?,
        };

        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            let c = mj.get("config")?;
            let cfg = ModelCfg {
                name: c.get("name")?.as_str()?.to_string(),
                vocab_size: c.get("vocab_size")?.as_usize()?,
                d_model: c.get("d_model")?.as_usize()?,
                n_layers: c.get("n_layers")?.as_usize()?,
                n_heads: c.get("n_heads")?.as_usize()?,
                ffn_dim: c.get("ffn_dim")?.as_usize()?,
                max_seq: c.get("max_seq")?.as_usize()?,
                prefill_len: c.get("prefill_len")?.as_usize()?,
                gamma_max: c.get("gamma_max")?.as_usize()?,
                head_dim: mj.get("head_dim")?.as_usize()?,
            };
            let weights = mj
                .get("weights")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                .collect::<Result<_>>()?;
            let mut artifacts = Vec::new();
            for aj in mj.get("artifacts")?.as_arr()? {
                let cj = aj.get("cost")?;
                artifacts.push(ArtifactEntry {
                    name: aj.get("name")?.as_str()?.to_string(),
                    variant: aj.get("variant")?.as_str()?.to_string(),
                    fn_name: aj.get("fn")?.as_str()?.to_string(),
                    batch: aj.get("batch")?.as_usize()?,
                    chunk_len: aj.get("chunk_len")?.as_usize()?,
                    n_layers: aj.get("n_layers")?.as_usize()?,
                    path: root.join(aj.get("path")?.as_str()?),
                    weights_file: aj.get("weights_file")?.as_str()?.to_string(),
                    weight_args: aj
                        .get("weight_args")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_str().map(String::from))
                        .collect::<std::result::Result<_, _>>()?,
                    cost: ArtifactCost {
                        weight_bytes_device: cj.get("weight_bytes_device")?.as_f64()?,
                        kv_bytes: cj.get("kv_bytes")?.as_f64()?,
                        act_bytes: cj.get("act_bytes")?.as_f64()?,
                        macs: cj.get("macs")?.as_f64()?,
                        tokens_per_call: cj.get("tokens_per_call")?.as_f64()?,
                    },
                });
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    cfg,
                    weights,
                    artifacts,
                    goldens_path: root.join(mj.get("goldens")?.as_str()?),
                    calibration_path: root.join(mj.get("calibration")?.as_str()?),
                },
            );
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            tokenizer_path: root.join(j.get("tokenizer")?.as_str()?),
            workloads_path: root.join(j.get("workloads")?.as_str()?),
            evalset_path: root.join(j.get("evalset")?.as_str()?),
            cost_model,
            models,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        crate::util::json::parse(
            r#"{
              "version": 1, "tokenizer": "tok.json",
              "workloads": "w.json", "evalset": "e.json",
              "cost_model": {
                "device": "sim", "hbm_bw_bytes_per_s": 1.6e12,
                "int8_ops_per_s": 3.76e14, "bf16_ops_per_s": 1.88e14,
                "bytes_per_weight": {"fp32": 2, "w8a8": 1},
                "kernel_launch_s": 2e-5, "drafter_cost_per_token_s": 1e-6
              },
              "models": {
                "m": {
                  "config": {"name":"m","vocab_size":320,"d_model":64,
                    "n_layers":2,"n_heads":2,"ffn_dim":128,"max_seq":128,
                    "prefill_len":64,"gamma_max":4,"rope_theta":10000.0},
                  "head_dim": 32,
                  "weights": {"fp32":"m/weights_fp32.npz","w8a8":"m/weights_w8a8.npz"},
                  "calibration": "m/calibration.json",
                  "goldens": "m/goldens.json",
                  "artifacts": [
                    {"name":"fp32_verify_b1","variant":"fp32","fn":"verify",
                     "batch":1,"chunk_len":5,"n_layers":2,
                     "path":"m/fp32_verify_b1.hlo.txt",
                     "weights_file":"m/weights_fp32.npz",
                     "weight_args":["embed","layers.0.ln1"],
                     "data_args":[],"outputs":[],
                     "cost":{"weight_bytes_device":1000,"kv_bytes":2000,
                             "act_bytes":100,"macs":5000,"tokens_per_call":5}}
                  ]
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(Path::new("/tmp/x"), &sample_manifest()).unwrap();
        assert_eq!(m.cost_model.device, "sim");
        let me = m.model("m").unwrap();
        assert_eq!(me.cfg.verify_len(), 5);
        assert_eq!(me.cfg.head_dim, 32);
        let a = me.artifact("fp32", "verify", 1).unwrap();
        assert_eq!(a.chunk_len, 5);
        assert_eq!(a.weight_args.len(), 2);
        assert_eq!(a.cost.kv_bytes, 2000.0);
        assert!(me.artifact("w8a8", "verify", 1).is_err());
        assert!(m.model("nope").is_err());
        assert_eq!(me.buckets("fp32", "verify"), vec![1]);
    }

    #[test]
    fn best_bucket_selects_smallest_fit_or_largest() {
        let m = Manifest::from_json(Path::new("/tmp/x"), &sample_manifest()).unwrap();
        let me = m.model("m").unwrap();
        // only b1 exported: exact fit at 1, largest-available for oversize
        assert_eq!(me.best_bucket("fp32", "verify", 1), Some(1));
        assert_eq!(me.best_bucket("fp32", "verify", 3), Some(1));
        // unknown (variant, fn): no buckets at all
        assert_eq!(me.best_bucket("w8a8", "verify", 1), None);
        assert_eq!(me.best_bucket("fp32", "decode", 1), None);
    }

    #[test]
    fn n_params_formula() {
        let m = Manifest::from_json(Path::new("/"), &sample_manifest()).unwrap();
        let cfg = &m.model("m").unwrap().cfg;
        // 320*64 + 2*(4*64^2 + 3*64*128 + 2*64) + 64
        assert_eq!(cfg.n_params(), 320 * 64 + 2 * (4 * 4096 + 3 * 8192 + 128) + 64);
    }

    #[test]
    fn rejects_bad_version() {
        let mut j = sample_manifest();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(2.0));
        }
        assert!(Manifest::from_json(Path::new("/"), &j).is_err());
    }
}

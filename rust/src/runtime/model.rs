//! Per-model runtime: owns the weight stores and lazily-compiled
//! executables for every (variant, fn, batch-bucket) the engine asks for,
//! plus a pool of bucket-shaped KV scratch caches so the per-step
//! gather/run/scatter pipeline never allocates on the hot path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use super::artifacts::{CostModelCfg, Manifest, ModelCfg, ModelEntry};
use super::client::{CompiledChunk, WeightStore, XlaRuntime};
use super::tensor::Tensor;

/// Max pooled scratch pairs per (n_layers, bucket) shape. Two is enough for
/// the engine's one-in-flight execution; anything beyond is dropped.
const SCRATCH_POOL_CAP: usize = 2;

/// Handle to one loaded model (e.g. "qwen3-like"): weights resident on the
/// device, executables compiled on first use and cached.
pub struct ModelRuntime {
    pub rt: Rc<XlaRuntime>,
    pub entry: ModelEntry,
    weights: RefCell<HashMap<String, Rc<WeightStore>>>, // npz path -> store
    execs: RefCell<HashMap<String, Rc<CompiledChunk>>>, // artifact name -> exec
    /// Reusable KV cache pairs keyed by (variant, n_layers, batch-bucket).
    /// Pooled tensors are *dirty*: callers must overwrite every position
    /// they expect the model to read. The gather path copies each row's
    /// committed prefix, so positions at or past a row's `kv_len` — and
    /// whole rows outside the gathered set — only ever hold stale finite
    /// values, which causally-masked, batch-independent per-row attention
    /// never reads. Keying by variant keeps the fidelity governor's
    /// shadow-audit scratch (reference variant) and any demoted-class
    /// traffic from thrashing the primary variant's hot pair — each
    /// (variant, depth, bucket) shape the engine alternates between keeps
    /// its own warm pool. The nesting (variant name outside, shape inside)
    /// lets the hot path look up by `&str` without allocating a key.
    #[allow(clippy::type_complexity)]
    scratch: RefCell<HashMap<String, HashMap<(usize, usize), Vec<(Tensor<f32>, Tensor<f32>)>>>>,
    /// Device pricing constants, carried from the manifest so the engine's
    /// step planner can cost candidate sub-batch plans without re-loading it.
    cost_model: CostModelCfg,
    manifest_root: std::path::PathBuf,
}

impl ModelRuntime {
    pub fn load(rt: Rc<XlaRuntime>, manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        Ok(ModelRuntime {
            rt,
            entry,
            weights: RefCell::new(HashMap::new()),
            execs: RefCell::new(HashMap::new()),
            scratch: RefCell::new(HashMap::new()),
            cost_model: manifest.cost_model.clone(),
            manifest_root: manifest.root.clone(),
        })
    }

    pub fn cfg(&self) -> &ModelCfg {
        &self.entry.cfg
    }

    /// Pricing constants of the simulated device this manifest targets.
    pub fn cost_model(&self) -> &CostModelCfg {
        &self.cost_model
    }

    /// Smallest exported bucket fitting `n` rows (see
    /// [`ModelEntry::best_bucket`]).
    pub fn best_bucket(&self, variant: &str, fn_name: &str, n: usize) -> Option<usize> {
        self.entry.best_bucket(variant, fn_name, n)
    }

    /// Weight store for an artifact's npz (loaded once, shared).
    pub fn weights_for(&self, weights_file: &str) -> Result<Rc<WeightStore>> {
        if let Some(w) = self.weights.borrow().get(weights_file) {
            return Ok(Rc::clone(w));
        }
        let store = Rc::new(self.rt.load_weights(&self.manifest_root.join(weights_file))?);
        self.weights
            .borrow_mut()
            .insert(weights_file.to_string(), Rc::clone(&store));
        Ok(store)
    }

    /// Compiled executable for (variant, fn, batch), compiled on first use.
    pub fn chunk(&self, variant: &str, fn_name: &str, batch: usize) -> Result<Rc<CompiledChunk>> {
        let art = self.entry.artifact(variant, fn_name, batch)?.clone();
        if let Some(c) = self.execs.borrow().get(&art.name) {
            return Ok(Rc::clone(c));
        }
        let cfg = &self.entry.cfg;
        let compiled = Rc::new(self.rt.compile(
            &art, cfg.vocab_size, cfg.head_dim, cfg.max_seq, cfg.n_heads,
        )?);
        self.execs
            .borrow_mut()
            .insert(art.name.clone(), Rc::clone(&compiled));
        Ok(compiled)
    }

    /// Convenience: run one chunk end-to-end (compile + weights cached).
    pub fn run_chunk(
        &self,
        variant: &str,
        fn_name: &str,
        batch: usize,
        tokens: &[i32],
        k: &Tensor<f32>,
        v: &Tensor<f32>,
        pos: &[i32],
    ) -> Result<super::client::ChunkOutput> {
        let chunk = self.chunk(variant, fn_name, batch)?;
        let weights = self.weights_for(&chunk.entry.weights_file)?;
        chunk.run(&self.rt, &weights, tokens, k, v, pos)
    }

    /// Host bytes of one single-row KV cache *pair* (`[L, 1, H, S, hd]`
    /// f32 k + v) at the given depth — what one prefix-cache segment costs
    /// resident, and the unit budget knobs are naturally expressed in.
    pub fn cache_row_bytes(&self, n_layers: usize) -> usize {
        let cfg = &self.entry.cfg;
        2 * n_layers * cfg.n_heads * cfg.max_seq * cfg.head_dim
            * std::mem::size_of::<f32>()
    }

    /// Fresh zeroed KV cache pair for a (variant, batch) shape.
    pub fn empty_cache(
        &self,
        n_layers: usize,
        batch: usize,
    ) -> (Tensor<f32>, Tensor<f32>) {
        let cfg = &self.entry.cfg;
        let dims = [n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        (Tensor::zeros(&dims), Tensor::zeros(&dims))
    }

    /// Borrow a bucket-shaped KV scratch pair from the `(variant, n_layers,
    /// batch)` pool (allocating on first use). Contents are *dirty* — see
    /// the `scratch` field docs. Return it with
    /// [`ModelRuntime::return_scratch`] under the same variant when done.
    pub fn take_scratch(&self, variant: &str, n_layers: usize,
                        batch: usize) -> (Tensor<f32>, Tensor<f32>) {
        if let Some(pair) = self
            .scratch
            .borrow_mut()
            .get_mut(variant)
            .and_then(|shapes| shapes.get_mut(&(n_layers, batch)))
            .and_then(Vec::pop)
        {
            return pair;
        }
        self.empty_cache(n_layers, batch)
    }

    /// Hand a scratch pair (or an advanced cache of the same shape) back to
    /// its variant's pool; dropped silently once the per-shape cap is
    /// reached.
    pub fn return_scratch(&self, variant: &str, k: Tensor<f32>, v: Tensor<f32>) {
        if k.dims.len() != 5 || k.dims != v.dims {
            return; // not a cache-shaped pair; refuse silently
        }
        let mut pool = self.scratch.borrow_mut();
        if !pool.contains_key(variant) {
            // allocate the variant key once, on first sight
            pool.insert(variant.to_string(), HashMap::new());
        }
        let slot = pool
            .get_mut(variant)
            .expect("just ensured")
            .entry((k.dims[0], k.dims[1]))
            .or_default();
        if slot.len() < SCRATCH_POOL_CAP {
            slot.push((k, v));
        }
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.execs.borrow().len()
    }
}

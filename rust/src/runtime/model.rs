//! Per-model runtime: owns the weight stores and lazily-compiled
//! executables for every (variant, fn, batch-bucket) the engine asks for.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use super::artifacts::{Manifest, ModelCfg, ModelEntry};
use super::client::{CompiledChunk, WeightStore, XlaRuntime};

/// Handle to one loaded model (e.g. "qwen3-like"): weights resident on the
/// device, executables compiled on first use and cached.
pub struct ModelRuntime {
    pub rt: Rc<XlaRuntime>,
    pub entry: ModelEntry,
    weights: RefCell<HashMap<String, Rc<WeightStore>>>, // npz path -> store
    execs: RefCell<HashMap<String, Rc<CompiledChunk>>>, // artifact name -> exec
    manifest_root: std::path::PathBuf,
}

impl ModelRuntime {
    pub fn load(rt: Rc<XlaRuntime>, manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        Ok(ModelRuntime {
            rt,
            entry,
            weights: RefCell::new(HashMap::new()),
            execs: RefCell::new(HashMap::new()),
            manifest_root: manifest.root.clone(),
        })
    }

    pub fn cfg(&self) -> &ModelCfg {
        &self.entry.cfg
    }

    /// Weight store for an artifact's npz (loaded once, shared).
    pub fn weights_for(&self, weights_file: &str) -> Result<Rc<WeightStore>> {
        if let Some(w) = self.weights.borrow().get(weights_file) {
            return Ok(Rc::clone(w));
        }
        let store = Rc::new(self.rt.load_weights(&self.manifest_root.join(weights_file))?);
        self.weights
            .borrow_mut()
            .insert(weights_file.to_string(), Rc::clone(&store));
        Ok(store)
    }

    /// Compiled executable for (variant, fn, batch), compiled on first use.
    pub fn chunk(&self, variant: &str, fn_name: &str, batch: usize) -> Result<Rc<CompiledChunk>> {
        let art = self.entry.artifact(variant, fn_name, batch)?.clone();
        if let Some(c) = self.execs.borrow().get(&art.name) {
            return Ok(Rc::clone(c));
        }
        let cfg = &self.entry.cfg;
        let compiled = Rc::new(self.rt.compile(
            &art, cfg.vocab_size, cfg.head_dim, cfg.max_seq, cfg.n_heads,
        )?);
        self.execs
            .borrow_mut()
            .insert(art.name.clone(), Rc::clone(&compiled));
        Ok(compiled)
    }

    /// Convenience: run one chunk end-to-end (compile + weights cached).
    pub fn run_chunk(
        &self,
        variant: &str,
        fn_name: &str,
        batch: usize,
        tokens: &[i32],
        k: &super::tensor::Tensor<f32>,
        v: &super::tensor::Tensor<f32>,
        pos: &[i32],
    ) -> Result<super::client::ChunkOutput> {
        let chunk = self.chunk(variant, fn_name, batch)?;
        let weights = self.weights_for(&chunk.entry.weights_file)?;
        chunk.run(&self.rt, &weights, tokens, k, v, pos)
    }

    /// Fresh zeroed KV cache pair for a (variant, batch) shape.
    pub fn empty_cache(
        &self,
        n_layers: usize,
        batch: usize,
    ) -> (super::tensor::Tensor<f32>, super::tensor::Tensor<f32>) {
        let cfg = &self.entry.cfg;
        let dims = [n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        (
            super::tensor::Tensor::zeros(&dims),
            super::tensor::Tensor::zeros(&dims),
        )
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.execs.borrow().len()
    }
}

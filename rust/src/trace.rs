//! Flight recorder: low-overhead structured tracing for per-request span
//! attribution.
//!
//! # Event taxonomy
//!
//! Every lifecycle edge of a request emits a [`TraceEvent`] keyed by the
//! request's ticket id (the causal key) plus the replica id and a monotonic
//! microsecond timestamp relative to the process epoch:
//!
//! | kind              | emitted by            | meaning                                   |
//! |-------------------|-----------------------|-------------------------------------------|
//! | `Enqueued`        | `scheduler.rs`        | request entered the admission queue       |
//! | `Dispatched`      | `cluster.rs`          | dispatcher chose a replica (home/stolen)  |
//! | `Admitted`        | `engine.rs`           | row + window slot granted; prefix hit len |
//! | `PrefillChunk`    | `engine.rs`           | one prefill chunk (ridden/dedicated/shed) |
//! | `Plan`            | `engine.rs`           | step planner chose N sub-batches          |
//! | `ChunkExec`       | `engine.rs`           | one chunk program call (variant/fn/bucket)|
//! | `Scatter`         | `engine.rs`           | sub-batch KV scatter-back done            |
//! | `Commit`          | `engine.rs`           | per-row accepted-token commit             |
//! | `Audit`           | `engine.rs`           | governor shadow audit ran                 |
//! | `Demote`/`Promote`| `engine.rs`           | governor precision transition             |
//! | `Cancelled`       | `engine.rs`           | request cancelled                         |
//! | `Finished`        | `router.rs`           | completion delivered to the waiter        |
//!
//! Step-scoped events (`Plan`, `ChunkExec`, `Scatter`, `Audit`,
//! `Demote`/`Promote`) carry ticket 0: they belong to a replica track, not a
//! request lane.
//!
//! # Overhead contract
//!
//! Disabled (`EngineConfig.trace == false`, the default): every record site is
//! one `Relaxed` load of an `AtomicBool` plus a branch — no allocation, no
//! clock read, no TLS access. The mock-sim differential in
//! `tests/bench_mock_sim.rs` holds the output bit-identical and the modeled
//! cost equal with tracing off.
//!
//! Enabled: an event is one clock read plus five atomic stores into a
//! per-thread single-producer seqlock ring ([`RING_CAP`] slots, overwrite
//! oldest). Readers never block writers; a drain that races a wrap or an
//! in-flight write counts the slot into `trace_dropped_events` instead of
//! surfacing a torn event. The invariant `recorded == drained + dropped` is
//! held by a concurrent property test in this module.
//!
//! # Export
//!
//! [`FlightRecorder::chrome_trace_json`] renders the drained stream as Chrome
//! trace-event JSON (Perfetto-loadable): one process track per replica,
//! `ChunkExec` as complete slices on the replica track, and one async-span
//! lane per request (`b`/`n`/`e` events keyed by ticket id) covering
//! Enqueued → … → Finished.

use std::cell::RefCell;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Slots per per-thread ring. Power of two (index masking).
pub const RING_CAP: usize = 4096;

/// Function codes carried in `ChunkExec` payloads.
pub const FUNC_DECODE: u8 = 0;
pub const FUNC_VERIFY: u8 = 1;
pub const FUNC_PREFILL: u8 = 2;
pub const FUNC_AUDIT: u8 = 3;
const FUNC_NAMES: [&str; 4] = ["decode", "verify", "prefill", "audit"];

/// Name of a `ChunkExec` function code.
pub fn func_name(func: u8) -> &'static str {
    FUNC_NAMES.get(func as usize).copied().unwrap_or("other")
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (pinned at first use; the
/// recorder constructor pins it early so all rings share one origin).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// How a prefill chunk was executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillMode {
    /// Rode a spare slot of a decode/verify sub-batch the step ran anyway.
    Ridden = 0,
    /// Needed a dedicated prefill-program call (a counted decode stall).
    Dedicated = 1,
    /// Dedicated call shed to the smaller verify program under queue pressure.
    Shed = 2,
}

impl PrefillMode {
    fn name(self) -> &'static str {
        match self {
            PrefillMode::Ridden => "ridden",
            PrefillMode::Dedicated => "dedicated",
            PrefillMode::Shed => "shed",
        }
    }
}

/// A typed span event. Payload fields are packed into one `u64` on the wire
/// (see `payload()` / `decode()`), so the ring slot stays four words.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the admission queue.
    Enqueued,
    /// Dispatcher routed the request to `replica` (stolen = spilled off home).
    Dispatched { replica: u32, stolen: bool },
    /// Admission granted; `hit_tokens` spliced from the prefix cache.
    Admitted { hit_tokens: u32 },
    /// One prefill chunk executed.
    PrefillChunk { mode: PrefillMode },
    /// Step planner partitioned the active rows into `subbatches` calls.
    Plan { subbatches: u32 },
    /// One chunk program call: interned variant id, function code, batch
    /// bucket, wall time in microseconds.
    ChunkExec { variant: u8, func: u8, bucket: u16, wall_us: u32 },
    /// Sub-batch scatter-back completed.
    Scatter,
    /// Row committed `accepted` tokens this step.
    Commit { accepted: u32 },
    /// Governor shadow audit ran on a sub-batch.
    Audit,
    /// Governor demoted a request class to the reference precision.
    Demote,
    /// Governor re-promoted a request class to the quantized variant.
    Promote,
    /// Request cancelled.
    Cancelled,
    /// Completion delivered to the waiting client.
    Finished,
}

impl EventKind {
    fn tag(self) -> u64 {
        match self {
            EventKind::Enqueued => 1,
            EventKind::Dispatched { .. } => 2,
            EventKind::Admitted { .. } => 3,
            EventKind::PrefillChunk { .. } => 4,
            EventKind::Plan { .. } => 5,
            EventKind::ChunkExec { .. } => 6,
            EventKind::Scatter => 7,
            EventKind::Commit { .. } => 8,
            EventKind::Audit => 9,
            EventKind::Demote => 10,
            EventKind::Promote => 11,
            EventKind::Cancelled => 12,
            EventKind::Finished => 13,
        }
    }

    /// Stable display name (used for Chrome `name` fields).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueued => "enqueued",
            EventKind::Dispatched { .. } => "dispatched",
            EventKind::Admitted { .. } => "admitted",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::Plan { .. } => "plan",
            EventKind::ChunkExec { .. } => "chunk_exec",
            EventKind::Scatter => "scatter",
            EventKind::Commit { .. } => "commit",
            EventKind::Audit => "audit",
            EventKind::Demote => "demote",
            EventKind::Promote => "promote",
            EventKind::Cancelled => "cancelled",
            EventKind::Finished => "finished",
        }
    }

    /// Tie-break rank for equal-timestamp sorting: pipeline order, so a
    /// drained stream reads causally even at microsecond granularity.
    fn rank(self) -> u8 {
        match self {
            EventKind::Dispatched { .. } => 0,
            EventKind::Enqueued => 1,
            EventKind::Admitted { .. } => 2,
            EventKind::PrefillChunk { .. } => 3,
            EventKind::Plan { .. } => 4,
            EventKind::ChunkExec { .. } => 5,
            EventKind::Scatter => 6,
            EventKind::Audit => 7,
            EventKind::Commit { .. } => 8,
            EventKind::Demote => 9,
            EventKind::Promote => 10,
            EventKind::Cancelled => 11,
            EventKind::Finished => 12,
        }
    }

    fn payload(self) -> u64 {
        match self {
            EventKind::Dispatched { replica, stolen } => {
                ((replica as u64) << 1) | stolen as u64
            }
            EventKind::Admitted { hit_tokens } => hit_tokens as u64,
            EventKind::PrefillChunk { mode } => mode as u64,
            EventKind::Plan { subbatches } => subbatches as u64,
            EventKind::ChunkExec { variant, func, bucket, wall_us } => {
                variant as u64
                    | (func as u64) << 8
                    | (bucket as u64) << 16
                    | (wall_us as u64) << 32
            }
            EventKind::Commit { accepted } => accepted as u64,
            _ => 0,
        }
    }

    fn decode(tag: u64, payload: u64) -> Option<EventKind> {
        Some(match tag {
            1 => EventKind::Enqueued,
            2 => EventKind::Dispatched {
                replica: (payload >> 1) as u32,
                stolen: payload & 1 != 0,
            },
            3 => EventKind::Admitted { hit_tokens: payload as u32 },
            4 => EventKind::PrefillChunk {
                mode: match payload {
                    0 => PrefillMode::Ridden,
                    1 => PrefillMode::Dedicated,
                    2 => PrefillMode::Shed,
                    _ => return None,
                },
            },
            5 => EventKind::Plan { subbatches: payload as u32 },
            6 => EventKind::ChunkExec {
                variant: payload as u8,
                func: (payload >> 8) as u8,
                bucket: (payload >> 16) as u16,
                wall_us: (payload >> 32) as u32,
            },
            7 => EventKind::Scatter,
            8 => EventKind::Commit { accepted: payload as u32 },
            9 => EventKind::Audit,
            10 => EventKind::Demote,
            11 => EventKind::Promote,
            12 => EventKind::Cancelled,
            13 => EventKind::Finished,
            _ => return None,
        })
    }
}

/// A drained, decoded event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Microseconds since the process trace epoch.
    pub ts_us: u64,
    /// Request ticket id; 0 for step-scoped (replica-track) events.
    pub ticket: u64,
    /// Replica that recorded the event.
    pub replica: u32,
    pub kind: EventKind,
}

/// One seqlock slot: sequence word + four payload words. Even seq 2i+2 means
/// generation i is published; odd means a write is in flight.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; 4],
}

/// Single-producer ring. Only the owning thread writes; any thread may read.
struct Ring {
    slots: Box<[Slot]>,
    head: AtomicU64,
}

impl Ring {
    fn new() -> Self {
        let slots = (0..RING_CAP)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                words: [
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                ],
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring { slots, head: AtomicU64::new(0) }
    }

    /// Publish one event (owner thread only). Seqlock write protocol: mark
    /// the slot odd, release-fence, store the words, then the even seq store
    /// (Release) publishes them; the head bump (Release) makes the slot
    /// visible to drains.
    fn push(&self, w: [u64; 4]) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[h as usize & (RING_CAP - 1)];
        slot.seq.store(2 * h + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        for (dst, src) in slot.words.iter().zip(w) {
            dst.store(src, Ordering::Relaxed);
        }
        slot.seq.store(2 * h + 2, Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Read generation `i` if still intact; `None` on overwrite or a torn
    /// (in-flight) write.
    fn read(&self, i: u64) -> Option<[u64; 4]> {
        let slot = &self.slots[i as usize & (RING_CAP - 1)];
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 != 2 * i + 2 {
            return None;
        }
        let w = [
            slot.words[0].load(Ordering::Relaxed),
            slot.words[1].load(Ordering::Relaxed),
            slot.words[2].load(Ordering::Relaxed),
            slot.words[3].load(Ordering::Relaxed),
        ];
        fence(Ordering::Acquire);
        let s2 = slot.seq.load(Ordering::Relaxed);
        (s2 == s1).then_some(w)
    }
}

struct RingEntry {
    ring: Arc<Ring>,
    /// Next generation to drain from this ring.
    tail: u64,
}

thread_local! {
    /// Per-thread rings, keyed by recorder id (a process can host several
    /// recorders across tests; each gets its own ring on each thread).
    static TLS_RINGS: RefCell<Vec<(u64, Arc<Ring>)>> = const { RefCell::new(Vec::new()) };
}

fn next_recorder_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// The trace sink: owns the per-thread rings, the enable flag, the drop
/// counter, and the interned variant-name table.
pub struct FlightRecorder {
    enabled: AtomicBool,
    dropped: AtomicU64,
    id: u64,
    names: Mutex<Vec<String>>,
    rings: Mutex<Vec<RingEntry>>,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("enabled", &self.enabled.load(Ordering::Relaxed))
            .field("dropped", &self.dropped.load(Ordering::Relaxed))
            .finish()
    }
}

impl FlightRecorder {
    pub fn new(enabled: bool) -> Self {
        epoch(); // pin the time origin before any thread records
        FlightRecorder {
            enabled: AtomicBool::new(enabled),
            dropped: AtomicU64::new(0),
            id: next_recorder_id(),
            names: Mutex::new(Vec::new()),
            rings: Mutex::new(Vec::new()),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Cumulative events lost to ring wrap or torn reads.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Intern a variant name, returning a stable small id for `ChunkExec`
    /// payloads. Caps at 255 ("other").
    pub fn intern(&self, name: &str) -> u8 {
        let mut names = self.names.lock().unwrap();
        if let Some(i) = names.iter().position(|n| n == name) {
            return i as u8;
        }
        if names.len() >= 255 {
            return 255;
        }
        names.push(name.to_string());
        (names.len() - 1) as u8
    }

    fn variant_names(&self) -> Vec<String> {
        self.names.lock().unwrap().clone()
    }

    /// This thread's ring for this recorder, registering it on first use.
    fn thread_ring(&self) -> Arc<Ring> {
        TLS_RINGS.with(|cell| {
            let mut rings = cell.borrow_mut();
            if let Some((_, r)) = rings.iter().find(|(id, _)| *id == self.id) {
                return r.clone();
            }
            let ring = Arc::new(Ring::new());
            rings.push((self.id, ring.clone()));
            self.rings
                .lock()
                .unwrap()
                .push(RingEntry { ring: ring.clone(), tail: 0 });
            ring
        })
    }

    /// Record one event. Callers go through [`TraceHandle::record`], which
    /// branches on the enable flag first.
    pub fn record_raw(&self, ticket: u64, replica: u32, ts_us: u64, kind: EventKind) {
        let w = [
            ticket,
            ts_us,
            kind.tag() | (replica as u64) << 8,
            kind.payload(),
        ];
        self.thread_ring().push(w);
    }

    /// Drain all rings since the previous drain. Returns the decoded events
    /// sorted by `(ts_us, pipeline rank, ticket)` plus the cumulative drop
    /// counter.
    pub fn drain(&self) -> (Vec<TraceEvent>, u64) {
        let mut out = Vec::new();
        {
            let mut rings = self.rings.lock().unwrap();
            for entry in rings.iter_mut() {
                let head = entry.ring.head.load(Ordering::Acquire);
                let lo = head.saturating_sub(RING_CAP as u64).max(entry.tail);
                if lo > entry.tail {
                    self.dropped.fetch_add(lo - entry.tail, Ordering::Relaxed);
                }
                for i in lo..head {
                    match entry.ring.read(i).and_then(decode_words) {
                        Some(ev) => out.push(ev),
                        None => {
                            self.dropped.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                entry.tail = head;
            }
        }
        // Stable sort: per-ring (per-thread) order is preserved at equal keys.
        out.sort_by_key(|ev| (ev.ts_us, ev.kind.rank(), ev.ticket));
        (out, self.dropped())
    }

    /// Drain and render as Chrome trace-event JSON.
    pub fn chrome_trace_json(&self) -> Json {
        let names = self.variant_names();
        let (events, dropped) = self.drain();
        chrome_trace(&events, &names, dropped, self.enabled())
    }
}

fn decode_words(w: [u64; 4]) -> Option<TraceEvent> {
    let kind = EventKind::decode(w[2] & 0xff, w[3])?;
    Some(TraceEvent {
        ts_us: w[1],
        ticket: w[0],
        replica: (w[2] >> 8) as u32,
        kind,
    })
}

/// Cheap cloneable recording capability: a recorder reference plus the
/// replica id stamped onto every event.
#[derive(Debug, Clone)]
pub struct TraceHandle {
    rec: Option<Arc<FlightRecorder>>,
    replica: u32,
}

impl Default for TraceHandle {
    fn default() -> Self {
        TraceHandle::disabled()
    }
}

impl TraceHandle {
    pub fn new(rec: Arc<FlightRecorder>, replica: u32) -> Self {
        TraceHandle { rec: Some(rec), replica }
    }

    /// A handle that records nothing and holds no recorder.
    pub fn disabled() -> Self {
        TraceHandle { rec: None, replica: 0 }
    }

    /// Same recorder, different replica id stamp.
    pub fn for_replica(&self, replica: u32) -> Self {
        TraceHandle { rec: self.rec.clone(), replica }
    }

    /// The single-branch off path: one Relaxed atomic load when a recorder
    /// is attached, a `None` check when not.
    #[inline]
    pub fn enabled(&self) -> bool {
        matches!(&self.rec, Some(r) if r.enabled())
    }

    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.rec.as_ref()
    }

    /// Capture a timestamp only if tracing is live — lets callers pin an
    /// event's time before its ticket id is known, with zero cost when off.
    #[inline]
    pub fn stamp(&self) -> Option<u64> {
        self.enabled().then(now_us)
    }

    /// Record an event now. When disabled this is the contract's single
    /// atomic branch: no allocation, no clock read, no TLS access.
    #[inline]
    pub fn record(&self, ticket: u64, kind: EventKind) {
        if !self.enabled() {
            return;
        }
        self.record_slow(now_us(), ticket, kind);
    }

    /// Record an event at a pre-captured [`stamp`](Self::stamp) timestamp.
    #[inline]
    pub fn record_at(&self, ts_us: u64, ticket: u64, kind: EventKind) {
        if !self.enabled() {
            return;
        }
        self.record_slow(ts_us, ticket, kind);
    }

    #[cold]
    fn record_slow(&self, ts_us: u64, ticket: u64, kind: EventKind) {
        if let Some(rec) = &self.rec {
            rec.record_raw(ticket, self.replica, ts_us, kind);
        }
    }

    /// Intern a variant name (0 when disabled: payloads are never drained).
    pub fn intern(&self, name: &str) -> u8 {
        match &self.rec {
            Some(rec) if rec.enabled() => rec.intern(name),
            _ => 0,
        }
    }
}

fn variant_label(names: &[String], id: u8) -> String {
    names
        .get(id as usize)
        .cloned()
        .unwrap_or_else(|| format!("v{id}"))
}

/// Render a drained event stream as Chrome trace-event JSON (the
/// `traceEvents` array format Perfetto loads). One process (`pid`) per
/// replica; `ChunkExec` becomes a complete (`X`) slice on the replica track;
/// each request ticket becomes an async nestable lane (`b`/`n`/`e` keyed by
/// the ticket id) spanning its first to last event.
pub fn chrome_trace(
    events: &[TraceEvent],
    variant_names: &[String],
    dropped: u64,
    enabled: bool,
) -> Json {
    let mut out: Vec<Json> = Vec::new();

    // Replica set: the recording replica, plus dispatch targets.
    let mut replicas: Vec<u32> = Vec::new();
    for ev in events {
        let pid = match ev.kind {
            EventKind::Dispatched { replica, .. } => replica,
            _ => ev.replica,
        };
        if !replicas.contains(&pid) {
            replicas.push(pid);
        }
    }
    replicas.sort_unstable();
    for r in &replicas {
        out.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::Num(*r as f64)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("replica {r}")))]),
            ),
        ]));
    }

    // First/last event index per ticket, to open/close the async lanes.
    let mut first: std::collections::BTreeMap<u64, usize> = Default::default();
    let mut last: std::collections::BTreeMap<u64, usize> = Default::default();
    for (i, ev) in events.iter().enumerate() {
        if ev.ticket == 0 {
            continue;
        }
        first.entry(ev.ticket).or_insert(i);
        last.insert(ev.ticket, i);
    }

    for (i, ev) in events.iter().enumerate() {
        let pid = match ev.kind {
            EventKind::Dispatched { replica, .. } => replica,
            _ => ev.replica,
        };
        if ev.ticket == 0 {
            // Step-scoped: replica track.
            match ev.kind {
                EventKind::ChunkExec { variant, func, bucket, wall_us } => {
                    out.push(Json::obj(vec![
                        ("ph", Json::str("X")),
                        (
                            "name",
                            Json::str(format!(
                                "exec {} b{} {}",
                                func_name(func),
                                bucket,
                                variant_label(variant_names, variant)
                            )),
                        ),
                        ("cat", Json::str("step")),
                        ("pid", Json::Num(pid as f64)),
                        ("tid", Json::Num(0.0)),
                        (
                            "ts",
                            Json::Num(ev.ts_us.saturating_sub(wall_us as u64) as f64),
                        ),
                        ("dur", Json::Num(wall_us as f64)),
                        (
                            "args",
                            Json::obj(vec![
                                (
                                    "variant",
                                    Json::str(variant_label(variant_names, variant)),
                                ),
                                ("fn", Json::str(func_name(func))),
                                ("bucket", Json::Num(bucket as f64)),
                            ]),
                        ),
                    ]));
                }
                _ => {
                    let mut args = vec![];
                    if let EventKind::Plan { subbatches } = ev.kind {
                        args.push(("subbatches", Json::Num(subbatches as f64)));
                    }
                    out.push(Json::obj(vec![
                        ("ph", Json::str("i")),
                        ("name", Json::str(ev.kind.name())),
                        ("cat", Json::str("step")),
                        ("s", Json::str("t")),
                        ("pid", Json::Num(pid as f64)),
                        ("tid", Json::Num(0.0)),
                        ("ts", Json::Num(ev.ts_us as f64)),
                        ("args", Json::obj(args)),
                    ]));
                }
            }
            continue;
        }

        let id = Json::str(format!("{}", ev.ticket));
        if first.get(&ev.ticket) == Some(&i) {
            out.push(Json::obj(vec![
                ("ph", Json::str("b")),
                ("cat", Json::str("request")),
                ("id", id.clone()),
                ("name", Json::str(format!("request {}", ev.ticket))),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(ev.ts_us as f64)),
            ]));
        }

        let mut args: Vec<(&str, Json)> = vec![("ticket", Json::Num(ev.ticket as f64))];
        match ev.kind {
            EventKind::Dispatched { replica, stolen } => {
                args.push(("target", Json::Num(replica as f64)));
                args.push(("stolen", Json::Bool(stolen)));
            }
            EventKind::Admitted { hit_tokens } => {
                args.push(("hit_tokens", Json::Num(hit_tokens as f64)));
            }
            EventKind::PrefillChunk { mode } => {
                args.push(("mode", Json::str(mode.name())));
            }
            EventKind::Commit { accepted } => {
                args.push(("accepted", Json::Num(accepted as f64)));
            }
            _ => {}
        }
        out.push(Json::obj(vec![
            ("ph", Json::str("n")),
            ("cat", Json::str("request")),
            ("id", id.clone()),
            ("name", Json::str(ev.kind.name())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("ts", Json::Num(ev.ts_us as f64)),
            ("args", Json::obj(args)),
        ]));

        if last.get(&ev.ticket) == Some(&i) {
            out.push(Json::obj(vec![
                ("ph", Json::str("e")),
                ("cat", Json::str("request")),
                ("id", id),
                ("name", Json::str(format!("request {}", ev.ticket))),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(ev.ts_us as f64)),
            ]));
        }
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
        ("enabled", Json::Bool(enabled)),
        ("trace_dropped_events", Json::Num(dropped as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn all_kinds() -> Vec<EventKind> {
        vec![
            EventKind::Enqueued,
            EventKind::Dispatched { replica: 3, stolen: true },
            EventKind::Dispatched { replica: 0, stolen: false },
            EventKind::Admitted { hit_tokens: 4095 },
            EventKind::PrefillChunk { mode: PrefillMode::Ridden },
            EventKind::PrefillChunk { mode: PrefillMode::Dedicated },
            EventKind::PrefillChunk { mode: PrefillMode::Shed },
            EventKind::Plan { subbatches: 7 },
            EventKind::ChunkExec { variant: 2, func: FUNC_VERIFY, bucket: 16, wall_us: 1234 },
            EventKind::Scatter,
            EventKind::Commit { accepted: 5 },
            EventKind::Audit,
            EventKind::Demote,
            EventKind::Promote,
            EventKind::Cancelled,
            EventKind::Finished,
        ]
    }

    #[test]
    fn payload_round_trips_every_kind() {
        for kind in all_kinds() {
            let got = EventKind::decode(kind.tag(), kind.payload());
            assert_eq!(got, Some(kind), "round trip failed for {kind:?}");
        }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = Arc::new(FlightRecorder::new(false));
        let h = TraceHandle::new(rec.clone(), 0);
        assert!(!h.enabled());
        assert_eq!(h.stamp(), None);
        h.record(1, EventKind::Enqueued);
        h.record_at(5, 1, EventKind::Finished);
        let (events, dropped) = rec.drain();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
        // The default handle holds no recorder at all.
        let d = TraceHandle::default();
        assert!(!d.enabled());
        d.record(1, EventKind::Enqueued);
    }

    #[test]
    fn events_drain_in_causal_order() {
        let rec = Arc::new(FlightRecorder::new(true));
        let h = TraceHandle::new(rec.clone(), 2);
        h.record(10, EventKind::Enqueued);
        h.record(10, EventKind::Admitted { hit_tokens: 8 });
        h.record(10, EventKind::Commit { accepted: 3 });
        h.record(10, EventKind::Finished);
        let (events, dropped) = rec.drain();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 4);
        assert!(events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        assert_eq!(events[0].kind, EventKind::Enqueued);
        assert_eq!(events[3].kind, EventKind::Finished);
        assert!(events.iter().all(|e| e.replica == 2 && e.ticket == 10));
        // A second drain yields nothing new.
        let (again, _) = rec.drain();
        assert!(again.is_empty());
    }

    #[test]
    fn ring_wrap_drops_exactly() {
        let rec = Arc::new(FlightRecorder::new(true));
        let h = TraceHandle::new(rec.clone(), 0);
        let extra = 37u64;
        let total = RING_CAP as u64 + extra;
        for i in 0..total {
            h.record(1, EventKind::Commit { accepted: i as u32 });
        }
        let (events, dropped) = rec.drain();
        assert_eq!(events.len(), RING_CAP);
        assert_eq!(dropped, extra);
        // The survivors are the newest RING_CAP events, still in order.
        let accepted: Vec<u32> = events
            .iter()
            .map(|e| match e.kind {
                EventKind::Commit { accepted } => accepted,
                _ => panic!("unexpected kind"),
            })
            .collect();
        assert_eq!(accepted[0], extra as u32);
        assert!(accepted.windows(2).all(|w| w[0] < w[1]));
    }

    /// Concurrent property: K writers × N events with a concurrent drainer.
    /// Self-validating payloads catch torn reads; the drop counter plus the
    /// drained count must account for every recorded event; drained events
    /// stay per-ticket monotonic across successive drains.
    #[test]
    fn concurrent_record_drain_accounts_for_every_event() {
        const WRITERS: u64 = 4;
        const PER_WRITER: u64 = 20_000;
        let rec = Arc::new(FlightRecorder::new(true));
        let stop = Arc::new(AtomicBool::new(false));

        let drainer = {
            let rec = rec.clone();
            let stop = stop.clone();
            thread::spawn(move || {
                let mut drained: Vec<TraceEvent> = Vec::new();
                while !stop.load(Ordering::Relaxed) {
                    let (evs, _) = rec.drain();
                    drained.extend(evs);
                }
                drained
            })
        };

        let writers: Vec<_> = (0..WRITERS)
            .map(|t| {
                let rec = rec.clone();
                thread::spawn(move || {
                    let h = TraceHandle::new(rec, t as u32);
                    for i in 0..PER_WRITER {
                        // Payload encodes (ticket, seq): torn reads can't
                        // produce a consistent pair.
                        h.record(
                            t + 1,
                            EventKind::Commit {
                                accepted: ((t + 1) * 1_000_000 + i) as u32,
                            },
                        );
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let mut drained = drainer.join().unwrap();
        let (tail_events, dropped) = rec.drain();
        drained.extend(tail_events);

        assert_eq!(
            drained.len() as u64 + dropped,
            WRITERS * PER_WRITER,
            "every recorded event must be drained or counted dropped"
        );
        let mut last_seq: std::collections::BTreeMap<u64, u64> = Default::default();
        for ev in &drained {
            let accepted = match ev.kind {
                EventKind::Commit { accepted } => accepted as u64,
                _ => panic!("unexpected kind {:?}", ev.kind),
            };
            let ticket = accepted / 1_000_000;
            assert_eq!(ticket, ev.ticket, "torn event: payload/ticket mismatch");
            let seq = accepted % 1_000_000;
            if let Some(prev) = last_seq.get(&ev.ticket) {
                assert!(
                    seq > *prev,
                    "per-ticket order violated: {seq} after {prev} for ticket {}",
                    ev.ticket
                );
            }
            last_seq.insert(ev.ticket, seq);
        }
    }

    #[test]
    fn chrome_trace_shape_covers_lanes_and_tracks() {
        let rec = Arc::new(FlightRecorder::new(true));
        let h = TraceHandle::new(rec.clone(), 0);
        let v = rec.intern("w8a8");
        assert_eq!(v, rec.intern("w8a8"));
        h.record(7, EventKind::Enqueued);
        h.record(7, EventKind::Admitted { hit_tokens: 0 });
        h.record(
            0,
            EventKind::ChunkExec { variant: v, func: FUNC_DECODE, bucket: 4, wall_us: 50 },
        );
        h.record(7, EventKind::Commit { accepted: 2 });
        h.record(7, EventKind::Finished);
        let json = rec.chrome_trace_json();
        let text = json.to_string();
        assert!(text.contains("\"traceEvents\""));
        let evs = match json.get("traceEvents") {
            Some(Json::Arr(a)) => a,
            other => panic!("traceEvents missing: {other:?}"),
        };
        let phases: Vec<String> = evs
            .iter()
            .filter_map(|e| match e.get("ph") {
                Some(Json::Str(s)) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert!(phases.contains(&"M".to_string()), "process metadata missing");
        assert!(phases.contains(&"b".to_string()), "async begin missing");
        assert!(phases.contains(&"e".to_string()), "async end missing");
        assert!(phases.contains(&"X".to_string()), "exec slice missing");
        assert_eq!(
            phases.iter().filter(|p| *p == "b").count(),
            phases.iter().filter(|p| *p == "e").count(),
            "unbalanced async lanes"
        );
        assert!(text.contains("w8a8"));
        assert_eq!(
            json.get("trace_dropped_events"),
            Some(&Json::Num(0.0))
        );
    }
}

//! Engine-wide metrics registry: counters, gauges and latency histograms,
//! cheap enough for the hot loop and dumpable as JSON for the server's
//! `/metrics`-style endpoint and the bench harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::hist::Histogram;
use crate::util::json::Json;

/// Names of the scheduler/serving metrics shared between the engine (which
/// records them) and the router's stats publisher (which reads them back).
pub mod names {
    /// Histogram: seconds a request queued before admission.
    pub const SCHED_DELAY_S: &str = "sched_delay_s";
    /// Histogram: active rows per decode/verify step (batch fill).
    pub const BATCH_OCCUPANCY: &str = "batch_occupancy";
    /// Gauge: requests waiting in the scheduler.
    pub const QUEUE_DEPTH: &str = "queue_depth";
    /// Histogram: useful-positions / executed-positions per decode/verify
    /// call (1.0 = every executed position carried real work). A per-call
    /// distribution — the aggregate served by the stats endpoint is the
    /// ratio of the two position counters below, not this histogram's mean,
    /// so small calls don't get overweighted.
    pub const CHUNK_EFFICIENCY: &str = "chunk_efficiency";
    /// Counter: positions that carried real work across decode/verify calls.
    pub const USEFUL_POSITIONS: &str = "useful_positions";
    /// Counter: positions executed (bucket x chunk) across decode/verify
    /// calls, padding included.
    pub const EXECUTED_POSITIONS: &str = "executed_positions";
    /// Histogram: sub-batches the elastic planner executed per step
    /// (1.0 = monolithic shape).
    pub const SUBBATCHES_PER_STEP: &str = "subbatches_per_step";
    /// Histogram: modeled seconds per step the chosen plan saves over the
    /// monolithic configured-bucket call (>= 0 by planner invariant).
    pub const PLANNED_SAVINGS_S: &str = "planned_savings_s";

    /// Counter: sampled shadow audits of primary-variant sub-batches.
    pub const GOVERNOR_AUDITS: &str = "governor_audits";
    /// Counter: scheduled re-promotion probes of reference sub-batches
    /// (tallied apart from audits so audits/eligible stays a true rate).
    pub const GOVERNOR_PROBES: &str = "governor_probes";
    /// Counter: primary-variant sub-batches the governor *could* have
    /// audited (the audit-rate denominator).
    pub const GOVERNOR_ELIGIBLE: &str = "governor_eligible";
    /// Counter: audits skipped because the shadow variant doesn't export
    /// the needed (fn, bucket) shape.
    pub const GOVERNOR_AUDIT_SKIPPED: &str = "governor_audit_skipped";
    /// Histogram: top-1 agreement between quantized and reference logits
    /// over a class's verified positions, one sample per (class, shadow
    /// call) — a shadow execution's rows are correlated, so they aggregate
    /// into a single observation (1.0 = quantization never flipped the
    /// argmax — the paper's §4.5 criterion).
    pub const GOVERNOR_AGREEMENT: &str = "governor_agreement";
    /// Histogram: per-(class, shadow call) acceptance-length delta,
    /// quantized − reference (negative = the quantized verifier accepts
    /// shorter prefixes than full precision would).
    pub const GOVERNOR_ACCEPT_DELTA: &str = "governor_accept_delta";
    /// Counter: request classes demoted to the reference variant.
    pub const GOVERNOR_DEMOTIONS: &str = "governor_demotions";
    /// Counter: request classes re-promoted to the primary variant.
    pub const GOVERNOR_PROMOTIONS: &str = "governor_promotions";

    /// Gauge (monotonic, published from the cache's own counters):
    /// admissions whose prompt matched a cached prefix (suffix-only
    /// prefill).
    pub const PREFIX_HITS: &str = "prefix_cache_hits";
    /// Gauge (monotonic): admissions that found no usable cached prefix
    /// (hits + misses = admissions with the cache enabled).
    pub const PREFIX_MISSES: &str = "prefix_cache_misses";
    /// Gauge (monotonic): prompt tokens served from cached KV instead of
    /// prefill.
    pub const PREFIX_HIT_TOKENS: &str = "prefix_cache_hit_tokens";
    /// Gauge (monotonic): cached segments evicted by the byte-budget LRU.
    pub const PREFIX_EVICTIONS: &str = "prefix_cache_evictions";
    /// Gauge: bytes of KV pages resident in the prefix cache's pool.
    pub const PREFIX_RESIDENT_BYTES: &str = "prefix_cache_resident_bytes";
    /// Gauge: page-runs (cached prefixes) resident in the prefix cache.
    pub const PREFIX_SEGMENTS: &str = "prefix_cache_segments";
    /// Gauge: pages resident in the prefix cache's pool.
    pub const PREFIX_RESIDENT_PAGES: &str = "prefix_cache_resident_pages";
    /// Gauge: live run→page references. Divided by resident pages this is
    /// the share ratio (1.0 = no sharing; higher = one physical page backs
    /// several cached prefixes).
    pub const PREFIX_PAGE_REFS: &str = "prefix_cache_page_refs";
    /// Gauge (monotonic): pool pages filled by copying KV in (fresh
    /// allocations + copy-on-write tails); stable while inserts merely
    /// reference shared pages.
    pub const PREFIX_COPIED_PAGES: &str = "prefix_cache_copied_pages";
    /// Gauge (monotonic): prompt tokens served from runs extended with
    /// generated continuations (mid-stream snapshots).
    pub const PREFIX_MID_STREAM_HIT_TOKENS: &str = "prefix_cache_mid_stream_hit_tokens";

    /// Gauge: bytes of KV resident right now — the page pool (cached runs
    /// + live row pages) plus, under the copy-based row backend, the
    /// batch group's whole slab. The headline the paged backend shrinks.
    pub const KV_RESIDENT_BYTES: &str = "kv_resident_bytes";
    /// Gauge (monotonic): high-water mark of [`KV_RESIDENT_BYTES`] — what
    /// the A/B bench compares across row backends.
    pub const KV_RESIDENT_PEAK_BYTES: &str = "kv_resident_peak_bytes";
    /// Gauge: page references held by live batch rows (a shared page
    /// counts once per referencing row).
    pub const KV_ROW_PAGE_REFS: &str = "kv_row_page_refs";
    /// Gauge (monotonic): row page-table entries installed by refcount
    /// bump — admission splices that copied nothing.
    pub const KV_ROW_SHARED_PAGES: &str = "kv_row_shared_pages";
    /// Gauge (monotonic): *full* pages copied building row page-tables.
    /// Zero on a warmed run is the zero-copy admission guarantee.
    pub const KV_ROW_COPIED_PAGES: &str = "kv_row_copied_pages";
    /// Gauge (monotonic): partial tail pages copied building row
    /// page-tables (expected even on fully-cached admissions: the growth
    /// frontier must be private).
    pub const KV_ROW_TAIL_COPIES: &str = "kv_row_tail_copies";
    /// Histogram: modeled seconds of KV movement the page-table row
    /// backend avoided versus the copy-based slab — shared-page admission
    /// installs, committed prefixes skipped by delta-only scatter, and
    /// by-reference finish-time snapshots.
    pub const KV_COPY_SAVED_S: &str = "kv_copy_saved_s";
    /// Histogram: modeled prefill seconds each cache hit saved *net* — the
    /// full-prompt chunk price minus the suffix-only price actually paid,
    /// minus the per-page splice traffic that realized the hit.
    pub const PREFILL_SAVED_S: &str = "prefill_saved_s";

    /// Counter: submitted prompts cut to the context cap (`max_seq - 2`,
    /// the longest prompt that can still emit a token before the row's
    /// context fills).
    pub const PROMPT_TRUNCATED: &str = "prompt_truncated";

    /// Counter: prefill chunks executed — one per admission-suffix chunk,
    /// whether it rode a decode/verify sub-batch's spare slot or ran as a
    /// dedicated prefill call. Monolithic admission counts its chunks too,
    /// so the A/B compares like with like.
    pub const PREFILL_CHUNKS: &str = "prefill_chunks";
    /// Gauge: admitted rows still mid-prefill (chunked admission only).
    pub const PREFILL_INFLIGHT_ROWS: &str = "prefill_inflight_rows";
    /// Counter: steps where a *dedicated* prefill call executed while at
    /// least one decode row was active — the stall the chunked-prefill
    /// riders exist to eliminate. Strictly lower chunked-vs-monolithic on
    /// the same workload is the A/B acceptance gate.
    pub const DECODE_STALL_STEPS: &str = "decode_stall_steps";
    /// Histogram: modeled seconds of dedicated-prefill stall each riding
    /// chunk avoided — the chunk's own priced call time, saved because it
    /// filled an already-paid spare slot instead of preempting decode.
    pub const PREFILL_STALL_SAVED_S: &str = "prefill_stall_saved_s";
    /// Counter: dedicated prefill chunks shrunk below the exported prefill
    /// window because the admission queue was deep (load-adaptive chunk
    /// sizing — the chunk reroutes through the single-row verify program,
    /// trading ingest throughput for a tighter per-step time bound).
    pub const PREFILL_SHED_CHUNKS: &str = "prefill_shed_chunks";

    /// Histogram: TTFT of requests whose admission hit the prefix cache.
    pub const TTFT_WARM_S: &str = "ttft_warm_s";
    /// Histogram: TTFT of requests admitted cold (no prefix hit).
    pub const TTFT_COLD_S: &str = "ttft_cold_s";
    /// Histogram: per-token decode latency of warm-admitted requests.
    pub const TPOT_WARM_S: &str = "tpot_warm_s";
    /// Histogram: per-token decode latency of cold-admitted requests.
    pub const TPOT_COLD_S: &str = "tpot_cold_s";

    /// Histogram name: rows actually carried per call executed at `bucket`
    /// (per-bucket occupancy).
    pub fn bucket_occupancy(bucket: usize) -> String {
        format!("bucket_occupancy_b{bucket}")
    }

    /// Counter name: calls executed at `bucket`.
    pub fn bucket_calls(bucket: usize) -> String {
        format!("bucket_calls_b{bucket}")
    }

    /// Counter name: decode/verify/audit chunk calls that streamed
    /// `variant`'s weights (prefill excluded).
    pub fn variant_calls(variant: &str) -> String {
        format!("variant_calls_{variant}")
    }
}

/// Speculative-decoding bookkeeping the paper's tables are built from.
#[derive(Debug, Default, Clone)]
pub struct SpecStats {
    /// Decoding steps (one draft+verify round or one fallback decode).
    pub steps: u64,
    /// Tokens emitted (accepted + bonus/corrective).
    pub tokens_out: u64,
    /// Draft tokens proposed by the drafter.
    pub drafted: u64,
    /// Draft tokens accepted by the verifier.
    pub accepted: u64,
    /// Steps where the drafter found no candidate (plain decode).
    pub draft_misses: u64,
    /// 1 when this request's prompt was truncated to the prefill window at
    /// submission (counts truncated requests after a merge).
    pub prompt_truncated: u64,
}

impl SpecStats {
    /// Mean acceptance length `L`: tokens emitted per decoding step — the
    /// paper's quality metric (1.0 = vanilla autoregressive).
    pub fn mean_acceptance_len(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.steps as f64
    }

    /// Token acceptance rate `alpha` over proposed drafts.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }

    pub fn merge(&mut self, o: &SpecStats) {
        self.steps += o.steps;
        self.tokens_out += o.tokens_out;
        self.drafted += o.drafted;
        self.accepted += o.accepted;
        self.draft_misses += o.draft_misses;
        self.prompt_truncated += o.prompt_truncated;
    }
}

/// Global-ish registry handed around by reference.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, AtomicI64>>,
    hists: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicI64::new(0))
            .store(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn observe(&self, name: &str, v: f64) {
        let mut m = self.hists.lock().unwrap();
        m.entry(name.to_string()).or_default().record(v);
    }

    pub fn hist(&self, name: &str) -> Option<Histogram> {
        self.hists.lock().unwrap().get(name).cloned()
    }

    /// Owned point-in-time snapshot of every counter/gauge/histogram, for
    /// cross-thread scrapes and cross-replica merging.
    pub fn export(&self) -> MetricsDump {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let hists = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.clone()))
            .collect();
        MetricsDump { counters, gauges, hists }
    }

    /// Snapshot as JSON (stable key order for golden tests).
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                (k.clone(), Json::Num(v.load(Ordering::Relaxed) as f64))
            })
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                (k.clone(), Json::Num(v.load(Ordering::Relaxed) as f64))
            })
            .collect();
        let hists = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("mean", Json::num(h.mean())),
                        ("p50", Json::num(h.p50())),
                        ("p95", Json::num(h.p95())),
                        ("p99", Json::num(h.p99())),
                        ("max", Json::num(h.max())),
                    ]),
                )
            })
            .collect();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(hists)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// Owned registry snapshot: mergeable across replicas (counters/gauges add,
/// histograms merge bucket-wise) and renderable in Prometheus text
/// exposition format for `{"cmd":"metrics"}`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsDump {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, Histogram>,
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; anything else becomes `_`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

impl MetricsDump {
    /// Fold another replica's snapshot into this one.
    pub fn merge(&mut self, other: &MetricsDump) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Render as Prometheus text exposition format (version 0.0.4).
    /// Histograms become cumulative `_bucket{le="..."}` series straight from
    /// the log-buckets, plus exact `_sum`/`_count`. Empty-count buckets are
    /// skipped (the cumulative values stay exact); the `+Inf` bucket is
    /// always emitted.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in &self.counters {
            let n = format!("quasar_{}", sanitize(name));
            let _ = writeln!(out, "# TYPE {n} counter");
            let _ = writeln!(out, "{n} {v}");
        }
        for (name, v) in &self.gauges {
            let n = format!("quasar_{}", sanitize(name));
            let _ = writeln!(out, "# TYPE {n} gauge");
            let _ = writeln!(out, "{n} {v}");
        }
        for (name, h) in &self.hists {
            let n = format!("quasar_{}", sanitize(name));
            let _ = writeln!(out, "# TYPE {n} histogram");
            let counts = h.bucket_counts();
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                cum += c;
                if c == 0 && i + 1 < counts.len() {
                    continue;
                }
                let le = Histogram::bucket_upper_bound(i);
                let le = if le.is_infinite() {
                    "+Inf".to_string()
                } else {
                    format!("{le}")
                };
                let _ = writeln!(out, "{n}_bucket{{le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(out, "{n}_sum {}", h.sum());
            let _ = writeln!(out, "{n}_count {}", h.count());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests", 1);
        m.inc("requests", 2);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.set_gauge("queue_depth", 5);
        m.set_gauge("queue_depth", 7);
        assert_eq!(m.gauge("queue_depth"), 7);
    }

    #[test]
    fn histograms_record() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("latency", i as f64 * 0.001);
        }
        let h = m.hist("latency").unwrap();
        assert_eq!(h.count(), 100);
        assert!(h.p50() > 0.03 && h.p50() < 0.08, "{}", h.p50());
    }

    #[test]
    fn json_snapshot_contains_all() {
        let m = Metrics::new();
        m.inc("a", 1);
        m.set_gauge("g", -2);
        m.observe("h", 0.5);
        let j = m.to_json();
        assert_eq!(j.get("counters").unwrap().get("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(j.get("gauges").unwrap().get("g").unwrap().as_i64().unwrap(), -2);
        assert_eq!(
            j.get("histograms").unwrap().get("h").unwrap().get("count").unwrap().as_i64().unwrap(),
            1
        );
    }

    #[test]
    fn export_merge_and_prometheus_text() {
        let a = Metrics::new();
        a.inc("requests_completed", 3);
        a.set_gauge("queue_depth", 2);
        a.observe("sched_delay_s", 0.001);
        a.observe("sched_delay_s", 0.002);
        let b = Metrics::new();
        b.inc("requests_completed", 4);
        b.set_gauge("queue_depth", 1);
        b.observe("sched_delay_s", 0.1);
        let mut dump = a.export();
        dump.merge(&b.export());
        assert_eq!(dump.counters["requests_completed"], 7);
        assert_eq!(dump.gauges["queue_depth"], 3);
        assert_eq!(dump.hists["sched_delay_s"].count(), 3);

        let text = dump.to_prometheus();
        assert!(text.contains("# TYPE quasar_requests_completed counter"));
        assert!(text.contains("quasar_requests_completed 7"));
        assert!(text.contains("# TYPE quasar_queue_depth gauge"));
        assert!(text.contains("quasar_queue_depth 3"));
        assert!(text.contains("# TYPE quasar_sched_delay_s histogram"));
        assert!(text.contains("quasar_sched_delay_s_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("quasar_sched_delay_s_count 3"));
        // no exponent notation anywhere (Prometheus floats are plain decimal)
        assert!(!text.contains('e') || !text.lines().any(|l| {
            l.split_whitespace().nth(1).is_some_and(|v| v.contains('e') && v != "+Inf")
        }));
        // cumulative bucket counts are non-decreasing
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev);
            prev = v;
        }
        // dotted / dashed names sanitize
        let c = Metrics::new();
        c.inc("governor.demote-total", 1);
        let t = c.export().to_prometheus();
        assert!(t.contains("quasar_governor_demote_total 1"));
    }

    #[test]
    fn spec_stats_derivations() {
        let s = SpecStats {
            steps: 10, tokens_out: 14, drafted: 20, accepted: 4, draft_misses: 2,
            prompt_truncated: 1,
        };
        assert!((s.mean_acceptance_len() - 1.4).abs() < 1e-12);
        assert!((s.acceptance_rate() - 0.2).abs() < 1e-12);
        let mut t = SpecStats::default();
        t.merge(&s);
        t.merge(&s);
        assert_eq!(t.steps, 20);
        assert_eq!(t.tokens_out, 28);
        assert_eq!(t.prompt_truncated, 2, "truncated-request count merges");
    }
}

//! Rust mirror of the closed-lexicon word tokenizer
//! (`python/compile/tokenizer.py`), loaded from `artifacts/tokenizer.json`.
//!
//! The corpus language is whitespace-separated words from a fixed lexicon,
//! so encoding is a dictionary lookup per word with `<unk>` fallback, and
//! `decode(encode(text)) == normalize(text)` exactly — a property the test
//! suite checks against strings generated from the vocab itself.

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::{Json, JsonError};

/// Special-token contract shared by every artifact tokenizer the stack
/// produces (`python/compile/tokenizer.py` reserves the first four vocab
/// slots). Engine commit/finish logic, the spec layer, and the server all
/// key off these instead of re-hardcoding the ids.
pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;
pub const UNK_ID: i32 = 3;

/// Word-level tokenizer over the shared reproduction lexicon.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: Vec<String>,
    index: HashMap<String, u32>,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub unk_id: i32,
}

impl Tokenizer {
    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let kind = v.get("kind")?.as_str()?;
        if kind != "closed-lexicon-word" {
            return Err(JsonError(format!("unsupported tokenizer kind {kind}")));
        }
        let vocab: Vec<String> = v
            .get("vocab")?
            .as_arr()?
            .iter()
            .map(|w| w.as_str().map(String::from))
            .collect::<Result<_, _>>()?;
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Ok(Tokenizer {
            pad_id: v.get("pad_id")?.as_i64()? as i32,
            bos_id: v.get("bos_id")?.as_i64()? as i32,
            eos_id: v.get("eos_id")?.as_i64()? as i32,
            unk_id: v.get("unk_id")?.as_i64()? as i32,
            vocab,
            index,
        })
    }

    pub fn load(path: &Path) -> Result<Self, JsonError> {
        Self::from_json(&crate::util::json::parse_file(path)?)
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Whether the loaded vocabulary honors the special-token contract the
    /// engine's finish logic assumes ([`PAD_ID`]..[`UNK_ID`]).
    pub fn matches_contract(&self) -> bool {
        self.pad_id == PAD_ID
            && self.bos_id == BOS_ID
            && self.eos_id == EOS_ID
            && self.unk_id == UNK_ID
    }

    pub fn token(&self, id: i32) -> Option<&str> {
        self.vocab.get(id as usize).map(|s| s.as_str())
    }

    pub fn id_of(&self, word: &str) -> Option<i32> {
        self.index.get(word).map(|&i| i as i32)
    }

    fn is_special(&self, id: i32) -> bool {
        id == self.pad_id || id == self.bos_id || id == self.eos_id
    }

    pub fn encode(&self, text: &str, add_bos: bool) -> Vec<i32> {
        let mut ids = Vec::with_capacity(text.len() / 4 + 1);
        if add_bos {
            ids.push(self.bos_id);
        }
        for word in text.split_whitespace() {
            ids.push(self.id_of(word).unwrap_or(self.unk_id));
        }
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if self.is_special(id) {
                continue;
            }
            let word = self.token(id).unwrap_or("<unk>");
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(word);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn tiny() -> Tokenizer {
        let j = parse(
            r#"{"kind":"closed-lexicon-word",
                "vocab":["<pad>","<bos>","<eos>","<unk>","tom","has","3","apples","."],
                "pad_id":0,"bos_id":1,"eos_id":2,"unk_id":3}"#,
        )
        .unwrap();
        Tokenizer::from_json(&j).unwrap()
    }

    #[test]
    fn contract_constants_match_convention() {
        let t = tiny();
        assert!(t.matches_contract());
        assert_eq!((PAD_ID, BOS_ID, EOS_ID, UNK_ID), (0, 1, 2, 3));
        let j = parse(
            r#"{"kind":"closed-lexicon-word","vocab":["a","b"],
                "pad_id":1,"bos_id":0,"eos_id":2,"unk_id":3}"#,
        )
        .unwrap();
        assert!(!Tokenizer::from_json(&j).unwrap().matches_contract());
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = tiny();
        let ids = t.encode("tom has 3 apples .", true);
        assert_eq!(ids, vec![1, 4, 5, 6, 7, 8]);
        assert_eq!(t.decode(&ids), "tom has 3 apples .");
    }

    #[test]
    fn unknown_words_map_to_unk() {
        let t = tiny();
        let ids = t.encode("tom eats pizza", false);
        assert_eq!(ids, vec![4, 3, 3]);
        assert_eq!(t.decode(&ids), "tom <unk> <unk>");
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = tiny();
        assert_eq!(t.decode(&[1, 4, 0, 0, 2]), "tom");
        assert_eq!(t.decode(&[]), "");
    }

    #[test]
    fn whitespace_normalization() {
        let t = tiny();
        assert_eq!(
            t.encode("  tom   has\napples ", false),
            vec![4, 5, 7]
        );
    }

    #[test]
    fn rejects_wrong_kind() {
        let j = parse(r#"{"kind":"bpe","vocab":[],"pad_id":0,"bos_id":1,"eos_id":2,"unk_id":3}"#)
            .unwrap();
        assert!(Tokenizer::from_json(&j).is_err());
    }
}

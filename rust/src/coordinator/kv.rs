//! Batch-group KV-cache manager.
//!
//! The exported artifacts operate on a whole `[L, B, H, S, hd]` cache, so
//! the engine keeps one *batch group* per batch bucket: a persistent cache
//! whose rows are leased to requests. Joining a request prefills into a
//! fresh single-row cache and splices that row in (`Tensor::
//! copy_axis1_row_from`); leaving zeroes the row. Row state never moves
//! between steps — continuous batching without cache shuffling.

use anyhow::{bail, Result};

use crate::runtime::Tensor;

/// A leased-row batched KV cache.
pub struct BatchGroup {
    pub k: Tensor<f32>,
    pub v: Tensor<f32>,
    /// `rows[i] = Some(request_slot)` when leased.
    rows: Vec<Option<usize>>,
    pub batch: usize,
}

impl BatchGroup {
    pub fn new(n_layers: usize, batch: usize, n_heads: usize, max_seq: usize,
               head_dim: usize) -> Self {
        let dims = [n_layers, batch, n_heads, max_seq, head_dim];
        BatchGroup {
            k: Tensor::zeros(&dims),
            v: Tensor::zeros(&dims),
            rows: vec![None; batch],
            batch,
        }
    }

    pub fn free_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.is_none()).count()
    }

    pub fn active_rows(&self) -> Vec<(usize, usize)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|slot| (i, slot)))
            .collect()
    }

    pub fn occupant(&self, row: usize) -> Option<usize> {
        self.rows[row]
    }

    pub fn is_empty(&self) -> bool {
        self.rows.iter().all(|r| r.is_none())
    }

    /// Lease a free row to `slot`, splicing in its prefilled single-row
    /// cache (`[L, 1, H, S, hd]`).
    pub fn join(&mut self, slot: usize, k1: &Tensor<f32>, v1: &Tensor<f32>) -> Result<usize> {
        if self.rows.iter().any(|r| *r == Some(slot)) {
            bail!("slot {slot} already in group");
        }
        let row = match self.rows.iter().position(|r| r.is_none()) {
            Some(r) => r,
            None => bail!("no free row in batch group"),
        };
        if k1.dims[1] != 1 || v1.dims[1] != 1 {
            bail!("expected single-row cache, got batch {}", k1.dims[1]);
        }
        self.k.copy_axis1_row_from(row, k1, 0);
        self.v.copy_axis1_row_from(row, v1, 0);
        self.rows[row] = Some(slot);
        Ok(row)
    }

    /// Release a row (request finished); zeroes it defensively so a stale
    /// read would produce obviously-wrong attention rather than plausible
    /// leakage from the previous occupant.
    pub fn leave(&mut self, row: usize) -> Result<usize> {
        let Some(slot) = self.rows[row] else {
            bail!("row {row} not leased");
        };
        self.rows[row] = None;
        self.k.zero_axis1_row(row);
        self.v.zero_axis1_row(row);
        Ok(slot)
    }

    /// Adopt the advanced caches returned by a chunk execution.
    pub fn adopt(&mut self, k: Tensor<f32>, v: Tensor<f32>) -> Result<()> {
        if k.dims != self.k.dims || v.dims != self.v.dims {
            bail!("adopt dims mismatch {:?} vs {:?}", k.dims, self.k.dims);
        }
        self.k = k;
        self.v = v;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group() -> BatchGroup {
        BatchGroup::new(2, 3, 2, 8, 4)
    }

    fn row_cache(fill: f32) -> (Tensor<f32>, Tensor<f32>) {
        let dims = [2, 1, 2, 8, 4];
        let mut k = Tensor::zeros(&dims);
        k.data.iter_mut().for_each(|x| *x = fill);
        let v = k.clone();
        (k, v)
    }

    #[test]
    fn join_leases_first_free_row_and_splices() {
        let mut g = group();
        let (k1, v1) = row_cache(7.0);
        let row = g.join(42, &k1, &v1).unwrap();
        assert_eq!(row, 0);
        assert_eq!(g.free_rows(), 2);
        assert_eq!(g.occupant(0), Some(42));
        assert_eq!(g.k.at(&[1, 0, 1, 3, 2]), 7.0);
        assert_eq!(g.k.at(&[1, 1, 1, 3, 2]), 0.0, "other rows untouched");
        assert_eq!(g.active_rows(), vec![(0, 42)]);
    }

    #[test]
    fn join_rejects_duplicate_slot_and_full_group() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        g.join(1, &k1, &v1).unwrap();
        assert!(g.join(1, &k1, &v1).is_err(), "duplicate slot");
        g.join(2, &k1, &v1).unwrap();
        g.join(3, &k1, &v1).unwrap();
        assert!(g.join(4, &k1, &v1).is_err(), "full group");
    }

    #[test]
    fn leave_frees_and_zeroes() {
        let mut g = group();
        let (k1, v1) = row_cache(5.0);
        let row = g.join(9, &k1, &v1).unwrap();
        assert_eq!(g.leave(row).unwrap(), 9);
        assert_eq!(g.free_rows(), 3);
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), 0.0);
        assert!(g.leave(row).is_err(), "double leave");
        assert!(g.is_empty());
    }

    #[test]
    fn rows_are_reused_after_leave() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        let r0 = g.join(1, &k1, &v1).unwrap();
        g.join(2, &k1, &v1).unwrap();
        g.leave(r0).unwrap();
        let r2 = g.join(3, &k1, &v1).unwrap();
        assert_eq!(r2, r0, "freed row is reused");
    }

    #[test]
    fn adopt_validates_dims() {
        let mut g = group();
        let bad = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        assert!(g.adopt(bad.clone(), bad).is_err());
        let good = Tensor::<f32>::zeros(&[2, 3, 2, 8, 4]);
        assert!(g.adopt(good.clone(), good).is_ok());
    }
}

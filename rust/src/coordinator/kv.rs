//! Batch-row KV management: copy-based slab rows and page-table rows.
//!
//! Two row backends share one occupancy model (rows leased to request
//! slots, row state never moves between steps — continuous batching
//! without cache shuffling):
//!
//! * [`BatchGroup`] — the copy-based A/B reference: a persistent
//!   `[L, B, H, S, hd]` slab whose rows are spliced in on join and zeroed
//!   on leave. All movement is **length-bounded**: joins, gathers,
//!   scatters and leaves touch only each row's committed positions (plus
//!   a per-row written high-water mark for speculative slack), never the
//!   full `max_seq` extent.
//! * [`PagedGroup`] — the serving shape: a row is a *page table* (ordered
//!   page ids + committed length) over the shared [`PrefixCache`] pool.
//!   Admission installs pages by refcount bump (copying only the partial
//!   tail), finish-time snapshots reference the row's own pages, and
//!   `leave` is a refcount release. The write discipline is append-only:
//!   committed positions never change, so full pages are immutable and
//!   only the private growth-frontier page is ever written
//!   ([`PrefixCache::write_row_page`] enforces refs == 1).
//!
//! Execution never adopts a whole returned cache in either backend: the
//! elastic step planner (`coordinator::plan`) runs each sub-batch against
//! a *bucket-shaped scratch cache* — gather copies each row's committed
//! prefix into scratch row order before a chunk runs, and scatter writes
//! back afterwards. The scatter asymmetry is the paged backend's win: a
//! slab row must copy back `committed + chunk` positions, a page-table
//! row writes only the newly-advanced `[from, to)` positions because its
//! committed pages are immutable and already hold what the scratch holds.
//! Rows outside the sub-batch are never touched. Scratch positions beyond
//! a gathered row's bound keep whatever stale-but-finite values the pool
//! left there — exactly the contract batch-independent causal attention
//! already grants rows outside the gathered set.

use anyhow::{bail, Result};

use super::prefixcache::PrefixCache;
use crate::runtime::Tensor;

/// A leased-row batched KV cache (copy-based slab rows).
pub struct BatchGroup {
    pub k: Tensor<f32>,
    pub v: Tensor<f32>,
    /// `rows[i] = Some(request_slot)` when leased.
    rows: Vec<Option<usize>>,
    pub batch: usize,
    /// Per-row written high-water mark: positions `written[i]..` of row `i`
    /// are zero. Length-bounded join zeroing and leave both rely on it; it
    /// is max-tracked because a verify chunk followed by a shorter decode
    /// chunk makes `committed + chunk` non-monotonic, and whole-cache adopt
    /// paths must report what they dirtied via
    /// [`BatchGroup::note_written`].
    written: Vec<usize>,
}

impl BatchGroup {
    pub fn new(n_layers: usize, batch: usize, n_heads: usize, max_seq: usize,
               head_dim: usize) -> Self {
        let dims = [n_layers, batch, n_heads, max_seq, head_dim];
        BatchGroup {
            k: Tensor::zeros(&dims),
            v: Tensor::zeros(&dims),
            rows: vec![None; batch],
            batch,
            written: vec![0; batch],
        }
    }

    pub fn free_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.is_none()).count()
    }

    pub fn active_rows(&self) -> Vec<(usize, usize)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|slot| (i, slot)))
            .collect()
    }

    pub fn occupant(&self, row: usize) -> Option<usize> {
        self.rows[row]
    }

    pub fn is_empty(&self) -> bool {
        self.rows.iter().all(|r| r.is_none())
    }

    /// Lease a free row to `slot`, splicing in its prefilled single-row
    /// cache (`[L, 1, H, S, hd]`) whole — [`BatchGroup::join_prefix`] at
    /// the full sequence extent.
    pub fn join(&mut self, slot: usize, k1: &Tensor<f32>, v1: &Tensor<f32>) -> Result<usize> {
        let seq = self.k.dims[self.k.rank() - 2];
        self.join_prefix(slot, k1, v1, seq)
    }

    /// Length-bounded [`BatchGroup::join`]: lease a free row but splice only
    /// the first `used_len` sequence positions of the single-row cache and
    /// zero the rest of the row. An admission only has `prompt_len` valid
    /// KV positions — the full-`max_seq` copy moved (and kept resident)
    /// whatever garbage the prefill chunk wrote past the prompt.
    pub fn join_prefix(&mut self, slot: usize, k1: &Tensor<f32>, v1: &Tensor<f32>,
                       used_len: usize) -> Result<usize> {
        if k1.dims[1] != 1 || v1.dims[1] != 1 {
            bail!("expected single-row cache, got batch {}", k1.dims[1]);
        }
        self.join_prefix_from_row(slot, k1, v1, 0, used_len)
    }

    /// [`BatchGroup::join_prefix`] from one row of a *multi-row* source —
    /// the shape page-run assembly produces: a prefill output, a gathered
    /// scratch cache, or any `[L, B', H, S, hd]` pair whose row `src_row`
    /// holds the request's committed prefix.
    pub fn join_prefix_from_row(&mut self, slot: usize, k_src: &Tensor<f32>,
                                v_src: &Tensor<f32>, src_row: usize,
                                used_len: usize) -> Result<usize> {
        if self.rows.iter().any(|r| *r == Some(slot)) {
            bail!("slot {slot} already in group");
        }
        let row = match self.rows.iter().position(|r| r.is_none()) {
            Some(r) => r,
            None => bail!("no free row in batch group"),
        };
        if k_src.dims != v_src.dims {
            bail!("source k/v dims differ: {:?} vs {:?}", k_src.dims, v_src.dims);
        }
        if src_row >= k_src.dims[1] {
            bail!("source row {src_row} out of range for batch {}", k_src.dims[1]);
        }
        let seq = self.k.dims[self.k.rank() - 2];
        if used_len > seq {
            bail!("used_len {used_len} exceeds cache seq {seq}");
        }
        // Positions `written[row]..` are zero by invariant, so only the
        // dirty remainder past the splice needs clearing — not the whole
        // `max_seq` extent.
        if used_len < self.written[row] {
            let n = self.written[row] - used_len;
            self.k.zero_axis1_row_seq_range(row, used_len, n);
            self.v.zero_axis1_row_seq_range(row, used_len, n);
        }
        self.k.copy_axis1_row_seq_prefix_from(row, k_src, src_row, used_len);
        self.v.copy_axis1_row_seq_prefix_from(row, v_src, src_row, used_len);
        self.rows[row] = Some(slot);
        self.written[row] = used_len;
        Ok(row)
    }

    /// Record that positions `0..len` of `row` may hold non-zero values —
    /// required after any path that writes the cache tensors directly
    /// (whole-cache adoption by the engine's identity fast path, which
    /// dirties *every* batch row up to its chunk extent, leased or not).
    /// Max-tracked; clamped to the sequence extent.
    pub fn note_written(&mut self, row: usize, len: usize) {
        let seq = self.k.dims[self.k.rank() - 2];
        self.written[row] = self.written[row].max(len.min(seq));
    }

    /// Release a row (request finished); zeroes its written prefix
    /// defensively so a stale read would produce obviously-wrong attention
    /// rather than plausible leakage from the previous occupant. Positions
    /// past the written high-water mark are already zero by invariant —
    /// zeroing the full `max_seq` extent would move bandwidth over them
    /// for nothing.
    pub fn leave(&mut self, row: usize) -> Result<usize> {
        let Some(slot) = self.rows[row] else {
            bail!("row {row} not leased");
        };
        self.rows[row] = None;
        let n = self.written[row];
        if n > 0 {
            self.k.zero_axis1_row_seq_range(row, 0, n);
            self.v.zero_axis1_row_seq_range(row, 0, n);
            self.written[row] = 0;
        }
        Ok(slot)
    }

    /// Check a gather/scatter row map (`(group row, length)` pairs) against
    /// the group and a scratch shape: every group row leased, in range and
    /// **unique**, lengths within the sequence extent, scratch large
    /// enough, dims matching everywhere but the batch axis.
    fn check_row_map(&self, rows: &[(usize, usize)], k: &Tensor<f32>,
                     v: &Tensor<f32>) -> Result<()> {
        if k.dims != v.dims {
            bail!("scratch k/v dims differ: {:?} vs {:?}", k.dims, v.dims);
        }
        if k.dims.len() != self.k.dims.len()
            || k.dims[0] != self.k.dims[0]
            || k.dims[2..] != self.k.dims[2..]
        {
            bail!("scratch dims {:?} incompatible with group {:?}", k.dims, self.k.dims);
        }
        if rows.len() > k.dims[1] {
            bail!("{} rows exceed scratch bucket {}", rows.len(), k.dims[1]);
        }
        let seq = self.k.dims[self.k.rank() - 2];
        // Duplicates would double-write on scatter (last scratch row wins
        // silently) and alias one lease across two scratch rows on gather —
        // reject rather than guess which copy the caller meant.
        let mut seen = vec![false; self.batch];
        for &(r, len) in rows {
            if r >= self.batch {
                bail!("row {r} out of range for batch {}", self.batch);
            }
            if self.rows[r].is_none() {
                bail!("row {r} not leased");
            }
            if seen[r] {
                bail!("duplicate row {r} in row map");
            }
            if len > seq {
                bail!("row {r} length {len} exceeds cache seq {seq}");
            }
            seen[r] = true;
        }
        Ok(())
    }

    /// Copy leased group rows into a bucket-shaped scratch cache pair,
    /// each bounded to its own valid length: scratch row `i` receives the
    /// first `rows[i].1` positions of group row `rows[i].0` — copy volume
    /// tracks committed positions, not `max_seq`. Scratch rows beyond
    /// `rows.len()`, and scratch positions beyond each row's length, are
    /// left as-is (padding the executed bucket; per-row causal attention
    /// never reads across batch rows or past the positions the chunk
    /// advances through).
    pub fn gather_rows(&self, rows: &[(usize, usize)], k_dst: &mut Tensor<f32>,
                       v_dst: &mut Tensor<f32>) -> Result<()> {
        self.check_row_map(rows, k_dst, v_dst)?;
        let triples: Vec<(usize, usize, usize)> =
            rows.iter().enumerate().map(|(i, &(r, len))| (i, r, len)).collect();
        k_dst.copy_axis1_rows_seq_prefix(&triples, &self.k);
        v_dst.copy_axis1_rows_seq_prefix(&triples, &self.v);
        Ok(())
    }

    /// Copy advanced scratch rows back into the group, each bounded to its
    /// own advanced length: group row `rows[i].0` receives the first
    /// `rows[i].1` positions of scratch row `i` — the inverse of
    /// [`BatchGroup::gather_rows`] after a chunk execution advanced the
    /// scratch (lengths grow by the executed chunk). Updates each row's
    /// written high-water mark.
    pub fn scatter_rows(&mut self, rows: &[(usize, usize)], k_src: &Tensor<f32>,
                        v_src: &Tensor<f32>) -> Result<()> {
        self.check_row_map(rows, k_src, v_src)?;
        let triples: Vec<(usize, usize, usize)> =
            rows.iter().enumerate().map(|(i, &(r, len))| (r, i, len)).collect();
        self.k.copy_axis1_rows_seq_prefix(&triples, k_src);
        self.v.copy_axis1_rows_seq_prefix(&triples, v_src);
        for &(r, len) in rows {
            self.written[r] = self.written[r].max(len);
        }
        Ok(())
    }
}

/// One page-table row: ordered pool page ids plus the committed length.
/// Page `i` covers token positions `[i*P, (i+1)*P)`; pages past
/// `ceil(len/P)` hold speculative slack from a truncated verify chunk and
/// are overwritten (they are private by construction) before ever being
/// read.
struct PagedRow {
    slot: usize,
    pages: Vec<u64>,
    /// Committed KV positions. Gathers read `0..len`; scatters write from
    /// `len` up; everything at or past `len` is unread garbage.
    len: usize,
}

/// Page-table batch rows over the shared [`PrefixCache`] pool — the
/// zero-copy row backend. Holds no KV bytes itself: every operation that
/// touches KV takes the pool. The append-only write discipline (module
/// docs) keeps every page either immutable-and-shareable (fully committed)
/// or private-and-writable (growth frontier, refs == 1).
pub struct PagedGroup {
    rows: Vec<Option<PagedRow>>,
    pub batch: usize,
    page_tokens: usize,
    max_seq: usize,
}

impl PagedGroup {
    pub fn new(batch: usize, page_tokens: usize, max_seq: usize) -> Self {
        PagedGroup {
            rows: (0..batch).map(|_| None).collect(),
            batch,
            page_tokens: page_tokens.max(1),
            max_seq,
        }
    }

    pub fn free_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.is_none()).count()
    }

    pub fn active_rows(&self) -> Vec<(usize, usize)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|pr| (i, pr.slot)))
            .collect()
    }

    pub fn occupant(&self, row: usize) -> Option<usize> {
        self.rows[row].as_ref().map(|pr| pr.slot)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.iter().all(|r| r.is_none())
    }

    /// A row's committed length.
    pub fn row_len(&self, row: usize) -> Option<usize> {
        self.rows[row].as_ref().map(|pr| pr.len)
    }

    /// A row's page table (for finish-time snapshots, which reference
    /// these ids instead of copying KV).
    pub fn row_pages(&self, row: usize) -> Option<&[u64]> {
        self.rows[row].as_ref().map(|pr| pr.pages.as_slice())
    }

    /// Pages referenced across all live rows (occupancy gauge; shared
    /// pages count once per referencing row, like the refcounts do).
    pub fn total_pages(&self) -> usize {
        self.rows.iter().flatten().map(|pr| pr.pages.len()).sum()
    }

    /// Lease a free row to `slot`, installing an already-built page table
    /// (from [`PrefixCache::lease_row_pages`]) covering `len` committed
    /// positions. O(1) — the copies (if any) happened building the table.
    /// The row takes ownership of the caller's page references.
    pub fn join_pages(&mut self, slot: usize, pages: Vec<u64>, len: usize) -> Result<usize> {
        if self.rows.iter().flatten().any(|pr| pr.slot == slot) {
            bail!("slot {slot} already in group");
        }
        let Some(row) = self.rows.iter().position(|r| r.is_none()) else {
            bail!("no free row in batch group");
        };
        if len > self.max_seq {
            bail!("len {len} exceeds max_seq {}", self.max_seq);
        }
        if pages.len() * self.page_tokens < len {
            bail!("{} pages cannot cover {len} tokens", pages.len());
        }
        self.rows[row] = Some(PagedRow { slot, pages, len });
        Ok(row)
    }

    /// Advance a row's committed length after the verifier committed
    /// tokens (the row's pages must already cover it — scatter ran first).
    pub fn set_len(&mut self, row: usize, len: usize) -> Result<()> {
        let Some(pr) = self.rows[row].as_mut() else {
            bail!("row {row} not leased");
        };
        if len > self.max_seq {
            bail!("len {len} exceeds max_seq {}", self.max_seq);
        }
        if pr.pages.len() * self.page_tokens < len {
            bail!("row {row} pages cover {} tokens, not {len}",
                  pr.pages.len() * self.page_tokens);
        }
        pr.len = len;
        Ok(())
    }

    /// Release a row: hand its page references back to the pool (shared
    /// pages survive on their runs' references; private frontier pages are
    /// freed). No zeroing — nothing can read a freed page table.
    pub fn leave(&mut self, pool: &mut PrefixCache, row: usize) -> Result<usize> {
        let Some(pr) = self.rows[row].take() else {
            bail!("row {row} not leased");
        };
        pool.release_row_pages(&pr.pages);
        Ok(pr.slot)
    }

    /// Shared row-map validation: leased, in range, unique, scratch pair
    /// shaped like a cache and large enough for the mapped rows.
    fn check_rows(&self, rows: &[usize], k: &Tensor<f32>, v: &Tensor<f32>) -> Result<()> {
        if k.dims != v.dims {
            bail!("scratch k/v dims differ: {:?} vs {:?}", k.dims, v.dims);
        }
        if k.rank() < 4 {
            bail!("scratch rank {} is not a [L, B, .., S, hd] cache", k.rank());
        }
        if rows.len() > k.dims[1] {
            bail!("{} rows exceed scratch bucket {}", rows.len(), k.dims[1]);
        }
        let mut seen = vec![false; self.batch];
        for &r in rows {
            if r >= self.batch {
                bail!("row {r} out of range for batch {}", self.batch);
            }
            if self.rows[r].is_none() {
                bail!("row {r} not leased");
            }
            if seen[r] {
                bail!("duplicate row {r} in row map");
            }
            seen[r] = true;
        }
        Ok(())
    }

    /// Assemble committed positions into a bucket-shaped scratch pair:
    /// scratch row `i` receives positions `0..rows[i].1` of group row
    /// `rows[i].0`, read page-wise from the pool. Lengths must not exceed
    /// each row's committed length — positions past it are speculative
    /// garbage no caller may observe.
    pub fn gather_rows(&self, pool: &PrefixCache, rows: &[(usize, usize)],
                       k_dst: &mut Tensor<f32>, v_dst: &mut Tensor<f32>) -> Result<()> {
        let idx: Vec<usize> = rows.iter().map(|&(r, _)| r).collect();
        self.check_rows(&idx, k_dst, v_dst)?;
        let p = self.page_tokens;
        for (i, &(r, len)) in rows.iter().enumerate() {
            let pr = self.rows[r].as_ref().expect("checked leased");
            if len > pr.len {
                bail!("gather length {len} exceeds row {r} committed {}", pr.len);
            }
            if len > k_dst.dims[k_dst.rank() - 2] {
                bail!("gather length {len} exceeds scratch seq");
            }
            let mut pos = 0usize;
            while pos < len {
                let n = (p - pos % p).min(len - pos);
                pool.read_page_into(pr.pages[pos / p], pos % p, k_dst, v_dst, i, pos, n)?;
                pos += n;
            }
        }
        Ok(())
    }

    /// Write back only the newly-advanced positions: group row
    /// `rows[i].0` absorbs scratch row `i`'s positions `[from, to)`
    /// (`rows[i] = (row, from, to)`), allocating fresh private pages at
    /// the growth frontier as needed. Committed pages below `from` are
    /// never touched — they are immutable and already hold what the
    /// scratch holds, which is the whole copy saving over the slab
    /// backend's `0..to` write-back. Does not advance the committed
    /// length; [`PagedGroup::set_len`] does, after the verifier commits.
    pub fn scatter_advance(&mut self, pool: &mut PrefixCache,
                           rows: &[(usize, usize, usize)],
                           k_src: &Tensor<f32>, v_src: &Tensor<f32>) -> Result<()> {
        let idx: Vec<usize> = rows.iter().map(|&(r, _, _)| r).collect();
        self.check_rows(&idx, k_src, v_src)?;
        let p = self.page_tokens;
        for (i, &(r, from, to)) in rows.iter().enumerate() {
            if from > to || to > self.max_seq {
                bail!("bad advance range [{from}, {to}) for row {r}");
            }
            if to > k_src.dims[k_src.rank() - 2] {
                bail!("advance range end {to} exceeds scratch seq");
            }
            let pr = self.rows[r].as_mut().expect("checked leased");
            if from > pr.pages.len() * p {
                bail!("advance from {from} leaves a page gap on row {r}");
            }
            while pr.pages.len() * p < to {
                pr.pages.push(pool.alloc_row_page(&k_src.dims));
            }
            let mut pos = from;
            while pos < to {
                let n = (p - pos % p).min(to - pos);
                pool.write_row_page(pr.pages[pos / p], pos % p, k_src, v_src, i, pos, n)?;
                pos += n;
            }
        }
        Ok(())
    }
}

/// The engine's row backend: copy-based slab rows (the A/B reference) or
/// page-table rows over the shared pool. Occupancy accessors are common;
/// data movement is backend-specific and dispatched at the call sites that
/// own the pool borrow.
pub enum RowStore {
    Copy(BatchGroup),
    Paged(PagedGroup),
}

impl RowStore {
    pub fn batch(&self) -> usize {
        match self {
            RowStore::Copy(g) => g.batch,
            RowStore::Paged(g) => g.batch,
        }
    }

    pub fn free_rows(&self) -> usize {
        match self {
            RowStore::Copy(g) => g.free_rows(),
            RowStore::Paged(g) => g.free_rows(),
        }
    }

    pub fn active_rows(&self) -> Vec<(usize, usize)> {
        match self {
            RowStore::Copy(g) => g.active_rows(),
            RowStore::Paged(g) => g.active_rows(),
        }
    }

    pub fn occupant(&self, row: usize) -> Option<usize> {
        match self {
            RowStore::Copy(g) => g.occupant(row),
            RowStore::Paged(g) => g.occupant(row),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            RowStore::Copy(g) => g.is_empty(),
            RowStore::Paged(g) => g.is_empty(),
        }
    }

    /// Release a row in either backend (the pool is unused by the slab
    /// backend but borrowed uniformly so call sites stay shape-agnostic).
    pub fn leave(&mut self, pool: &mut PrefixCache, row: usize) -> Result<usize> {
        match self {
            RowStore::Copy(g) => g.leave(row),
            RowStore::Paged(g) => g.leave(pool, row),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::prefixcache::PrefixCacheConfig;

    fn group() -> BatchGroup {
        BatchGroup::new(2, 3, 2, 8, 4)
    }

    fn row_cache(fill: f32) -> (Tensor<f32>, Tensor<f32>) {
        let dims = [2, 1, 2, 8, 4];
        let mut k = Tensor::zeros(&dims);
        k.data.iter_mut().for_each(|x| *x = fill);
        let v = k.clone();
        (k, v)
    }

    #[test]
    fn join_leases_first_free_row_and_splices() {
        let mut g = group();
        let (k1, v1) = row_cache(7.0);
        let row = g.join(42, &k1, &v1).unwrap();
        assert_eq!(row, 0);
        assert_eq!(g.free_rows(), 2);
        assert_eq!(g.occupant(0), Some(42));
        assert_eq!(g.k.at(&[1, 0, 1, 3, 2]), 7.0);
        assert_eq!(g.k.at(&[1, 1, 1, 3, 2]), 0.0, "other rows untouched");
        assert_eq!(g.active_rows(), vec![(0, 42)]);
    }

    #[test]
    fn join_rejects_duplicate_slot_and_full_group() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        g.join(1, &k1, &v1).unwrap();
        assert!(g.join(1, &k1, &v1).is_err(), "duplicate slot");
        g.join(2, &k1, &v1).unwrap();
        g.join(3, &k1, &v1).unwrap();
        assert!(g.join(4, &k1, &v1).is_err(), "full group");
    }

    #[test]
    fn join_prefix_splices_used_positions_and_zeroes_the_rest() {
        let mut g = group(); // seq axis = 8
        let (k1, v1) = row_cache(7.0); // every position non-zero
        let row = g.join_prefix(11, &k1, &v1, 3).unwrap();
        assert_eq!(g.occupant(row), Some(11));
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), 7.0);
        assert_eq!(g.k.at(&[1, row, 1, 2, 3]), 7.0);
        assert_eq!(g.k.at(&[0, row, 0, 3, 0]), 0.0, "beyond used_len zeroed");
        assert_eq!(g.v.at(&[1, row, 1, 7, 3]), 0.0);
        assert_eq!(g.k.at(&[0, 1, 0, 0, 0]), 0.0, "other rows untouched");

        // Round trip against the full splice: used_len == seq must be
        // bit-identical to join().
        let mut a = group();
        let ra = a.join_prefix(1, &k1, &v1, 8).unwrap();
        let mut b = group();
        let rb = b.join(1, &k1, &v1).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(a.k, b.k);
        assert_eq!(a.v, b.v);

        // And the spliced prefix survives a gather/scatter round trip.
        let mut sk = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
        let mut sv = sk.clone();
        g.gather_rows(&[(row, 3)], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 2, 0]), 7.0);
        assert_eq!(sk.at(&[0, 0, 0, 5, 0]), 0.0);
        g.scatter_rows(&[(row, 3)], &sk, &sv).unwrap();
        assert_eq!(g.k.at(&[1, row, 1, 2, 3]), 7.0);

        // Validation: oversized used_len, duplicate slot, full group.
        assert!(g.join_prefix(12, &k1, &v1, 9).is_err(), "used_len > seq");
        assert!(g.join_prefix(11, &k1, &v1, 2).is_err(), "duplicate slot");
        g.join_prefix(12, &k1, &v1, 1).unwrap();
        g.join_prefix(13, &k1, &v1, 1).unwrap();
        assert!(g.join_prefix(14, &k1, &v1, 1).is_err(), "full group");
    }

    #[test]
    fn join_prefix_from_row_splices_the_selected_source_row() {
        // A 2-row source whose row 1 is the request's prefix; rows join from
        // it directly (no single-row intermediate).
        let mut src_k = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        for (i, x) in src_k.data.iter_mut().enumerate() {
            *x = i as f32;
        }
        let src_v = src_k.clone();
        let mut g = group();
        let row = g.join_prefix_from_row(5, &src_k, &src_v, 1, 3).unwrap();
        assert_eq!(g.occupant(row), Some(5));
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), src_k.at(&[0, 1, 0, 0, 0]));
        assert_eq!(g.k.at(&[1, row, 1, 2, 3]), src_k.at(&[1, 1, 1, 2, 3]));
        assert_eq!(g.k.at(&[0, row, 0, 3, 0]), 0.0, "beyond used_len zeroed");
        // Row 0 of a single-row source matches plain join_prefix exactly.
        let (k1, v1) = row_cache(3.0);
        let mut a = group();
        let ra = a.join_prefix_from_row(1, &k1, &v1, 0, 4).unwrap();
        let mut b = group();
        let rb = b.join_prefix(1, &k1, &v1, 4).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(a.k, b.k);
        // Out-of-range source row is an error, not a panic.
        let mut c = group();
        assert!(c.join_prefix_from_row(1, &src_k, &src_v, 2, 3).is_err());
    }

    #[test]
    fn leave_frees_and_zeroes() {
        let mut g = group();
        let (k1, v1) = row_cache(5.0);
        let row = g.join(9, &k1, &v1).unwrap();
        assert_eq!(g.leave(row).unwrap(), 9);
        assert_eq!(g.free_rows(), 3);
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), 0.0);
        assert!(g.leave(row).is_err(), "double leave");
        assert!(g.is_empty());
    }

    #[test]
    fn rows_are_reused_after_leave() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        let r0 = g.join(1, &k1, &v1).unwrap();
        g.join(2, &k1, &v1).unwrap();
        g.leave(r0).unwrap();
        let r2 = g.join(3, &k1, &v1).unwrap();
        assert_eq!(r2, r0, "freed row is reused");
    }

    #[test]
    fn gather_scatter_round_trip_preserves_rows() {
        let mut g = group();
        for (slot, fill) in [(1, 10.0f32), (2, 20.0), (3, 30.0)] {
            let (k1, v1) = row_cache(fill);
            g.join(slot, &k1, &v1).unwrap();
        }
        let before_k = g.k.clone();
        // gather rows 2 and 0 (in that order) into a 2-bucket scratch
        let mut sk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut sv = sk.clone();
        g.gather_rows(&[(2, 8), (0, 8)], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 0, 0]), 30.0, "scratch row 0 = group row 2");
        assert_eq!(sk.at(&[1, 1, 1, 7, 3]), 10.0, "scratch row 1 = group row 0");
        // scatter straight back: the group must be bit-identical
        g.scatter_rows(&[(2, 8), (0, 8)], &sk, &sv).unwrap();
        assert_eq!(g.k, before_k, "gather->scatter round trip changed the cache");
        // an advanced scratch lands in the right group rows only
        sk.data.iter_mut().for_each(|x| *x += 1.0);
        g.scatter_rows(&[(2, 8), (0, 8)], &sk, &sk.clone()).unwrap();
        assert_eq!(g.k.at(&[0, 2, 0, 0, 0]), 31.0);
        assert_eq!(g.k.at(&[0, 0, 0, 0, 0]), 11.0);
        assert_eq!(g.k.at(&[0, 1, 0, 0, 0]), 20.0, "row outside the map untouched");
    }

    #[test]
    fn gather_into_oversize_bucket_pads_and_leaves_tail_rows() {
        let mut g = group();
        let (k1, v1) = row_cache(4.0);
        g.join(7, &k1, &v1).unwrap();
        let mut sk = Tensor::<f32>::zeros(&[2, 4, 2, 8, 4]);
        sk.data.iter_mut().for_each(|x| *x = -1.0); // dirty pooled scratch
        let mut sv = sk.clone();
        g.gather_rows(&[(0, 8)], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 0, 0]), 4.0);
        assert_eq!(sk.at(&[0, 3, 0, 0, 0]), -1.0, "padding rows left as-is");
    }

    #[test]
    fn length_bounded_gather_scatter_leave_padding_positions_untouched() {
        // Satellite regression: gather/scatter moved the full max_seq
        // extent per row regardless of committed length. Both must now be
        // bounded — scratch (and group) positions past each row's length
        // keep their prior contents bit-for-bit.
        let mut g = group(); // seq = 8
        let (k1, v1) = row_cache(7.0);
        let row = g.join_prefix(1, &k1, &v1, 4).unwrap(); // 4 committed
        let mut sk = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
        sk.data.iter_mut().for_each(|x| *x = -9.0); // dirty pooled scratch
        let mut sv = sk.clone();
        g.gather_rows(&[(row, 4)], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 3, 0]), 7.0, "committed positions copied");
        assert_eq!(sk.at(&[0, 0, 0, 4, 0]), -9.0, "padding positions untouched");
        assert_eq!(sk.at(&[1, 0, 1, 7, 3]), -9.0);

        // Scatter back 5 positions (one-token advance): group position 5..
        // must stay exactly as it was (zero), not absorb scratch garbage.
        sk.data.iter_mut().for_each(|x| {
            if *x == -9.0 { *x = -5.0; }
        });
        let sv2 = sk.clone();
        g.scatter_rows(&[(row, 5)], &sk, &sv2).unwrap();
        assert_eq!(g.k.at(&[0, row, 0, 4, 0]), -5.0, "advanced position written");
        assert_eq!(g.k.at(&[0, row, 0, 5, 0]), 0.0, "beyond the advance untouched");
        assert_eq!(g.k.at(&[1, row, 1, 7, 3]), 0.0);
    }

    #[test]
    fn written_invariant_holds_across_join_advance_leave_cycles() {
        // Satellite: leave() zeroes only the written prefix; the "positions
        // past written are zero" invariant must survive arbitrary
        // join/advance/leave cycles, including re-joining a freed row with
        // a shorter prefix and whole-cache dirtying via note_written.
        let seq = 8usize;
        let all_zero_past = |g: &BatchGroup, row: usize, from: usize| {
            for l in 0..2 {
                for h in 0..2 {
                    for s in from..seq {
                        for d in 0..4 {
                            assert_eq!(g.k.at(&[l, row, h, s, d]), 0.0,
                                       "k[{l},{row},{h},{s},{d}] not zero");
                            assert_eq!(g.v.at(&[l, row, h, s, d]), 0.0);
                        }
                    }
                }
            }
        };
        let mut g = group();
        let (k1, v1) = row_cache(3.0);
        let row = g.join_prefix(1, &k1, &v1, 3).unwrap();
        all_zero_past(&g, row, 3);
        // Advance: scatter 6 valid positions (3 committed + 3 speculative).
        let mut sk = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
        sk.data.iter_mut().for_each(|x| *x = 2.0);
        let sv = sk.clone();
        g.scatter_rows(&[(row, 6)], &sk, &sv).unwrap();
        all_zero_past(&g, row, 6);
        // A shorter follow-up advance must not shrink the high-water mark.
        g.scatter_rows(&[(row, 4)], &sk, &sv).unwrap();
        g.leave(row).unwrap();
        all_zero_past(&g, row, 0);
        // Re-join the same (freed) row with a shorter prefix: still clean.
        let row2 = g.join_prefix(2, &k1, &v1, 2).unwrap();
        assert_eq!(row2, row, "freed row reused");
        all_zero_past(&g, row2, 2);
        // Whole-cache adoption dirties rows the row map never covered:
        // note_written keeps leave() honest about it.
        g.k.data.iter_mut().for_each(|x| *x = 1.0);
        g.v.data.iter_mut().for_each(|x| *x = 1.0);
        for r in 0..3 {
            g.note_written(r, seq);
        }
        g.leave(row2).unwrap();
        all_zero_past(&g, row2, 0);
    }

    #[test]
    fn gather_scatter_validate_rows_and_shapes() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        g.join(1, &k1, &v1).unwrap();
        let mut sk = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
        let mut sv = sk.clone();
        assert!(g.gather_rows(&[(1, 8)], &mut sk, &mut sv).is_err(), "row 1 not leased");
        assert!(g.gather_rows(&[(9, 8)], &mut sk, &mut sv).is_err(), "row out of range");
        assert!(g.gather_rows(&[(0, 8), (0, 8)], &mut sk, &mut sv).is_err(),
                "bucket too small");
        assert!(g.gather_rows(&[(0, 9)], &mut sk, &mut sv).is_err(), "length > seq");
        let mut bad = Tensor::<f32>::zeros(&[2, 1, 2, 6, 4]);
        assert!(g.gather_rows(&[(0, 6)], &mut bad, &mut sv.clone()).is_err(),
                "seq mismatch");
        assert!(g.scatter_rows(&[(9, 8)], &sk, &sv).is_err());
        assert!(g.gather_rows(&[(0, 8)], &mut sk, &mut sv).is_ok());
        assert!(g.scatter_rows(&[(0, 8)], &sk, &sv).is_ok());

        // Regression: a duplicated row index used to pass validation even
        // when the scratch had room — scatter then double-wrote the group
        // row (last scratch row silently winning) and gather aliased one
        // lease across two scratch rows. Must be rejected outright.
        let (k2, v2) = row_cache(2.0);
        g.join(2, &k2, &v2).unwrap(); // second lease so [0, 0] isn't "too small"
        let mut sk2 = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut sv2 = sk2.clone();
        assert!(
            g.gather_rows(&[(0, 8), (0, 8)], &mut sk2, &mut sv2).is_err(),
            "duplicate gather rows must be rejected"
        );
        assert!(
            g.scatter_rows(&[(0, 8), (0, 8)], &sk2, &sv2).is_err(),
            "duplicate scatter rows must be rejected"
        );
        assert!(g.gather_rows(&[(1, 8), (0, 8)], &mut sk2, &mut sv2).is_ok(),
                "distinct rows still fine");
    }

    // ---- PagedGroup ----

    const PDIMS: [usize; 5] = [2, 1, 2, 8, 4]; // single-row cache shape
    const PAGE: usize = 4;

    fn pool() -> PrefixCache {
        PrefixCache::new(PrefixCacheConfig {
            page_tokens: PAGE,
            min_prefix: 2,
            ..Default::default()
        })
    }

    /// Single-row cache whose position `s` holds `tokens[s]`.
    fn row_for(tokens: &[i32]) -> (Tensor<f32>, Tensor<f32>) {
        let mut k = Tensor::<f32>::zeros(&PDIMS);
        let mut v = Tensor::<f32>::zeros(&PDIMS);
        for l in 0..PDIMS[0] {
            for h in 0..PDIMS[2] {
                for (s, &t) in tokens.iter().enumerate() {
                    for d in 0..PDIMS[4] {
                        let off = (((l * PDIMS[2]) + h) * PDIMS[3] + s) * PDIMS[4] + d;
                        k.data[off] = t as f32;
                        v.data[off] = t as f32 + 0.5;
                    }
                }
            }
        }
        (k, v)
    }

    #[test]
    fn paged_join_gather_scatter_leave_round_trip() {
        let mut pool = pool();
        let mut g = PagedGroup::new(2, PAGE, 8);
        let tokens: Vec<i32> = vec![10, 11, 12, 13, 14]; // 1 full page + tail
        let (k, v) = row_for(&tokens);
        let rp = pool.lease_row_pages("fp32", &tokens, &k, &v, 0).unwrap();
        let row = g.join_pages(7, rp.pages, tokens.len()).unwrap();
        assert_eq!(g.occupant(row), Some(7));
        assert_eq!(g.row_len(row), Some(5));
        assert_eq!(g.free_rows(), 1);
        assert_eq!(g.active_rows(), vec![(row, 7)]);

        // Gather reproduces the committed prefix; dirty scratch positions
        // past it stay untouched.
        let mut sk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        sk.data.iter_mut().for_each(|x| *x = -9.0);
        let mut sv = sk.clone();
        g.gather_rows(&pool, &[(row, 5)], &mut sk, &mut sv).unwrap();
        for s in 0..5 {
            assert_eq!(sk.at(&[0, 0, 0, s, 0]), tokens[s] as f32, "position {s}");
            assert_eq!(sv.at(&[1, 0, 1, s, 3]), tokens[s] as f32 + 0.5);
        }
        assert_eq!(sk.at(&[0, 0, 0, 5, 0]), -9.0, "padding untouched");
        assert_eq!(sk.at(&[0, 1, 0, 0, 0]), -9.0, "other scratch rows untouched");

        // Advance: the chunk wrote positions [5, 7); scatter only those.
        for s in 5..7 {
            for l in 0..2 {
                for h in 0..2 {
                    for d in 0..4 {
                        let off = ((((l * 2) * 2 + h) * 8) + s) * 4 + d;
                        sk.data[off] = 90.0 + s as f32;
                        sv.data[off] = 90.5 + s as f32;
                    }
                }
            }
        }
        let pages_before = pool.stats().resident_pages;
        g.scatter_advance(&mut pool, &[(row, 5, 7)], &sk, &sv).unwrap();
        assert_eq!(pool.stats().resident_pages, pages_before + 1,
                   "one fresh frontier page for positions [5, 8)");
        g.set_len(row, 7).unwrap();
        // Re-gather sees the advance.
        let mut rk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut rv = rk.clone();
        g.gather_rows(&pool, &[(row, 7)], &mut rk, &mut rv).unwrap();
        assert_eq!(rk.at(&[0, 0, 0, 6, 0]), 96.0);
        assert_eq!(rv.at(&[1, 0, 1, 5, 3]), 95.5);
        assert_eq!(rk.at(&[0, 0, 0, 4, 0]), 14.0, "committed prefix intact");

        // Leave releases every page reference.
        assert_eq!(g.leave(&mut pool, row).unwrap(), 7);
        assert!(g.is_empty());
        assert_eq!(pool.stats().row_page_refs, 0);
        assert!(g.leave(&mut pool, row).is_err(), "double leave");
    }

    #[test]
    fn paged_rows_share_cached_pages_and_never_write_them() {
        let mut pool = pool();
        let mut g = PagedGroup::new(2, PAGE, 8);
        let template: Vec<i32> = vec![5; PAGE]; // one full page
        let (k, v) = row_for(&template);
        pool.insert("fp32", &template, &k, &v);

        // Two rows admit on the same cached template: one physical page.
        let rp1 = pool.lease_row_pages("fp32", &template, &k, &v, 0).unwrap();
        let rp2 = pool.lease_row_pages("fp32", &template, &k, &v, 0).unwrap();
        assert_eq!(rp1.pages, rp2.pages, "both rows reference the same page");
        assert_eq!(rp1.shared + rp2.shared, 2);
        assert_eq!(pool.stats().row_copied_pages, 0, "zero full-page copies warm");
        let shared_pid = rp1.pages[0];
        let r1 = g.join_pages(1, rp1.pages, PAGE).unwrap();
        let r2 = g.join_pages(2, rp2.pages, PAGE).unwrap();
        assert_eq!(pool.page_ref_count(shared_pid), Some(3), "run + two rows");

        // Advancing writes the frontier (a fresh page), never the shared
        // page — which both rows keep reading correctly.
        let (sk, sv) = {
            let mut t: Vec<i32> = template.clone();
            t.extend([8]);
            row_for(&t)
        };
        let mut bk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut bv = bk.clone();
        bk.copy_axis1_row_from(0, &sk, 0);
        bv.copy_axis1_row_from(0, &sv, 0);
        g.scatter_advance(&mut pool, &[(r1, PAGE, PAGE + 1)], &bk, &bv).unwrap();
        g.set_len(r1, PAGE + 1).unwrap();
        assert_eq!(pool.page_ref_count(shared_pid), Some(3), "shared page untouched");
        let mut gk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut gv = gk.clone();
        g.gather_rows(&pool, &[(r2, PAGE), (r1, PAGE + 1)], &mut gk, &mut gv).unwrap();
        assert_eq!(gk.at(&[0, 0, 0, PAGE - 1, 0]), 5.0, "row 2 reads the template");
        assert_eq!(gk.at(&[0, 1, 0, PAGE, 0]), 8.0, "row 1 reads its advance");

        g.leave(&mut pool, r1).unwrap();
        g.leave(&mut pool, r2).unwrap();
        assert_eq!(pool.page_ref_count(shared_pid), Some(1), "run reference remains");
        assert_eq!(pool.stats().row_page_refs, 0);
    }

    #[test]
    fn paged_group_validates_like_the_slab_group() {
        let mut pool = pool();
        let mut g = PagedGroup::new(2, PAGE, 8);
        let tokens: Vec<i32> = vec![1, 2, 3];
        let (k, v) = row_for(&tokens);
        let rp = pool.lease_row_pages("fp32", &tokens, &k, &v, 0).unwrap();
        let row = g.join_pages(1, rp.pages, 3).unwrap();
        // Duplicate slot, bad coverage, oversize len.
        assert!(g.join_pages(1, vec![], 0).is_err(), "duplicate slot");
        assert!(g.join_pages(2, vec![], 3).is_err(), "no pages for 3 tokens");
        assert!(g.join_pages(2, vec![1, 2, 3], 9).is_err(), "len > max_seq");
        // Gather beyond committed, unleased rows, duplicates.
        let mut sk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut sv = sk.clone();
        assert!(g.gather_rows(&pool, &[(row, 4)], &mut sk, &mut sv).is_err(),
                "gather past committed");
        assert!(g.gather_rows(&pool, &[(1, 1)], &mut sk, &mut sv).is_err(), "not leased");
        assert!(g.gather_rows(&pool, &[(row, 3), (row, 3)], &mut sk, &mut sv).is_err(),
                "duplicate rows");
        assert!(g.scatter_advance(&mut pool, &[(row, 5, 4)], &sk, &sv).is_err(),
                "inverted range");
        assert!(g.scatter_advance(&mut pool, &[(row, 3, 9)], &sk, &sv).is_err(),
                "range past max_seq");
        assert!(g.scatter_advance(&mut pool, &[(row, 6, 7)], &sk, &sv).is_err(),
                "page gap");
        assert!(g.set_len(row, 9).is_err(), "len past max_seq");
        assert!(g.set_len(row, 5).is_err(), "len past page coverage");
        g.leave(&mut pool, row).unwrap();
        assert_eq!(pool.stats().resident_bytes, 0);
    }
}

//! Batch-group KV-cache manager.
//!
//! The engine keeps one *batch group* per serving configuration: a
//! persistent `[L, B, H, S, hd]` cache whose rows are leased to requests.
//! Joining a request splices a prefilled row in; leaving zeroes the row.
//! Row state never moves between steps — continuous batching without cache
//! shuffling. Join sources are row-addressed
//! ([`BatchGroup::join_prefix_from_row`]): admission joins from row 0 of
//! the prefill output (paged prefix-cache splice + suffix chunk writes),
//! bounded to the prompt's valid length; sources with more than one batch
//! row work the same way with the holding row selected by index.
//!
//! Execution no longer adopts a whole returned cache: the elastic step
//! planner (`coordinator::plan`) runs each sub-batch against a
//! *bucket-shaped scratch cache*, so the group exposes per-row movement
//! instead — [`BatchGroup::gather_rows`] copies leased rows into scratch row
//! order before a chunk runs, and [`BatchGroup::scatter_rows`] copies the
//! advanced rows back afterwards. Rows outside the sub-batch are never
//! touched, which also means freed rows stay zeroed instead of accumulating
//! speculative garbage.

use anyhow::{bail, Result};

use crate::runtime::Tensor;

/// A leased-row batched KV cache.
pub struct BatchGroup {
    pub k: Tensor<f32>,
    pub v: Tensor<f32>,
    /// `rows[i] = Some(request_slot)` when leased.
    rows: Vec<Option<usize>>,
    pub batch: usize,
}

impl BatchGroup {
    pub fn new(n_layers: usize, batch: usize, n_heads: usize, max_seq: usize,
               head_dim: usize) -> Self {
        let dims = [n_layers, batch, n_heads, max_seq, head_dim];
        BatchGroup {
            k: Tensor::zeros(&dims),
            v: Tensor::zeros(&dims),
            rows: vec![None; batch],
            batch,
        }
    }

    pub fn free_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.is_none()).count()
    }

    pub fn active_rows(&self) -> Vec<(usize, usize)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|slot| (i, slot)))
            .collect()
    }

    pub fn occupant(&self, row: usize) -> Option<usize> {
        self.rows[row]
    }

    pub fn is_empty(&self) -> bool {
        self.rows.iter().all(|r| r.is_none())
    }

    /// Lease a free row to `slot`, splicing in its prefilled single-row
    /// cache (`[L, 1, H, S, hd]`) whole — [`BatchGroup::join_prefix`] at
    /// the full sequence extent.
    pub fn join(&mut self, slot: usize, k1: &Tensor<f32>, v1: &Tensor<f32>) -> Result<usize> {
        let seq = self.k.dims[self.k.rank() - 2];
        self.join_prefix(slot, k1, v1, seq)
    }

    /// Length-bounded [`BatchGroup::join`]: lease a free row but splice only
    /// the first `used_len` sequence positions of the single-row cache and
    /// zero the rest of the row. An admission only has `prompt_len` valid
    /// KV positions — the full-`max_seq` copy moved (and kept resident)
    /// whatever garbage the prefill chunk wrote past the prompt.
    pub fn join_prefix(&mut self, slot: usize, k1: &Tensor<f32>, v1: &Tensor<f32>,
                       used_len: usize) -> Result<usize> {
        if k1.dims[1] != 1 || v1.dims[1] != 1 {
            bail!("expected single-row cache, got batch {}", k1.dims[1]);
        }
        self.join_prefix_from_row(slot, k1, v1, 0, used_len)
    }

    /// [`BatchGroup::join_prefix`] from one row of a *multi-row* source —
    /// the shape page-run assembly produces: a prefill output, a gathered
    /// scratch cache, or any `[L, B', H, S, hd]` pair whose row `src_row`
    /// holds the request's committed prefix.
    pub fn join_prefix_from_row(&mut self, slot: usize, k_src: &Tensor<f32>,
                                v_src: &Tensor<f32>, src_row: usize,
                                used_len: usize) -> Result<usize> {
        if self.rows.iter().any(|r| *r == Some(slot)) {
            bail!("slot {slot} already in group");
        }
        let row = match self.rows.iter().position(|r| r.is_none()) {
            Some(r) => r,
            None => bail!("no free row in batch group"),
        };
        if k_src.dims != v_src.dims {
            bail!("source k/v dims differ: {:?} vs {:?}", k_src.dims, v_src.dims);
        }
        if src_row >= k_src.dims[1] {
            bail!("source row {src_row} out of range for batch {}", k_src.dims[1]);
        }
        let seq = self.k.dims[self.k.rank() - 2];
        if used_len > seq {
            bail!("used_len {used_len} exceeds cache seq {seq}");
        }
        if used_len < seq {
            // The full-extent splice overwrites every position anyway.
            self.k.zero_axis1_row(row);
            self.v.zero_axis1_row(row);
        }
        self.k.copy_axis1_row_seq_prefix_from(row, k_src, src_row, used_len);
        self.v.copy_axis1_row_seq_prefix_from(row, v_src, src_row, used_len);
        self.rows[row] = Some(slot);
        Ok(row)
    }

    /// Release a row (request finished); zeroes it defensively so a stale
    /// read would produce obviously-wrong attention rather than plausible
    /// leakage from the previous occupant.
    pub fn leave(&mut self, row: usize) -> Result<usize> {
        let Some(slot) = self.rows[row] else {
            bail!("row {row} not leased");
        };
        self.rows[row] = None;
        self.k.zero_axis1_row(row);
        self.v.zero_axis1_row(row);
        Ok(slot)
    }

    /// Check a gather/scatter row map against the group and a scratch shape:
    /// every group row leased, in range and **unique**, scratch large
    /// enough, dims matching everywhere but the batch axis.
    fn check_row_map(&self, rows: &[usize], k: &Tensor<f32>, v: &Tensor<f32>) -> Result<()> {
        if k.dims != v.dims {
            bail!("scratch k/v dims differ: {:?} vs {:?}", k.dims, v.dims);
        }
        if k.dims.len() != self.k.dims.len()
            || k.dims[0] != self.k.dims[0]
            || k.dims[2..] != self.k.dims[2..]
        {
            bail!("scratch dims {:?} incompatible with group {:?}", k.dims, self.k.dims);
        }
        if rows.len() > k.dims[1] {
            bail!("{} rows exceed scratch bucket {}", rows.len(), k.dims[1]);
        }
        // Duplicates would double-write on scatter (last scratch row wins
        // silently) and alias one lease across two scratch rows on gather —
        // reject rather than guess which copy the caller meant.
        let mut seen = vec![false; self.batch];
        for &r in rows {
            if r >= self.batch {
                bail!("row {r} out of range for batch {}", self.batch);
            }
            if self.rows[r].is_none() {
                bail!("row {r} not leased");
            }
            if seen[r] {
                bail!("duplicate row {r} in row map");
            }
            seen[r] = true;
        }
        Ok(())
    }

    /// Copy leased group rows into a bucket-shaped scratch cache pair:
    /// scratch row `i` receives group row `rows[i]`. Scratch rows beyond
    /// `rows.len()` are left as-is (padding the executed bucket; per-row
    /// attention never reads across batch rows).
    pub fn gather_rows(&self, rows: &[usize], k_dst: &mut Tensor<f32>,
                       v_dst: &mut Tensor<f32>) -> Result<()> {
        self.check_row_map(rows, k_dst, v_dst)?;
        let pairs: Vec<(usize, usize)> =
            rows.iter().enumerate().map(|(i, &r)| (i, r)).collect();
        k_dst.copy_axis1_rows(&pairs, &self.k);
        v_dst.copy_axis1_rows(&pairs, &self.v);
        Ok(())
    }

    /// Copy advanced scratch rows back into the group: group row `rows[i]`
    /// receives scratch row `i` — the inverse of [`BatchGroup::gather_rows`]
    /// after a chunk execution advanced the scratch.
    pub fn scatter_rows(&mut self, rows: &[usize], k_src: &Tensor<f32>,
                        v_src: &Tensor<f32>) -> Result<()> {
        self.check_row_map(rows, k_src, v_src)?;
        let pairs: Vec<(usize, usize)> =
            rows.iter().enumerate().map(|(i, &r)| (r, i)).collect();
        self.k.copy_axis1_rows(&pairs, k_src);
        self.v.copy_axis1_rows(&pairs, v_src);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group() -> BatchGroup {
        BatchGroup::new(2, 3, 2, 8, 4)
    }

    fn row_cache(fill: f32) -> (Tensor<f32>, Tensor<f32>) {
        let dims = [2, 1, 2, 8, 4];
        let mut k = Tensor::zeros(&dims);
        k.data.iter_mut().for_each(|x| *x = fill);
        let v = k.clone();
        (k, v)
    }

    #[test]
    fn join_leases_first_free_row_and_splices() {
        let mut g = group();
        let (k1, v1) = row_cache(7.0);
        let row = g.join(42, &k1, &v1).unwrap();
        assert_eq!(row, 0);
        assert_eq!(g.free_rows(), 2);
        assert_eq!(g.occupant(0), Some(42));
        assert_eq!(g.k.at(&[1, 0, 1, 3, 2]), 7.0);
        assert_eq!(g.k.at(&[1, 1, 1, 3, 2]), 0.0, "other rows untouched");
        assert_eq!(g.active_rows(), vec![(0, 42)]);
    }

    #[test]
    fn join_rejects_duplicate_slot_and_full_group() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        g.join(1, &k1, &v1).unwrap();
        assert!(g.join(1, &k1, &v1).is_err(), "duplicate slot");
        g.join(2, &k1, &v1).unwrap();
        g.join(3, &k1, &v1).unwrap();
        assert!(g.join(4, &k1, &v1).is_err(), "full group");
    }

    #[test]
    fn join_prefix_splices_used_positions_and_zeroes_the_rest() {
        let mut g = group(); // seq axis = 8
        let (k1, v1) = row_cache(7.0); // every position non-zero
        let row = g.join_prefix(11, &k1, &v1, 3).unwrap();
        assert_eq!(g.occupant(row), Some(11));
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), 7.0);
        assert_eq!(g.k.at(&[1, row, 1, 2, 3]), 7.0);
        assert_eq!(g.k.at(&[0, row, 0, 3, 0]), 0.0, "beyond used_len zeroed");
        assert_eq!(g.v.at(&[1, row, 1, 7, 3]), 0.0);
        assert_eq!(g.k.at(&[0, 1, 0, 0, 0]), 0.0, "other rows untouched");

        // Round trip against the full splice: used_len == seq must be
        // bit-identical to join().
        let mut a = group();
        let ra = a.join_prefix(1, &k1, &v1, 8).unwrap();
        let mut b = group();
        let rb = b.join(1, &k1, &v1).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(a.k, b.k);
        assert_eq!(a.v, b.v);

        // And the spliced prefix survives a gather/scatter round trip.
        let mut sk = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
        let mut sv = sk.clone();
        g.gather_rows(&[row], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 2, 0]), 7.0);
        assert_eq!(sk.at(&[0, 0, 0, 5, 0]), 0.0);
        g.scatter_rows(&[row], &sk, &sv).unwrap();
        assert_eq!(g.k.at(&[1, row, 1, 2, 3]), 7.0);

        // Validation: oversized used_len, duplicate slot, full group.
        assert!(g.join_prefix(12, &k1, &v1, 9).is_err(), "used_len > seq");
        assert!(g.join_prefix(11, &k1, &v1, 2).is_err(), "duplicate slot");
        g.join_prefix(12, &k1, &v1, 1).unwrap();
        g.join_prefix(13, &k1, &v1, 1).unwrap();
        assert!(g.join_prefix(14, &k1, &v1, 1).is_err(), "full group");
    }

    #[test]
    fn join_prefix_from_row_splices_the_selected_source_row() {
        // A 2-row source whose row 1 is the request's prefix; rows join from
        // it directly (no single-row intermediate).
        let mut src_k = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        for (i, x) in src_k.data.iter_mut().enumerate() {
            *x = i as f32;
        }
        let src_v = src_k.clone();
        let mut g = group();
        let row = g.join_prefix_from_row(5, &src_k, &src_v, 1, 3).unwrap();
        assert_eq!(g.occupant(row), Some(5));
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), src_k.at(&[0, 1, 0, 0, 0]));
        assert_eq!(g.k.at(&[1, row, 1, 2, 3]), src_k.at(&[1, 1, 1, 2, 3]));
        assert_eq!(g.k.at(&[0, row, 0, 3, 0]), 0.0, "beyond used_len zeroed");
        // Row 0 of a single-row source matches plain join_prefix exactly.
        let (k1, v1) = row_cache(3.0);
        let mut a = group();
        let ra = a.join_prefix_from_row(1, &k1, &v1, 0, 4).unwrap();
        let mut b = group();
        let rb = b.join_prefix(1, &k1, &v1, 4).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(a.k, b.k);
        // Out-of-range source row is an error, not a panic.
        let mut c = group();
        assert!(c.join_prefix_from_row(1, &src_k, &src_v, 2, 3).is_err());
    }

    #[test]
    fn leave_frees_and_zeroes() {
        let mut g = group();
        let (k1, v1) = row_cache(5.0);
        let row = g.join(9, &k1, &v1).unwrap();
        assert_eq!(g.leave(row).unwrap(), 9);
        assert_eq!(g.free_rows(), 3);
        assert_eq!(g.k.at(&[0, row, 0, 0, 0]), 0.0);
        assert!(g.leave(row).is_err(), "double leave");
        assert!(g.is_empty());
    }

    #[test]
    fn rows_are_reused_after_leave() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        let r0 = g.join(1, &k1, &v1).unwrap();
        g.join(2, &k1, &v1).unwrap();
        g.leave(r0).unwrap();
        let r2 = g.join(3, &k1, &v1).unwrap();
        assert_eq!(r2, r0, "freed row is reused");
    }

    #[test]
    fn gather_scatter_round_trip_preserves_rows() {
        let mut g = group();
        for (slot, fill) in [(1, 10.0f32), (2, 20.0), (3, 30.0)] {
            let (k1, v1) = row_cache(fill);
            g.join(slot, &k1, &v1).unwrap();
        }
        let before_k = g.k.clone();
        // gather rows 2 and 0 (in that order) into a 2-bucket scratch
        let mut sk = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut sv = sk.clone();
        g.gather_rows(&[2, 0], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 0, 0]), 30.0, "scratch row 0 = group row 2");
        assert_eq!(sk.at(&[1, 1, 1, 7, 3]), 10.0, "scratch row 1 = group row 0");
        // scatter straight back: the group must be bit-identical
        g.scatter_rows(&[2, 0], &sk, &sv).unwrap();
        assert_eq!(g.k, before_k, "gather->scatter round trip changed the cache");
        // an advanced scratch lands in the right group rows only
        sk.data.iter_mut().for_each(|x| *x += 1.0);
        g.scatter_rows(&[2, 0], &sk, &sk.clone()).unwrap();
        assert_eq!(g.k.at(&[0, 2, 0, 0, 0]), 31.0);
        assert_eq!(g.k.at(&[0, 0, 0, 0, 0]), 11.0);
        assert_eq!(g.k.at(&[0, 1, 0, 0, 0]), 20.0, "row outside the map untouched");
    }

    #[test]
    fn gather_into_oversize_bucket_pads_and_leaves_tail_rows() {
        let mut g = group();
        let (k1, v1) = row_cache(4.0);
        g.join(7, &k1, &v1).unwrap();
        let mut sk = Tensor::<f32>::zeros(&[2, 4, 2, 8, 4]);
        sk.data.iter_mut().for_each(|x| *x = -1.0); // dirty pooled scratch
        let mut sv = sk.clone();
        g.gather_rows(&[0], &mut sk, &mut sv).unwrap();
        assert_eq!(sk.at(&[0, 0, 0, 0, 0]), 4.0);
        assert_eq!(sk.at(&[0, 3, 0, 0, 0]), -1.0, "padding rows left as-is");
    }

    #[test]
    fn gather_scatter_validate_rows_and_shapes() {
        let mut g = group();
        let (k1, v1) = row_cache(1.0);
        g.join(1, &k1, &v1).unwrap();
        let mut sk = Tensor::<f32>::zeros(&[2, 1, 2, 8, 4]);
        let mut sv = sk.clone();
        assert!(g.gather_rows(&[1], &mut sk, &mut sv).is_err(), "row 1 not leased");
        assert!(g.gather_rows(&[9], &mut sk, &mut sv).is_err(), "row out of range");
        assert!(g.gather_rows(&[0, 0], &mut sk, &mut sv).is_err(), "bucket too small");
        let mut bad = Tensor::<f32>::zeros(&[2, 1, 2, 6, 4]);
        assert!(g.gather_rows(&[0], &mut bad, &mut sv.clone()).is_err(), "seq mismatch");
        assert!(g.scatter_rows(&[9], &sk, &sv).is_err());
        assert!(g.gather_rows(&[0], &mut sk, &mut sv).is_ok());
        assert!(g.scatter_rows(&[0], &sk, &sv).is_ok());

        // Regression: a duplicated row index used to pass validation even
        // when the scratch had room — scatter then double-wrote the group
        // row (last scratch row silently winning) and gather aliased one
        // lease across two scratch rows. Must be rejected outright.
        let (k2, v2) = row_cache(2.0);
        g.join(2, &k2, &v2).unwrap(); // second lease so [0, 0] isn't "too small"
        let mut sk2 = Tensor::<f32>::zeros(&[2, 2, 2, 8, 4]);
        let mut sv2 = sk2.clone();
        assert!(
            g.gather_rows(&[0, 0], &mut sk2, &mut sv2).is_err(),
            "duplicate gather rows must be rejected"
        );
        assert!(
            g.scatter_rows(&[0, 0], &sk2, &sv2).is_err(),
            "duplicate scatter rows must be rejected"
        );
        assert!(g.gather_rows(&[1, 0], &mut sk2, &mut sv2).is_ok(), "distinct rows still fine");
    }
}

//! Replica-fleet dispatch plane: the top tier of the two-tier coordinator.
//!
//! One engine replica — one `EngineHandle`, with its own engine thread,
//! scheduler, governor, step loop and paged KV pool — is the unit the rest
//! of the stack already knows how to run. This module owns N of them behind
//! a [`ClusterHandle`] that is API-compatible with a bare handle
//! (`submit`/`cancel`/`warm_prefix`/`stats`/`shutdown`), so the server,
//! the leader binary and the benches switch between one engine and a fleet
//! with a `--replicas N` knob. N = 1 degenerates to exactly the bare-engine
//! behavior (same request ids, same admission order, same output bytes) and
//! stays the A/B reference.
//!
//! ## Locality-aware dispatch
//!
//! The shared-prefix paged KV cache (PRs 4–6) only pays off if a
//! conversation's later turns land on the pool that already holds its
//! pages. Dispatch therefore keys each request by its prefix *family*: a
//! [`LocalityIndex`] probe hashes the prompt's page-aligned prefix
//! boundaries (the same key shape the radix trie matches on, without any
//! pool lock) and resolves every turn of a conversation — and every
//! request stamped from the same workload template — to one stable family
//! key. The family key consistent-hashes onto a vnode ring over the
//! replicas, so adding or removing a replica remaps only ~1/N of the key
//! space (asserted by a property test) and multi-turn resubmits land on
//! the replica whose pool holds their pages.
//!
//! ## Work-stealing spillover
//!
//! Locality loses to a hot template: one replica drowns while three idle.
//! When the home replica's in-flight depth is at least
//! [`ClusterConfig::steal_threshold`] and some other replica is strictly
//! shallower, the request is *stolen* to the shallowest replica
//! ([`dispatch_decision`] — a pure function, property-tested). A stolen
//! request admits cold there and is priced as a cold admission (full
//! suffix prefill, cold TTFT bucket) — the steal counter plus the engines'
//! own warm/cold split keep that cost visible rather than averaged away.
//! The `--dispatch random` scatter policy is the control: same fleet, no
//! locality, for the CI A/B that asserts locality's warm hit rate beats it.
//!
//! ## What stays where
//!
//! The dispatcher holds no request state: completions flow on each
//! replica's private ticket channels exactly as before, cancels route by
//! the id-stride rule (`EngineConfig::replicas` — replica r mints ids
//! `r + 1, r + 1 + N, …`, so `(id - 1) % N` recovers the owner with no
//! shared allocator), and stats aggregate by *reading* each replica's
//! lock-free block. The one piece of shared mutable state is the locality
//! index behind a mutex taken for a few hash probes per submit — never
//! across generation, never by engine threads.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::metrics::MetricsDump;
use crate::trace::{EventKind, FlightRecorder, TraceHandle};
use crate::util::hist::Histogram;
use crate::util::json::Json;

use super::engine::EngineConfig;
use super::prefixcache::LocalityIndex;
use super::request::GenParams;
use super::router::{
    BucketStat, EngineHandle, StatsSnapshot, Ticket, VariantCalls,
};

/// How the dispatch plane picks a replica for a new request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchPolicy {
    /// Consistent-hash by prefix family (the default): multi-turn
    /// resubmits and template siblings land on the replica whose paged
    /// pool already holds their pages, with work-stealing spillover.
    #[default]
    Locality,
    /// Deterministic round-robin scatter, ignoring prefixes. The A/B
    /// control that shows what locality buys.
    Random,
}

impl DispatchPolicy {
    pub fn parse(s: &str) -> Option<DispatchPolicy> {
        match s {
            "locality" => Some(DispatchPolicy::Locality),
            "random" => Some(DispatchPolicy::Random),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::Locality => "locality",
            DispatchPolicy::Random => "random",
        }
    }
}

/// Fleet topology and dispatch tuning.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Engine replicas to spawn (>= 1; 1 = the bare-engine A/B reference).
    pub replicas: usize,
    pub dispatch: DispatchPolicy,
    /// Home-replica in-flight depth at which a request may spill to the
    /// shallowest replica. Below it, locality always wins.
    pub steal_threshold: usize,
    /// Virtual nodes per replica on the consistent-hash ring. More vnodes
    /// smooth the key-space split; the default is plenty for single-digit
    /// fleets.
    pub vnodes: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            dispatch: DispatchPolicy::Locality,
            steal_threshold: 8,
            vnodes: 64,
        }
    }
}

// ---------------------------------------------------------------------------
// Pure dispatch machinery (property-tested without engines)
// ---------------------------------------------------------------------------

fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Build the consistent-hash ring: `vnodes` points per replica, sorted by
/// hash. Vnode positions depend only on `(replica index, vnode index)`, so
/// the ring for N replicas shares all its points with the ring for N+1
/// except the new replica's own — which is exactly the ~1/N key-movement
/// property.
pub fn build_ring(replicas: usize, vnodes: usize) -> Vec<(u64, usize)> {
    let mut ring = Vec::with_capacity(replicas * vnodes);
    for r in 0..replicas {
        for v in 0..vnodes {
            let mut key = [0u8; 16];
            key[..8].copy_from_slice(&(r as u64).to_le_bytes());
            key[8..].copy_from_slice(&(v as u64).to_le_bytes());
            ring.push((fnv1a_bytes(&key), r));
        }
    }
    ring.sort_unstable();
    ring
}

/// Map a family key to its home replica: the first vnode clockwise of the
/// key (wrapping).
pub fn ring_assign(ring: &[(u64, usize)], key: u64) -> usize {
    debug_assert!(!ring.is_empty());
    let i = ring.partition_point(|&(h, _)| h < key);
    ring[i % ring.len()].1
}

/// The steal rule, as a pure function of observed depths: stay home unless
/// the home replica's depth has reached `steal_threshold` AND somewhere is
/// strictly shallower — then go to the shallowest replica (lowest index on
/// ties). Returns `(target, stolen)`.
///
/// Two bounds fall out of the rule and are property-tested: a steal never
/// happens while the home replica is below the threshold (locality is
/// never traded away cheaply), and a steal target is always strictly
/// shallower than home (stealing cannot pile onto a deeper replica).
pub fn dispatch_decision(
    home: usize,
    depths: &[usize],
    steal_threshold: usize,
) -> (usize, bool) {
    debug_assert!(home < depths.len());
    if depths[home] < steal_threshold {
        return (home, false);
    }
    let (min_r, &min_d) = depths
        .iter()
        .enumerate()
        .min_by_key(|&(i, &d)| (d, i))
        .expect("non-empty fleet");
    if min_d < depths[home] && min_r != home {
        (min_r, true)
    } else {
        (home, false)
    }
}

/// Recover the replica that minted a request id under the id-stride scheme
/// (`EngineConfig::replicas`): replica r mints `r + 1, r + 1 + N, …`.
pub fn replica_of_id(id: u64, replicas: usize) -> usize {
    let n = replicas.max(1) as u64;
    ((id.max(1) - 1) % n) as usize
}

// ---------------------------------------------------------------------------
// The fleet handle
// ---------------------------------------------------------------------------

/// Where one submission actually landed: the replica the dispatcher chose
/// and whether the steal rule moved it off its locality home. Returned by
/// [`ClusterHandle::submit_dispatch`] so callers (the server's per-request
/// echo, slow-request logging) can attribute a request to its replica
/// without parsing the id stride.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DispatchInfo {
    pub replica: usize,
    pub stolen: bool,
}

/// Dispatch-plane counters, point-in-time. Part of [`ClusterSnapshot`].
#[derive(Debug, Clone, Default)]
pub struct DispatchSnapshot {
    pub policy: String,
    pub steal_threshold: usize,
    /// Requests routed away from their home replica by the steal rule.
    pub steals: u64,
    /// Submits whose prompt matched a recorded prefix boundary in the
    /// locality index (the *dispatcher's* warm hits — the engines' own
    /// `prefix.hit_rate` tells whether the pages were really there).
    pub locality_hits: u64,
    pub locality_misses: u64,
    pub locality_hit_rate: f64,
    /// Submits dispatched to each replica, by replica index.
    pub dispatched: Vec<u64>,
}

/// Fleet-level stats: the aggregated fleet view plus every replica's own
/// snapshot and the dispatch counters.
#[derive(Debug, Clone, Default)]
pub struct ClusterSnapshot {
    pub fleet: StatsSnapshot,
    pub replicas: Vec<StatsSnapshot>,
    pub dispatch: DispatchSnapshot,
}

impl ClusterSnapshot {
    /// JSON shape: the fleet aggregate's keys inlined at the top level —
    /// so every existing `{"cmd":"stats"}` consumer keeps reading the same
    /// keys — plus a `replicas` array (per-replica breakdown) and a
    /// `dispatch` object.
    pub fn to_json(&self) -> Json {
        let mut obj = match self.fleet.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("StatsSnapshot::to_json returns an object"),
        };
        obj.insert(
            "replicas".into(),
            Json::arr(self.replicas.iter().map(|s| s.to_json()).collect()),
        );
        obj.insert(
            "dispatch".into(),
            Json::obj(vec![
                ("policy", Json::str(self.dispatch.policy.clone())),
                (
                    "steal_threshold",
                    Json::num(self.dispatch.steal_threshold as f64),
                ),
                ("steals", Json::num(self.dispatch.steals as f64)),
                (
                    "locality_hits",
                    Json::num(self.dispatch.locality_hits as f64),
                ),
                (
                    "locality_misses",
                    Json::num(self.dispatch.locality_misses as f64),
                ),
                (
                    "locality_hit_rate",
                    Json::num(self.dispatch.locality_hit_rate),
                ),
                (
                    "dispatched",
                    Json::arr(
                        self.dispatch
                            .dispatched
                            .iter()
                            .map(|&d| Json::num(d as f64))
                            .collect(),
                    ),
                ),
            ]),
        );
        Json::Obj(obj)
    }
}

/// Fold per-replica snapshots into one fleet view. Counters sum; rates and
/// means recombine under the weight that produced them (steps for
/// occupancy-style means, completions for scheduling delay, summed
/// hits/misses for hit rates). Latency percentiles come from the replicas'
/// raw histograms merged bucket-wise, so the fleet p99 is the percentile of
/// the *combined* distribution — not a max-fold or weighted mean over
/// replica percentiles, both of which misrepresent bimodal fleets (the
/// bucket-accuracy unit test below builds exactly that case). The max-fold
/// remains only as the fallback when a snapshot carries no histograms.
/// `aggregate(&[s])` reproduces `s` exactly, which is what keeps the
/// 1-replica cluster's stats endpoint bit-compatible with the bare
/// engine's (unit-tested).
pub fn aggregate(snaps: &[StatsSnapshot]) -> StatsSnapshot {
    if snaps.is_empty() {
        return StatsSnapshot::default();
    }
    let sum_u64 = |f: &dyn Fn(&StatsSnapshot) -> u64| snaps.iter().map(f).sum::<u64>();
    let sum_usize =
        |f: &dyn Fn(&StatsSnapshot) -> usize| snaps.iter().map(f).sum::<usize>();
    let sum_f64 = |f: &dyn Fn(&StatsSnapshot) -> f64| snaps.iter().map(f).sum::<f64>();
    // Weighted mean that degenerates to the plain value for one snapshot
    // and to 0 when no weight has accumulated anywhere.
    let wmean = |val: &dyn Fn(&StatsSnapshot) -> f64,
                 weight: &dyn Fn(&StatsSnapshot) -> f64| {
        let total: f64 = snaps.iter().map(weight).sum();
        if total <= 0.0 {
            0.0
        } else {
            snaps.iter().map(|s| val(s) * weight(s)).sum::<f64>() / total
        }
    };
    let max_f64 = |f: &dyn Fn(&StatsSnapshot) -> f64| {
        snaps.iter().map(f).fold(0.0_f64, f64::max)
    };

    let mut buckets: std::collections::BTreeMap<usize, BucketStat> =
        std::collections::BTreeMap::new();
    for s in snaps {
        for b in &s.buckets {
            let e = buckets.entry(b.bucket).or_insert(BucketStat {
                bucket: b.bucket,
                calls: 0,
                mean_rows: 0.0,
            });
            // Calls-weighted mean of mean_rows, folded incrementally.
            let total = e.calls + b.calls;
            if total > 0 {
                e.mean_rows = (e.mean_rows * e.calls as f64
                    + b.mean_rows * b.calls as f64)
                    / total as f64;
            }
            e.calls = total;
        }
    }
    let mut variants: std::collections::BTreeMap<String, u64> =
        std::collections::BTreeMap::new();
    for s in snaps {
        for v in &s.variants {
            *variants.entry(v.variant.clone()).or_insert(0) += v.calls;
        }
    }
    // Per-class draft-depth stats fold by class name: counters sum, and the
    // fleet EWMA recombines under the drafted-token weight that produced
    // each replica's value (steps-weighted would overweight shallow rows).
    let mut gamma: std::collections::BTreeMap<String, super::router::GammaClassStat> =
        std::collections::BTreeMap::new();
    for s in snaps {
        for c in &s.gamma {
            let e = gamma
                .entry(c.class.clone())
                .or_insert_with(|| super::router::GammaClassStat {
                    class: c.class.clone(),
                    ..Default::default()
                });
            let total = e.drafted + c.drafted;
            if total > 0 {
                e.accept_ewma = (e.accept_ewma * e.drafted as f64
                    + c.accept_ewma * c.drafted as f64)
                    / total as f64;
            }
            e.steps += c.steps;
            e.drafted = total;
            e.accepted += c.accepted;
        }
    }

    let hits = sum_u64(&|s| s.prefix.hits);
    let misses = sum_u64(&|s| s.prefix.misses);
    let pages = sum_u64(&|s| s.prefix.resident_pages);
    let audits = sum_u64(&|s| s.governor.audits);

    // Merge the raw latency histograms bucket-wise; fleet percentiles read
    // off the combined distribution below.
    let mut hists: BTreeMap<String, Histogram> = BTreeMap::new();
    for s in snaps {
        for (name, h) in &s.hists {
            match hists.get_mut(name) {
                Some(acc) => acc.merge(h),
                None => {
                    hists.insert(name.clone(), h.clone());
                }
            }
        }
    }
    // Percentile of the merged distribution when we have it; the old
    // fleet-worst fold only as fallback. For a single snapshot the scalar
    // passes through untouched (bit-for-bit identity).
    let pct = |name: &str, q: f64, fold: f64| {
        if snaps.len() == 1 {
            return fold;
        }
        match hists.get(name) {
            Some(h) if h.count() > 0 => h.quantile(q),
            _ => fold,
        }
    };

    StatsSnapshot {
        // A fleet view belongs to no single replica; keep the sole
        // replica's identity when there is exactly one (the N=1 identity).
        replica: if snaps.len() == 1 { snaps[0].replica } else { 0 },
        in_flight: sum_usize(&|s| s.in_flight),
        queue_depth: sum_usize(&|s| s.queue_depth),
        active_rows: sum_usize(&|s| s.active_rows),
        // Fleet capacity: rows across all replicas.
        batch: sum_usize(&|s| s.batch),
        steps: sum_u64(&|s| s.steps),
        batch_occupancy: wmean(&|s| s.batch_occupancy, &|s| s.steps as f64),
        sched_delay_s: wmean(&|s| s.sched_delay_s, &|s| s.completed as f64),
        chunk_efficiency: wmean(&|s| s.chunk_efficiency, &|s| s.steps as f64),
        subbatches_per_step: wmean(&|s| s.subbatches_per_step, &|s| s.steps as f64),
        completed: sum_u64(&|s| s.completed),
        cancelled: sum_u64(&|s| s.cancelled),
        buckets: buckets.into_values().collect(),
        variants: variants
            .into_iter()
            .map(|(variant, calls)| VariantCalls { variant, calls })
            .collect(),
        governor: super::router::GovernorSnapshot {
            audits,
            probes: sum_u64(&|s| s.governor.probes),
            audit_rate: wmean(&|s| s.governor.audit_rate, &|s| s.governor.audits as f64),
            top1_agreement: wmean(
                &|s| s.governor.top1_agreement,
                &|s| s.governor.audits as f64,
            ),
            accept_delta: wmean(
                &|s| s.governor.accept_delta,
                &|s| s.governor.audits as f64,
            ),
            demotions: sum_u64(&|s| s.governor.demotions),
            promotions: sum_u64(&|s| s.governor.promotions),
        },
        gamma: gamma.into_values().collect(),
        prefix: super::router::PrefixSnapshot {
            hits,
            misses,
            hit_rate: if hits + misses == 0 {
                0.0
            } else {
                hits as f64 / (hits + misses) as f64
            },
            hit_tokens: sum_u64(&|s| s.prefix.hit_tokens),
            mid_stream_hit_tokens: sum_u64(&|s| s.prefix.mid_stream_hit_tokens),
            resident_bytes: sum_u64(&|s| s.prefix.resident_bytes),
            resident_pages: pages,
            page_share_ratio: if pages == 0 {
                0.0
            } else {
                // refs_i = ratio_i * pages_i, so this is sum(refs)/sum(pages).
                snaps
                    .iter()
                    .map(|s| s.prefix.page_share_ratio * s.prefix.resident_pages as f64)
                    .sum::<f64>()
                    / pages as f64
            },
            segments: sum_u64(&|s| s.prefix.segments),
            evictions: sum_u64(&|s| s.prefix.evictions),
            prefill_saved_s: sum_f64(&|s| s.prefix.prefill_saved_s),
        },
        kv: super::router::KvSnapshot {
            paged_rows: snaps[0].kv.paged_rows,
            resident_bytes: sum_u64(&|s| s.kv.resident_bytes),
            // Sum of per-replica peaks: an upper bound on the true
            // concurrent fleet peak (replica peaks need not coincide).
            resident_peak_bytes: sum_u64(&|s| s.kv.resident_peak_bytes),
            row_page_refs: sum_u64(&|s| s.kv.row_page_refs),
            row_shared_pages: sum_u64(&|s| s.kv.row_shared_pages),
            row_copied_pages: sum_u64(&|s| s.kv.row_copied_pages),
            row_tail_copies: sum_u64(&|s| s.kv.row_tail_copies),
            copy_saved_s: sum_f64(&|s| s.kv.copy_saved_s),
        },
        prefill: super::router::PrefillSnapshot {
            chunks: sum_u64(&|s| s.prefill.chunks),
            inflight_rows: sum_u64(&|s| s.prefill.inflight_rows),
            decode_stall_steps: sum_u64(&|s| s.prefill.decode_stall_steps),
            stall_saved_s: sum_f64(&|s| s.prefill.stall_saved_s),
            ttft_warm_p50_s: pct(
                crate::metrics::names::TTFT_WARM_S,
                0.50,
                max_f64(&|s| s.prefill.ttft_warm_p50_s),
            ),
            ttft_warm_p99_s: pct(
                crate::metrics::names::TTFT_WARM_S,
                0.99,
                max_f64(&|s| s.prefill.ttft_warm_p99_s),
            ),
            ttft_cold_p50_s: pct(
                crate::metrics::names::TTFT_COLD_S,
                0.50,
                max_f64(&|s| s.prefill.ttft_cold_p50_s),
            ),
            ttft_cold_p99_s: pct(
                crate::metrics::names::TTFT_COLD_S,
                0.99,
                max_f64(&|s| s.prefill.ttft_cold_p99_s),
            ),
            tpot_warm_p50_s: pct(
                crate::metrics::names::TPOT_WARM_S,
                0.50,
                max_f64(&|s| s.prefill.tpot_warm_p50_s),
            ),
            tpot_warm_p99_s: pct(
                crate::metrics::names::TPOT_WARM_S,
                0.99,
                max_f64(&|s| s.prefill.tpot_warm_p99_s),
            ),
            tpot_cold_p50_s: pct(
                crate::metrics::names::TPOT_COLD_S,
                0.50,
                max_f64(&|s| s.prefill.tpot_cold_p50_s),
            ),
            tpot_cold_p99_s: pct(
                crate::metrics::names::TPOT_COLD_S,
                0.99,
                max_f64(&|s| s.prefill.tpot_cold_p99_s),
            ),
        },
        prompt_truncated: sum_u64(&|s| s.prompt_truncated),
        hists,
        // Fleet uptime = the longest-lived replica's.
        uptime_s: max_f64(&|s| s.uptime_s),
        config: snaps[0].config.clone(),
    }
}

/// Handle to a replica fleet. `Sync` like the [`EngineHandle`] it
/// generalizes: share one behind an `Arc` and submit from any number of
/// threads.
pub struct ClusterHandle {
    replicas: Vec<EngineHandle>,
    ring: Vec<(u64, usize)>,
    dispatch: DispatchPolicy,
    steal_threshold: usize,
    /// Prefix-family index for locality dispatch; the lock guards a few
    /// hash probes per submit, never any engine work.
    locality: Mutex<LocalityIndex>,
    /// Round-robin cursor for the `Random` scatter policy.
    rr: AtomicUsize,
    steals: AtomicU64,
    locality_hits: AtomicU64,
    locality_misses: AtomicU64,
    dispatched: Vec<AtomicU64>,
    /// Dispatch-plane view of the fleet-shared flight recorder (disarmed
    /// unless `EngineConfig::trace`); records the `Dispatched` span event
    /// with the routing decision's own timestamp.
    trace: TraceHandle,
}

impl ClusterHandle {
    /// Spawn `ccfg.replicas` engine replicas of `cfg`. Each replica gets
    /// its own engine thread (construction serialized by the router's boot
    /// lock) with `cfg.replica`/`cfg.replicas` stamped for id striding;
    /// `max_queue` is the per-replica admission cap.
    pub fn spawn(
        artifacts: PathBuf,
        model: String,
        cfg: EngineConfig,
        ccfg: ClusterConfig,
        max_queue: usize,
    ) -> Result<Self> {
        if ccfg.replicas == 0 {
            bail!("cluster needs at least one replica");
        }
        let n = ccfg.replicas;
        // One recorder for the whole fleet: every replica's span events
        // land in the same trace, on the same monotonic timebase, so the
        // Perfetto export shows cross-replica steals on one timeline.
        let recorder = Arc::new(FlightRecorder::new(cfg.trace));
        let mut replicas = Vec::with_capacity(n);
        for r in 0..n {
            let mut rcfg = cfg.clone();
            rcfg.replica = r;
            rcfg.replicas = n;
            replicas.push(EngineHandle::spawn_with_recorder(
                artifacts.clone(),
                model.clone(),
                rcfg,
                max_queue,
                Arc::clone(&recorder),
            )?);
        }
        let page_tokens = cfg.prefix.page_tokens.max(1);
        Ok(ClusterHandle {
            replicas,
            ring: build_ring(n, ccfg.vnodes.max(1)),
            dispatch: ccfg.dispatch,
            steal_threshold: ccfg.steal_threshold.max(1),
            locality: Mutex::new(LocalityIndex::new(page_tokens)),
            rr: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            locality_hits: AtomicU64::new(0),
            locality_misses: AtomicU64::new(0),
            dispatched: (0..n).map(|_| AtomicU64::new(0)).collect(),
            trace: TraceHandle::new(recorder, 0),
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Pick the replica a new prompt dispatches to, updating the locality
    /// index and the steal/hit counters. Returns `(target, stolen)`.
    fn route(&self, prompt: &[i32]) -> (usize, bool) {
        let n = self.replicas.len();
        match self.dispatch {
            DispatchPolicy::Random => {
                (self.rr.fetch_add(1, Ordering::Relaxed) % n, false)
            }
            DispatchPolicy::Locality => {
                let (family, hit) = self.locality.lock().unwrap().observe(prompt);
                if hit {
                    self.locality_hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.locality_misses.fetch_add(1, Ordering::Relaxed);
                }
                if n == 1 {
                    return (0, false);
                }
                let home = ring_assign(&self.ring, family);
                let depths: Vec<usize> =
                    self.replicas.iter().map(|r| r.in_flight()).collect();
                let (target, stolen) =
                    dispatch_decision(home, &depths, self.steal_threshold);
                if stolen {
                    self.steals.fetch_add(1, Ordering::Relaxed);
                }
                (target, stolen)
            }
        }
    }

    /// Submit to the dispatched replica; the returned [`Ticket`] is the
    /// request's private completion channel exactly as with a bare handle.
    pub fn submit(&self, prompt: Vec<i32>, params: GenParams, task: &str) -> Result<Ticket> {
        self.submit_dispatch(prompt, params, task).map(|(t, _)| t)
    }

    /// [`ClusterHandle::submit`], plus where the request landed. The
    /// `Dispatched` span event carries the routing decision's timestamp
    /// (stamped before the ticket id exists) so the trace shows dispatch
    /// preceding the engine's own `Enqueued`.
    pub fn submit_dispatch(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
        task: &str,
    ) -> Result<(Ticket, DispatchInfo)> {
        let stamp = self.trace.stamp();
        let (target, stolen) = self.route(&prompt);
        self.dispatched[target].fetch_add(1, Ordering::Relaxed);
        let ticket = self.replicas[target].submit(prompt, params, task)?;
        if let Some(ts) = stamp {
            self.trace.record_at(
                ts,
                ticket.id,
                EventKind::Dispatched { replica: target as u32, stolen },
            );
        }
        Ok((ticket, DispatchInfo { replica: target, stolen }))
    }

    /// The fleet-shared flight recorder (disarmed unless
    /// `EngineConfig::trace`).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        self.replicas[0].recorder()
    }

    /// Drain the fleet-shared flight recorder into Chrome trace-event JSON
    /// (one track per replica, one async lane per request).
    pub fn trace_json(&self) -> Json {
        self.replicas[0].trace_json()
    }

    /// Fleet-merged metrics registry dump: every replica scraped, then
    /// counters summed and histograms merged bucket-wise.
    pub fn metrics_dump(&self) -> Result<MetricsDump> {
        let mut dump = MetricsDump::default();
        for r in &self.replicas {
            dump.merge(&r.metrics_dump()?);
        }
        Ok(dump)
    }

    /// Cancel routes straight to the replica that minted the id (the
    /// id-stride rule) — no broadcast, no shared allocator.
    pub fn cancel(&self, id: u64) -> Result<()> {
        let r = replica_of_id(id, self.replicas.len());
        self.replicas[r].cancel(id)
    }

    /// Boot warm-up, fleet-aware: every template is keyed into the
    /// locality index and prefilled on its *home* replica only — warming
    /// all replicas with all templates would waste N−1 copies of every
    /// page run, and dispatch sends the template's requests home anyway.
    /// Under the `Random` scatter policy templates round-robin instead
    /// (there is no home). Returns the total templates cached.
    pub fn warm_prefix(&self, templates: Vec<(Vec<i32>, String)>) -> Result<usize> {
        let n = self.replicas.len();
        let mut per: Vec<Vec<(Vec<i32>, String)>> = (0..n).map(|_| Vec::new()).collect();
        for (ids, task) in templates {
            let home = match self.dispatch {
                DispatchPolicy::Locality => {
                    let (family, _) = self.locality.lock().unwrap().observe(&ids);
                    if n == 1 {
                        0
                    } else {
                        ring_assign(&self.ring, family)
                    }
                }
                DispatchPolicy::Random => self.rr.fetch_add(1, Ordering::Relaxed) % n,
            };
            per[home].push((ids, task));
        }
        let mut cached = 0;
        for (r, batch) in per.into_iter().enumerate() {
            if !batch.is_empty() {
                cached += self.replicas[r].warm_prefix(batch)?;
            }
        }
        Ok(cached)
    }

    /// Fleet-wide submitted-but-not-completed count.
    pub fn in_flight(&self) -> usize {
        self.replicas.iter().map(|r| r.in_flight()).sum()
    }

    /// Fleet-aggregated stats, same shape as a bare engine's (see
    /// [`aggregate`]). For the per-replica breakdown use
    /// [`ClusterHandle::cluster_stats`].
    pub fn stats(&self) -> StatsSnapshot {
        let snaps: Vec<StatsSnapshot> = self.replicas.iter().map(|r| r.stats()).collect();
        let mut fleet = aggregate(&snaps);
        fleet.config.dispatch = self.dispatch.name().to_string();
        fleet
    }

    /// Everything: fleet aggregate, per-replica snapshots, dispatch
    /// counters.
    pub fn cluster_stats(&self) -> ClusterSnapshot {
        let mut replicas: Vec<StatsSnapshot> =
            self.replicas.iter().map(|r| r.stats()).collect();
        let mut fleet = aggregate(&replicas);
        // The router layer doesn't know the dispatch policy; stamp it here
        // so the config echo is complete at every level of the breakdown.
        fleet.config.dispatch = self.dispatch.name().to_string();
        for r in &mut replicas {
            r.config.dispatch = self.dispatch.name().to_string();
        }
        let hits = self.locality_hits.load(Ordering::Relaxed);
        let misses = self.locality_misses.load(Ordering::Relaxed);
        ClusterSnapshot {
            fleet,
            replicas,
            dispatch: DispatchSnapshot {
                policy: self.dispatch.name().into(),
                steal_threshold: self.steal_threshold,
                steals: self.steals.load(Ordering::Relaxed),
                locality_hits: hits,
                locality_misses: misses,
                locality_hit_rate: if hits + misses == 0 {
                    0.0
                } else {
                    hits as f64 / (hits + misses) as f64
                },
                dispatched: self
                    .dispatched
                    .iter()
                    .map(|d| d.load(Ordering::Relaxed))
                    .collect(),
            },
        }
    }

    /// Graceful shutdown: drain every replica, then join them all. The
    /// first error is reported after every replica has been joined.
    pub fn shutdown(self) -> Result<()> {
        let mut first_err = None;
        for r in self.replicas {
            if let Err(e) = r.shutdown() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_is_shareable_across_threads() {
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<ClusterHandle>();
        assert_sync_send::<ClusterSnapshot>();
    }

    #[test]
    fn ring_covers_every_replica_and_is_deterministic() {
        let ring = build_ring(4, 64);
        assert_eq!(ring.len(), 4 * 64);
        assert_eq!(ring, build_ring(4, 64), "ring is a pure function");
        // Sweep the key space: every replica owns a share.
        let mut owned = [0usize; 4];
        for k in 0..4096u64 {
            owned[ring_assign(&ring, k.wrapping_mul(0x9e37_79b9_7f4a_7c15))] += 1;
        }
        for (r, &n) in owned.iter().enumerate() {
            assert!(n > 0, "replica {r} owns no keys");
        }
    }

    #[test]
    fn steal_rule_bounds() {
        // Below threshold: never steals, whatever the imbalance.
        assert_eq!(dispatch_decision(0, &[3, 0, 0, 0], 4), (0, false));
        // At threshold with a shallower replica: steals to the shallowest.
        assert_eq!(dispatch_decision(0, &[4, 2, 1, 5], 4), (2, true));
        // At threshold but nobody shallower: stays home.
        assert_eq!(dispatch_decision(1, &[4, 4, 4, 4], 4), (1, false));
        // Home is itself the shallowest: stays home, not a steal.
        assert_eq!(dispatch_decision(2, &[9, 9, 4, 9], 4), (2, false));
        // Single replica can never steal.
        assert_eq!(dispatch_decision(0, &[100], 1), (0, false));
    }

    #[test]
    fn id_stride_roundtrip() {
        for n in 1..=5usize {
            for r in 0..n {
                for k in 0..4u64 {
                    let id = (r as u64 + 1) + k * n as u64;
                    assert_eq!(replica_of_id(id, n), r, "id {id} of {n}");
                }
            }
        }
        // Defensive: id 0 (never minted) and n = 0 don't panic.
        assert_eq!(replica_of_id(0, 4), 0);
        assert_eq!(replica_of_id(7, 0), 0);
    }

    #[test]
    fn aggregate_of_one_snapshot_is_identity() {
        // The N=1 cluster must answer `stats` exactly like a bare engine:
        // build a snapshot with every weight-bearing field non-zero and
        // check the aggregate reproduces it bit for bit.
        let s = StatsSnapshot {
            replica: 3,
            in_flight: 5,
            queue_depth: 2,
            active_rows: 3,
            batch: 4,
            steps: 100,
            batch_occupancy: 2.75,
            sched_delay_s: 0.0125,
            chunk_efficiency: 0.8,
            subbatches_per_step: 1.5,
            completed: 42,
            cancelled: 2,
            buckets: vec![BucketStat { bucket: 4, calls: 10, mean_rows: 3.5 }],
            variants: vec![VariantCalls { variant: "w8a8".into(), calls: 10 }],
            governor: super::super::router::GovernorSnapshot {
                audits: 8,
                probes: 2,
                audit_rate: 0.25,
                top1_agreement: 0.99,
                accept_delta: -0.125,
                demotions: 1,
                promotions: 1,
            },
            gamma: vec![super::super::router::GammaClassStat {
                class: "chat".into(),
                accept_ewma: 3.25,
                steps: 20,
                drafted: 80,
                accepted: 65,
            }],
            prefix: super::super::router::PrefixSnapshot {
                hits: 30,
                misses: 10,
                hit_rate: 0.75,
                hit_tokens: 960,
                mid_stream_hit_tokens: 128,
                resident_bytes: 1 << 20,
                resident_pages: 64,
                page_share_ratio: 1.25,
                segments: 7,
                evictions: 3,
                prefill_saved_s: 0.5,
            },
            kv: super::super::router::KvSnapshot {
                paged_rows: true,
                resident_bytes: 2 << 20,
                resident_peak_bytes: 3 << 20,
                row_page_refs: 11,
                row_shared_pages: 9,
                row_copied_pages: 1,
                row_tail_copies: 2,
                copy_saved_s: 0.25,
            },
            prefill: super::super::router::PrefillSnapshot {
                chunks: 17,
                inflight_rows: 1,
                decode_stall_steps: 4,
                stall_saved_s: 0.0625,
                ttft_warm_p50_s: 0.01,
                ttft_warm_p99_s: 0.02,
                ttft_cold_p50_s: 0.03,
                ttft_cold_p99_s: 0.04,
                tpot_warm_p50_s: 0.001,
                tpot_warm_p99_s: 0.002,
                tpot_cold_p50_s: 0.003,
                tpot_cold_p99_s: 0.004,
            },
            prompt_truncated: 1,
            hists: {
                let mut m = BTreeMap::new();
                let mut h = Histogram::new();
                h.record(0.01);
                h.record(0.02);
                m.insert(crate::metrics::names::TTFT_COLD_S.to_string(), h);
                m
            },
            uptime_s: 33.5,
            config: super::super::router::ConfigEcho {
                method: "w8a8".into(),
                batch: 4,
                replicas: 1,
                dispatch: "none".into(),
                paged_rows: true,
                chunked_prefill: true,
                adaptive_gamma: true,
                trace: false,
            },
        };
        let a = aggregate(std::slice::from_ref(&s));
        assert_eq!(a.replica, s.replica);
        assert_eq!(a.in_flight, s.in_flight);
        assert_eq!(a.queue_depth, s.queue_depth);
        assert_eq!(a.active_rows, s.active_rows);
        assert_eq!(a.batch, s.batch);
        assert_eq!(a.steps, s.steps);
        assert_eq!(a.batch_occupancy, s.batch_occupancy);
        assert_eq!(a.sched_delay_s, s.sched_delay_s);
        assert_eq!(a.chunk_efficiency, s.chunk_efficiency);
        assert_eq!(a.subbatches_per_step, s.subbatches_per_step);
        assert_eq!(a.completed, s.completed);
        assert_eq!(a.cancelled, s.cancelled);
        assert_eq!(a.buckets, s.buckets);
        assert_eq!(a.variants, s.variants);
        assert_eq!(a.governor, s.governor);
        assert_eq!(a.gamma, s.gamma);
        assert_eq!(a.prefix, s.prefix);
        assert_eq!(a.kv, s.kv);
        assert_eq!(a.prefill, s.prefill);
        assert_eq!(a.prompt_truncated, s.prompt_truncated);
        assert_eq!(a.hists, s.hists);
        assert_eq!(a.uptime_s, s.uptime_s);
        assert_eq!(a.config, s.config);
    }

    #[test]
    fn merged_histogram_p99_is_bucket_accurate() {
        // Bimodal fleet: replica A served 9 900 requests at ~1 ms TTFT,
        // replica B served 100 at ~100 ms. The combined distribution's p99
        // sits in the fast mode (9 900 of 10 000 samples < the 99th cut),
        // so neither a max-fold over replica p99s (0.1 s) nor any weighted
        // mean of them is right — only the merged histogram gets it.
        let name = crate::metrics::names::TTFT_COLD_S;
        let mut fast = Histogram::new();
        for _ in 0..9_900 {
            fast.record(0.001);
        }
        let mut slow = Histogram::new();
        for _ in 0..100 {
            slow.record(0.1);
        }
        let mut a = StatsSnapshot::default();
        a.prefill.ttft_cold_p99_s = fast.p99();
        a.hists.insert(name.to_string(), fast.clone());
        let mut b = StatsSnapshot::default();
        b.replica = 1;
        b.prefill.ttft_cold_p99_s = slow.p99();
        b.hists.insert(name.to_string(), slow.clone());

        let f = aggregate(&[a, b]);
        let merged_p99 = f.prefill.ttft_cold_p99_s;
        let max_fold = fast.p99().max(slow.p99());
        let weighted_mean = (fast.p99() * 9_900.0 + slow.p99() * 100.0) / 10_000.0;
        assert!(
            merged_p99 < 0.01,
            "fleet p99 {merged_p99} must sit in the fast mode"
        );
        assert!(
            merged_p99 < max_fold / 5.0,
            "merged p99 {merged_p99} vs max-fold {max_fold}"
        );
        assert!(
            (merged_p99 - weighted_mean).abs() > 1e-4,
            "merged p99 {merged_p99} must differ from weighted mean {weighted_mean}"
        );
        // And the merged histogram itself is carried for the next tier up.
        let h = f.hists.get(name).expect("merged histogram present");
        assert_eq!(h.count(), 10_000);
        assert!((h.sum() - (9_900.0 * 0.001 + 100.0 * 0.1)).abs() < 1e-6);
    }

    #[test]
    fn aggregate_recombines_weighted_rates() {
        let mut a = StatsSnapshot::default();
        a.steps = 100;
        a.batch = 4;
        a.batch_occupancy = 3.0;
        a.completed = 10;
        a.sched_delay_s = 0.010;
        a.prefix.hits = 9;
        a.prefix.misses = 1;
        a.prefix.hit_rate = 0.9;
        let mut b = StatsSnapshot::default();
        b.replica = 1;
        b.steps = 300;
        b.batch = 4;
        b.batch_occupancy = 1.0;
        b.completed = 30;
        b.sched_delay_s = 0.030;
        b.prefix.hits = 1;
        b.prefix.misses = 9;
        b.prefix.hit_rate = 0.1;
        let f = aggregate(&[a, b]);
        assert_eq!(f.replica, 0, "fleet view is anonymous");
        assert_eq!(f.batch, 8, "fleet capacity sums");
        assert_eq!(f.steps, 400);
        // (3.0*100 + 1.0*300) / 400
        assert!((f.batch_occupancy - 1.5).abs() < 1e-12);
        // (0.010*10 + 0.030*30) / 40
        assert!((f.sched_delay_s - 0.025).abs() < 1e-12);
        // Recomputed from summed hits/misses, not averaged rates.
        assert!((f.prefix.hit_rate - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cluster_snapshot_json_keeps_flat_fleet_keys() {
        let snap = ClusterSnapshot {
            fleet: StatsSnapshot { queue_depth: 7, ..Default::default() },
            replicas: vec![
                StatsSnapshot { replica: 0, ..Default::default() },
                StatsSnapshot { replica: 1, ..Default::default() },
            ],
            dispatch: DispatchSnapshot {
                policy: "locality".into(),
                steal_threshold: 8,
                steals: 3,
                locality_hits: 5,
                locality_misses: 5,
                locality_hit_rate: 0.5,
                dispatched: vec![6, 4],
            },
        };
        let j = snap.to_json();
        // Existing consumers keep their flat keys…
        assert_eq!(j.get("queue_depth").unwrap().as_i64().unwrap(), 7);
        // …and the fleet detail rides alongside.
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[1].get("replica").unwrap().as_i64().unwrap(), 1);
        let d = j.get("dispatch").unwrap();
        assert_eq!(d.get("policy").unwrap().as_str().unwrap(), "locality");
        assert_eq!(d.get("steals").unwrap().as_i64().unwrap(), 3);
        assert!(
            (d.get("locality_hit_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12
        );
        assert_eq!(d.get("dispatched").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn dispatch_policy_parse_roundtrip() {
        for p in [DispatchPolicy::Locality, DispatchPolicy::Random] {
            assert_eq!(DispatchPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DispatchPolicy::parse("nope"), None);
    }
}

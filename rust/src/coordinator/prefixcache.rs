//! Shared-prefix KV reuse: a radix-trie index over committed token
//! sequences mapping to **page-runs** over a refcounted fixed-size page
//! pool, with a byte-budget LRU evictor.
//!
//! At serving scale the paper's five task families are heavily templated —
//! requests share long system-prompt prefixes — yet every admission paid a
//! full prefill chunk over the whole prompt. This module lets the engine
//! run admission as *longest-prefix-match, then suffix-only prefill*.
//!
//! ## The paged store (vs. the PR-4 whole-row segment store)
//!
//! The first cut of this cache snapshotted each committed prefix as a whole
//! `[L, 1, H, S, hd]` single-row KV copy: a 40-token template pinned a full
//! `max_seq` row, and two keys sharing that template each held their own
//! copy of it. The store is now paged, the shape vLLM-style paged attention
//! and SGLang-style radix reuse converge on:
//!
//! * **Pages**: the pool's unit is a `[L, 1, H, page_tokens, hd]` KV pair
//!   holding `page_tokens` consecutive sequence positions of one cached
//!   prefix. A prefix of `len` tokens resides in `ceil(len/page_tokens)`
//!   pages — never a `max_seq` row. Pages are refcounted by the runs that
//!   reference them and freed only at refcount zero.
//! * **Page-runs**: a radix-trie value is a *run* — an ordered page list
//!   whose page `i` covers token positions `[i*P, min((i+1)*P, len))`. One
//!   physical page backs every run (and every concurrent admission) that
//!   shares its tokens: inserting `template ++ body_b` after
//!   `template ++ body_a` references the template's full pages and copies
//!   only the divergent tail.
//! * **Tail-page copy-on-write**: a page only partially covered by the run
//!   that owns it is never mutated while shared. Extending a run whose tail
//!   page is exclusively referenced appends in place (positions past the
//!   old coverage); otherwise the extension copies into a fresh page. Either
//!   way, bytes a lease might read are immutable for the page's lifetime.
//! * **Leases**: [`PrefixCache::lookup`] returns a [`Lease`] that pins the
//!   matched run (and therefore every page it references) until
//!   [`PrefixCache::release`]; the evictor never frees a leased run's
//!   pages, so a splice in flight can never read freed memory no matter
//!   what inserts happen in between.
//! * **Eviction**: inserts that push resident page bytes over
//!   `budget_bytes` evict unleased runs in least-recently-used order,
//!   freeing only the pages that drop to refcount zero — a shared template
//!   page survives its youngest run. When every resident run is leased the
//!   cache temporarily exceeds its budget rather than corrupt a lease.
//! * **Mid-stream runs**: the engine extends a finished request's cached
//!   run with full pages of its *generated* continuation
//!   ([`PrefixCache::insert_from_row`] with the prompt boundary as
//!   `mid_from`), so a multi-turn resubmit (`prompt ++ answer ++
//!   follow-up`) hits past the original prompt. Match tokens served past
//!   that boundary — and only those — are tallied in
//!   [`PrefixCacheStats::mid_stream_hit_tokens`].
//!
//! Keys stay isolated per verifier weight variant: a `w8a8`-prefilled
//! prefix is not bit-exact KV for a class the fidelity governor demoted to
//! `fp32`, so cross-variant reuse would silently break the engine's
//! bit-identity guarantees (deliberately out of scope — see ROADMAP).
//!
//! Correctness note (why page sharing and suffix-only prefill are
//! bit-exact): attention is causal, so the KV a prefill writes for
//! positions `0..h` depends only on tokens `0..h`. Two keys sharing their
//! first `h` tokens therefore share those positions' KV *bytes*, which is
//! exactly what lets one page back many runs; and a cached run whose key
//! equals the request's first `h` prompt tokens holds exactly the KV the
//! request's own prefill would have computed at the same variant, so
//! running the chunk at write offset `pos = h` over the remaining tokens
//! reproduces the cold path bit for bit.
//!
//! Mid-stream runs lean on one additional assumption: the KV the
//! *decode/verify* programs write for a position is byte-identical to what
//! the *prefill* program would write for the same tokens (mathematically
//! equal by causality; bitwise equality additionally requires the AOT
//! artifacts not to fuse the KV projections differently). The paged
//! integration scenario and the CI warm-vs-cold A/B assert this on the
//! real artifacts — if a future artifact set breaks it, those gates go
//! red and `PrefixCacheConfig::mid_stream` is the switch to pull.

use std::collections::{BTreeMap, HashMap, VecDeque};

use anyhow::{anyhow, bail, Result};

use crate::runtime::Tensor;

/// Tuning knobs for the prefix cache. `Default` is *enabled* with a 256 MiB
/// budget — reuse is lossless by construction, so it is on unless a bench
/// explicitly wants cold admissions ([`PrefixCacheConfig::off`]).
#[derive(Debug, Clone)]
pub struct PrefixCacheConfig {
    /// Master switch. Disabled: no lookups, no snapshots, zero overhead.
    pub enabled: bool,
    /// Resident-page byte budget the LRU evictor enforces (pages of leased
    /// runs are exempt while leased).
    pub budget_bytes: usize,
    /// Shortest prefix worth caching or matching: a tiny shared prefix
    /// saves less prefill than the page splice costs.
    pub min_prefix: usize,
    /// Sequence positions per pool page. Smaller pages share finer-grained
    /// prefixes and waste less tail; larger pages amortize bookkeeping.
    pub page_tokens: usize,
    /// Snapshot full pages of a finished request's *generated* continuation
    /// back into its cached run, so multi-turn resubmits hit past the
    /// prompt. Lossless (same causality argument as prompt reuse), so on by
    /// default.
    pub mid_stream: bool,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig {
            enabled: true,
            budget_bytes: 256 << 20,
            min_prefix: 4,
            page_tokens: 16,
            mid_stream: true,
        }
    }
}

impl PrefixCacheConfig {
    /// Disabled (cold-admission A/B baseline).
    pub fn off() -> Self {
        PrefixCacheConfig { enabled: false, ..Default::default() }
    }
}

/// A pinned reference to one cached page-run. Obtained from
/// [`PrefixCache::lookup`]; none of the run's pages can be freed until the
/// lease is handed back via [`PrefixCache::release`]. Not `Clone` — one
/// lookup, one release.
#[derive(Debug)]
pub struct Lease {
    id: u64,
    len: usize,
}

impl Lease {
    /// Run id (stable for the run's lifetime; test hook).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Matched prefix length in tokens — the positions admission may skip.
    /// May end mid-page; [`PrefixCache::splice`] copies exactly this many
    /// tokens, never a trailing page's uncovered remainder.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Point-in-time counters (monotonic except the `resident_*` / `segments`
/// / `leases` / `page_refs` levels).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Prompt tokens served from cache instead of prefill.
    pub hit_tokens: u64,
    /// Subset of `hit_tokens` served by runs that were extended with
    /// generated continuations (mid-stream snapshots).
    pub mid_stream_hit_tokens: u64,
    pub inserts: u64,
    /// Inserts refused because a single run's pages exceed the whole budget.
    pub rejected: u64,
    /// Runs evicted by the byte-budget LRU.
    pub evictions: u64,
    /// Pool pages filled by copying KV in (fresh allocations and
    /// copy-on-write tails). The zero-copy sharing assertion counter: an
    /// insert that only references existing pages does not move it.
    pub copied_pages: u64,
    /// Run→page references added *without* a copy (prefix sharing).
    pub shared_pages: u64,
    pub resident_bytes: usize,
    /// Pages resident in the pool.
    pub resident_pages: usize,
    /// Cached page-runs (radix-trie values; the old segment count).
    pub segments: usize,
    /// Leases currently outstanding (refcounts not yet released).
    pub leases: usize,
    /// Total live run→page references. `page_refs / resident_pages` is the
    /// share ratio: 1.0 = no sharing, higher = one physical page backing
    /// several cached prefixes.
    pub page_refs: usize,
    /// Full pool pages live batch rows reference instead of copying
    /// (page-table admissions riding cached runs). Monotonic.
    pub row_shared_pages: u64,
    /// Full pool pages copied into private row pages because no cached run
    /// covered them (cold admissions). Monotonic — the warm-admission
    /// zero-copy assertion counter.
    pub row_copied_pages: u64,
    /// Partial tail pages copied for rows (expected even when fully
    /// cached: the growth frontier must be private). Monotonic.
    pub row_tail_copies: u64,
    /// Live row→page references (the page-table working set).
    pub row_page_refs: usize,
}

impl PrefixCacheStats {
    /// hits / (hits + misses); 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            return 0.0;
        }
        self.hits as f64 / n as f64
    }

    /// Run→page references per resident page (0 when the pool is empty).
    pub fn page_share_ratio(&self) -> f64 {
        if self.resident_pages == 0 {
            return 0.0;
        }
        self.page_refs as f64 / self.resident_pages as f64
    }
}

/// One pool page: `page_tokens` sequence positions of KV for one cached
/// prefix, shared by every run whose key covers its token range.
struct Page {
    k: Tensor<f32>,
    v: Tensor<f32>,
    /// Runs referencing this page. Freed at zero.
    refs: u32,
    bytes: usize,
}

/// One cached prefix: a trie key resolved to an ordered page list. Page `i`
/// covers token positions `[i*P, min((i+1)*P, key.len()))` — runs tile
/// their key without overlap by construction.
struct Run {
    variant: String,
    /// Token key (the committed prefix); kept so eviction can unlink the
    /// trie node. Tiny next to the KV bytes it indexes.
    key: Vec<i32>,
    pages: Vec<u64>,
    /// Outstanding lookups pinning this run (and its pages).
    leases: u32,
    last_use: u64,
    /// Key positions `mid_from..` hold *generated-continuation* KV
    /// (mid-stream snapshot); positions below are prompt content. Equals
    /// `key.len()` for plain prompt runs, so only tokens a match serves
    /// past this boundary count toward `mid_stream_hit_tokens`.
    mid_from: usize,
}

/// Longest common prefix length of two token slices.
fn lcp(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Compressed radix-trie node: each edge carries a non-empty token label;
/// a node's `seg` is the run cached for the exact prefix spelled by the
/// path from the root.
#[derive(Default)]
struct Node {
    seg: Option<u64>,
    edges: Vec<(Vec<i32>, Node)>,
}

impl Node {
    /// Deepest usable match of `tokens` against the cached keys:
    /// `(run id, match length)`. The walk may stop *inside* an edge or
    /// at a key-less interior node — every key in the subtree below the
    /// stop point extends `tokens[..match]`, and by causality the first
    /// `match` KV positions of any such run are exactly the KV for
    /// `tokens[..match]`. So the cache serves partial matches *into*
    /// longer cached prefixes (template + body A serving template + body
    /// B), not just whole cached keys.
    fn longest(&self, tokens: &[i32]) -> Option<(u64, usize)> {
        let mut node = self;
        let mut depth = 0usize;
        let mut rest = tokens;
        loop {
            let edge = node
                .edges
                .iter()
                .find(|(l, _)| !rest.is_empty() && l.first() == rest.first());
            let Some((label, child)) = edge else {
                // The query ends or diverges at this node: the common
                // prefix is exactly `depth`, shared by every key under it.
                return node.any_seg().map(|id| (id, depth));
            };
            let c = lcp(label, rest);
            if c < label.len() {
                // Stopped mid-edge: every key under `child` starts with
                // `tokens[..depth + c]`.
                return child.any_seg().map(|id| (id, depth + c));
            }
            depth += c;
            rest = &rest[c..];
            node = child;
        }
    }

    /// Any run id in this subtree (pre-order). Trie invariant: every leaf
    /// holds a run, so this is `None` only on an empty root.
    fn any_seg(&self) -> Option<u64> {
        if let Some(id) = self.seg {
            return Some(id);
        }
        self.edges.iter().find_map(|(_, c)| c.any_seg())
    }

    /// Insert `id` at `tokens`, splitting an edge if the key diverges
    /// mid-label. Returns a previously-stored id at exactly this key.
    fn insert(&mut self, tokens: &[i32], id: u64) -> Option<u64> {
        if tokens.is_empty() {
            return self.seg.replace(id);
        }
        for (label, child) in &mut self.edges {
            let c = lcp(label, tokens);
            if c == 0 {
                continue;
            }
            if c == label.len() {
                return child.insert(&tokens[c..], id);
            }
            // Split: `label[..c]` stays on this edge, the old child moves
            // under `label[c..]` below a fresh intermediate node.
            let tail = label.split_off(c);
            let mut old_child = Node::default();
            std::mem::swap(child, &mut old_child);
            child.edges.push((tail, old_child));
            return child.insert(&tokens[c..], id);
        }
        let leaf = Node { seg: Some(id), edges: Vec::new() };
        self.edges.push((tokens.to_vec(), leaf));
        None
    }

    /// Remove the run at exactly `tokens`; prunes empty leaves and
    /// re-merges pass-through nodes so the trie stays compressed. Returns
    /// whether the key was present.
    fn remove(&mut self, tokens: &[i32]) -> bool {
        if tokens.is_empty() {
            return self.seg.take().is_some();
        }
        let mut removed = false;
        let mut prune = None;
        for (i, (label, child)) in self.edges.iter_mut().enumerate() {
            let c = lcp(label, tokens);
            if c == 0 {
                continue;
            }
            if c < label.len() {
                return false;
            }
            removed = child.remove(&tokens[c..]);
            if removed {
                if child.seg.is_none() && child.edges.is_empty() {
                    prune = Some(i);
                } else if child.seg.is_none() && child.edges.len() == 1 {
                    let (clabel, cchild) = child.edges.pop().expect("len checked");
                    label.extend(clabel);
                    *child = cchild;
                }
            }
            break;
        }
        if let Some(i) = prune {
            self.edges.swap_remove(i);
        }
        removed
    }
}

/// Internal monotonic counters (levels are derived on demand).
#[derive(Default)]
struct Counters {
    hits: u64,
    misses: u64,
    hit_tokens: u64,
    mid_stream_hit_tokens: u64,
    inserts: u64,
    rejected: u64,
    evictions: u64,
    copied_pages: u64,
    shared_pages: u64,
    row_shared_pages: u64,
    row_copied_pages: u64,
    row_tail_copies: u64,
}

/// Pages backing one live batch row, handed out by
/// [`PrefixCache::lease_row_pages`]. The caller owns one refcount per page
/// id and must hand every id back through
/// [`PrefixCache::release_row_pages`] (directly or via
/// `PagedGroup::leave`).
#[derive(Debug, Default)]
pub struct RowPages {
    /// Ordered page ids; page `i` covers token positions
    /// `[i*P, min((i+1)*P, len))`.
    pub pages: Vec<u64>,
    /// Full pages shared with a cached run: referenced, not copied.
    pub shared: usize,
    /// Full pages copied from the source (no cached coverage).
    pub copied: usize,
    /// Partial tail pages copied (1 or 0). A tail is copied even when the
    /// cache covers it: the row will write into it, and rows never write
    /// shared pages.
    pub tail_copied: usize,
}

/// The cache itself. Owned by the engine (single-threaded, like the rest of
/// the step loop); concurrency stays in the router layer.
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    /// One radix root per weight variant (see module docs on why reuse must
    /// not cross variants).
    roots: BTreeMap<String, Node>,
    runs: BTreeMap<u64, Run>,
    pages: BTreeMap<u64, Page>,
    next_run: u64,
    next_page: u64,
    /// Logical clock for LRU recency (bumped per lookup/insert).
    tick: u64,
    resident_bytes: usize,
    /// Live row→page references (pages leased to batch rows). Pages with
    /// only row references are working set, not cache: eviction never
    /// touches them because their refcount can't reach zero while leased.
    row_refs: usize,
    counters: Counters,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> Self {
        PrefixCache {
            cfg,
            roots: BTreeMap::new(),
            runs: BTreeMap::new(),
            pages: BTreeMap::new(),
            next_run: 1,
            next_page: 1,
            tick: 0,
            resident_bytes: 0,
            row_refs: 0,
            counters: Counters::default(),
        }
    }

    pub fn config(&self) -> &PrefixCacheConfig {
        &self.cfg
    }

    /// Pool page size in tokens (clamped to at least 1).
    fn page_len(&self) -> usize {
        self.cfg.page_tokens.max(1)
    }

    /// Deepest cached match of `tokens` under `variant`, at least
    /// `min_prefix` (and at least one) token long. A hit pins the run
    /// (lease) and refreshes its recency; every call counts toward the hit
    /// rate. The lease's `len()` is the *match* length — it may be shorter
    /// than the backing run (and may end mid-page), in which case the run's
    /// leading positions serve it.
    pub fn lookup(&mut self, variant: &str, tokens: &[i32]) -> Option<Lease> {
        if !self.cfg.enabled {
            return None;
        }
        self.tick += 1;
        let hit = self
            .roots
            .get(variant)
            .and_then(|r| r.longest(tokens))
            .filter(|&(_, len)| len >= self.cfg.min_prefix.max(1));
        match hit {
            Some((id, len)) => {
                let run = self.runs.get_mut(&id).expect("trie points at live run");
                debug_assert!(run.key.len() >= len, "match longer than its run");
                run.leases += 1;
                run.last_use = self.tick;
                self.counters.hits += 1;
                self.counters.hit_tokens += len as u64;
                // Only tokens served past the run's prompt boundary are
                // mid-stream gain — a template hit on an extended run is
                // ordinary prompt reuse and must not inflate the tally.
                self.counters.mid_stream_hit_tokens +=
                    len.saturating_sub(run.mid_from) as u64;
                Some(Lease { id, len })
            }
            None => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Gather a leased match's prefix (`0..lease.len()` token positions of
    /// the backing run) page by page into row 0 of a zeroed single-row
    /// cache pair. Only matched tokens move: a match ending mid-page copies
    /// that page's covered head, never its uncovered remainder. The
    /// destination's sequence extent only needs to fit the match (its other
    /// axes must equal the pool's page shape).
    pub fn splice(&self, lease: &Lease, k_dst: &mut Tensor<f32>,
                  v_dst: &mut Tensor<f32>) -> Result<()> {
        let run = self
            .runs
            .get(&lease.id)
            .ok_or_else(|| anyhow!("lease {} has no live run", lease.id))?;
        if lease.len > run.key.len() {
            bail!("lease length {} exceeds run length {}", lease.len, run.key.len());
        }
        if k_dst.dims != v_dst.dims {
            bail!("destination k/v dims differ: {:?} vs {:?}", k_dst.dims, v_dst.dims);
        }
        let r = k_dst.rank();
        if r < 4 {
            bail!("destination rank {r} is not a [L, B, .., S, hd] cache");
        }
        if k_dst.dims[r - 2] < lease.len {
            bail!(
                "destination seq {} cannot hold a {}-token match",
                k_dst.dims[r - 2], lease.len
            );
        }
        let p = self.page_len();
        let mut start = 0usize;
        for &pid in &run.pages {
            if start >= lease.len {
                break;
            }
            let n = p.min(lease.len - start);
            let page = self.pages.get(&pid).ok_or_else(|| {
                anyhow!("run {} references freed page {pid}", lease.id)
            })?;
            if page.k.rank() != r
                || page.k.dims[0] != k_dst.dims[0]
                || page.k.dims[2..r - 2] != k_dst.dims[2..r - 2]
                || page.k.dims[r - 1] != k_dst.dims[r - 1]
            {
                bail!(
                    "page dims {:?} incompatible with destination {:?}",
                    page.k.dims, k_dst.dims
                );
            }
            k_dst.copy_axis1_row_seq_range_from(0, start, &page.k, 0, 0, n);
            v_dst.copy_axis1_row_seq_range_from(0, start, &page.v, 0, 0, n);
            start += n;
        }
        Ok(())
    }

    /// Hand a lease back; the run (and any page only it references) becomes
    /// evictable again once its lease count returns to zero.
    pub fn release(&mut self, lease: Lease) {
        if let Some(run) = self.runs.get_mut(&lease.id) {
            debug_assert!(run.leases > 0, "release without matching lease");
            run.leases = run.leases.saturating_sub(1);
        }
    }

    // ---- Row page-table API ------------------------------------------------
    //
    // The pool doubles as the allocator for *live batch rows* (page-table
    // rows over the shared pool, not owned `max_seq` slabs). The ownership
    // discipline is strict and simple because committed KV is append-only:
    //
    // * A row may READ any page it references (gather).
    // * A row may WRITE only a page it references *exclusively* (refs == 1)
    //   — its private growth-frontier pages. [`PrefixCache::write_row_page`]
    //   enforces this; sharing an already-written full page (admission
    //   riding a cached run, or a finish-time snapshot referencing row
    //   pages) is always safe because full pages are never written again.
    // * Row references pin pages exactly like run references: eviction
    //   frees pages only at refcount zero, so a page a live row references
    //   is never freed or COW'd out from under it.
    //
    // None of these entry points check `cfg.enabled` — with the cache
    // disabled the pool still serves as the rows' page allocator (every
    // admission simply copies all of its pages: no runs, no sharing).

    /// Longest cached match of `tokens` under `variant` *without* counting
    /// a hit/miss, taking a lease or touching recency: the sharing probe
    /// the row page-table admission uses after `lookup`/`insert` already
    /// did the accounting.
    pub fn find(&self, variant: &str, tokens: &[i32]) -> Option<(u64, usize)> {
        if !self.cfg.enabled {
            return None;
        }
        self.roots.get(variant).and_then(|r| r.longest(tokens))
    }

    /// Page shape for a source cache pair: the row shape at one page of
    /// sequence.
    fn page_dims(&self, cache_dims: &[usize]) -> Vec<usize> {
        let r = cache_dims.len();
        let mut pdims = cache_dims.to_vec();
        pdims[1] = 1;
        pdims[r - 2] = self.page_len();
        pdims
    }

    /// Allocate one zeroed private page (refcount 1, owned by a row) shaped
    /// after `cache_dims` (a `[L, B, .., S, hd]` cache's row shape at
    /// `page_tokens` sequence positions). The caller owns the reference.
    pub fn alloc_row_page(&mut self, cache_dims: &[usize]) -> u64 {
        let pdims = self.page_dims(cache_dims);
        let page_bytes = 2 * pdims.iter().product::<usize>() * std::mem::size_of::<f32>();
        let pid = self.next_page;
        self.next_page += 1;
        self.pages.insert(pid, Page {
            k: Tensor::zeros(&pdims),
            v: Tensor::zeros(&pdims),
            refs: 1,
            bytes: page_bytes,
        });
        self.resident_bytes += page_bytes;
        self.row_refs += 1;
        pid
    }

    /// Build a row's page table for `tokens`, whose KV lives in row
    /// `src_row` of `k_src`/`v_src` (the advanced prefill scratch): full
    /// pages covered by the longest cached run are *referenced* (refcount
    /// bump, zero copy), everything else — including a partial tail even
    /// when cached — is copied into fresh private pages, because the row
    /// will write its growth frontier and rows never write shared pages.
    /// Runs `evict_to_budget` afterwards: row pages count toward the pool
    /// budget like any resident page (they are the serving working set).
    pub fn lease_row_pages(&mut self, variant: &str, tokens: &[i32],
                           k_src: &Tensor<f32>, v_src: &Tensor<f32>,
                           src_row: usize) -> Result<RowPages> {
        let len = tokens.len();
        let r = k_src.rank();
        if r < 4 || k_src.dims != v_src.dims {
            bail!("source is not a cache-shaped pair: {:?} vs {:?}", k_src.dims, v_src.dims);
        }
        if src_row >= k_src.dims[1] {
            bail!("source row {src_row} out of range for batch {}", k_src.dims[1]);
        }
        if len > k_src.dims[r - 2] {
            bail!("{len} tokens exceed source seq {}", k_src.dims[r - 2]);
        }
        let p = self.page_len();
        let mut out = RowPages::default();
        if len == 0 {
            return Ok(out);
        }
        let hit = self.find(variant, tokens);
        let match_len = hit.map(|(_, m)| m).unwrap_or(0).min(len);
        let src_pages: Vec<u64> = match hit {
            Some((rid, _)) => self.runs.get(&rid).expect("trie points at live run").pages.clone(),
            None => Vec::new(),
        };
        let n_pages = len.div_ceil(p);
        // Only pages the match covers *entirely* are shareable; the row
        // must own its partial tail (and anything uncached) privately.
        let full_shared = (match_len / p).min(src_pages.len()).min(len / p);
        let pdims = self.page_dims(&k_src.dims);
        let page_bytes = 2 * pdims.iter().product::<usize>() * std::mem::size_of::<f32>();
        for i in 0..n_pages {
            let start = i * p;
            let cov = p.min(len - start);
            if i < full_shared {
                self.pages.get_mut(&src_pages[i]).expect("run references live page").refs += 1;
                self.row_refs += 1;
                self.counters.row_shared_pages += 1;
                out.pages.push(src_pages[i]);
                out.shared += 1;
                continue;
            }
            let mut pk = Tensor::<f32>::zeros(&pdims);
            let mut pv = Tensor::<f32>::zeros(&pdims);
            pk.copy_axis1_row_seq_range_from(0, 0, k_src, src_row, start, cov);
            pv.copy_axis1_row_seq_range_from(0, 0, v_src, src_row, start, cov);
            let pid = self.next_page;
            self.next_page += 1;
            self.pages.insert(pid, Page { k: pk, v: pv, refs: 1, bytes: page_bytes });
            self.resident_bytes += page_bytes;
            self.row_refs += 1;
            if cov == p {
                self.counters.row_copied_pages += 1;
                out.copied += 1;
            } else {
                self.counters.row_tail_copies += 1;
                out.tail_copied += 1;
            }
            out.pages.push(pid);
        }
        self.evict_to_budget(0);
        Ok(out)
    }

    /// Hand a row's page references back; pages whose refcount drops to
    /// zero are freed (shared pages survive on their runs' references).
    pub fn release_row_pages(&mut self, pages: &[u64]) {
        for &pid in pages {
            let Some(page) = self.pages.get_mut(&pid) else {
                debug_assert!(false, "row released unknown page {pid}");
                continue;
            };
            debug_assert!(page.refs > 0, "row release on zero-ref page {pid}");
            page.refs -= 1;
            self.row_refs = self.row_refs.saturating_sub(1);
            if page.refs == 0 {
                let bytes = page.bytes;
                self.pages.remove(&pid);
                self.resident_bytes -= bytes;
            }
        }
    }

    /// Write `n` sequence positions from `(src_row, src_pos)` of a cache
    /// pair into page `id` starting at `page_pos`. Refuses unless the page
    /// is exclusively referenced (refs == 1): rows only ever write their
    /// private growth frontier, so a shared page reaching this call is a
    /// bookkeeping bug, not a copy-on-write opportunity.
    pub fn write_row_page(&mut self, id: u64, page_pos: usize,
                          k_src: &Tensor<f32>, v_src: &Tensor<f32>,
                          src_row: usize, src_pos: usize, n: usize) -> Result<()> {
        let page = self
            .pages
            .get_mut(&id)
            .ok_or_else(|| anyhow!("write into unknown page {id}"))?;
        if page.refs != 1 {
            bail!("page {id} is shared ({} refs): rows never write shared pages", page.refs);
        }
        page.k.copy_axis1_row_seq_range_from(0, page_pos, k_src, src_row, src_pos, n);
        page.v.copy_axis1_row_seq_range_from(0, page_pos, v_src, src_row, src_pos, n);
        Ok(())
    }

    /// Copy `n` sequence positions of page `id` (from `page_pos`) into
    /// `(dst_row, dst_pos)` of a cache pair — the page-wise gather read.
    pub fn read_page_into(&self, id: u64, page_pos: usize,
                          k_dst: &mut Tensor<f32>, v_dst: &mut Tensor<f32>,
                          dst_row: usize, dst_pos: usize, n: usize) -> Result<()> {
        let page = self
            .pages
            .get(&id)
            .ok_or_else(|| anyhow!("read from unknown page {id}"))?;
        k_dst.copy_axis1_row_seq_range_from(dst_row, dst_pos, &page.k, 0, page_pos, n);
        v_dst.copy_axis1_row_seq_range_from(dst_row, dst_pos, &page.v, 0, page_pos, n);
        Ok(())
    }

    /// Snapshot a finished row's committed prefix as a run that *references*
    /// the row's own pages — the zero-copy mid-stream snapshot: pure
    /// refcount bumps, no KV moves, partial tail included (the run's key
    /// length bounds what a future splice reads, so tail positions past
    /// `tokens.len()` are never served). Returns runs evicted rebalancing
    /// the budget (the new run itself adds zero bytes).
    pub fn insert_pages(&mut self, variant: &str, tokens: &[i32], pages: &[u64],
                        mid_from: Option<usize>) -> usize {
        if !self.cfg.enabled || tokens.is_empty() || tokens.len() < self.cfg.min_prefix {
            return 0;
        }
        let len = tokens.len();
        let p = self.page_len();
        let n_pages = len.div_ceil(p);
        if pages.len() < n_pages || pages[..n_pages].iter().any(|id| !self.pages.contains_key(id)) {
            return 0; // not a coherent page table for this key; refuse quietly
        }
        self.tick += 1;
        // Same fully-covered fast path as insert_from_row: a key a cached
        // run already covers adds nothing.
        if let Some((rid, m)) = self.roots.get(variant).and_then(|rt| rt.longest(tokens)) {
            if m == len {
                if let Some(run) = self.runs.get_mut(&rid) {
                    run.last_use = self.tick;
                }
                return 0;
            }
        }
        let run_pages: Vec<u64> = pages[..n_pages].to_vec();
        for pid in &run_pages {
            self.pages.get_mut(pid).expect("checked above").refs += 1;
            self.counters.shared_pages += 1;
        }
        let id = self.next_run;
        self.next_run += 1;
        let _replaced = self
            .roots
            .entry(variant.to_string())
            .or_default()
            .insert(tokens, id);
        debug_assert!(_replaced.is_none(), "fully-covered check said the key was absent");
        self.runs.insert(id, Run {
            variant: variant.to_string(),
            key: tokens.to_vec(),
            pages: run_pages,
            leases: 0,
            last_use: self.tick,
            mid_from: mid_from.unwrap_or(len).min(len),
        });
        self.counters.inserts += 1;
        self.evict_to_budget(id)
    }

    /// Snapshot the first `tokens.len()` positions of an advanced
    /// single-row cache pair under (`variant`, `tokens`) — see
    /// [`PrefixCache::insert_from_row`].
    pub fn insert(&mut self, variant: &str, tokens: &[i32], k: &Tensor<f32>,
                  v: &Tensor<f32>) -> usize {
        self.insert_from_row(variant, tokens, k, v, 0, None)
    }

    /// Snapshot the first `tokens.len()` sequence positions of row
    /// `src_row` of an advanced cache pair under (`variant`, `tokens`),
    /// then evict least-recently-used unleased runs until the budget holds.
    /// Returns the number of runs evicted.
    ///
    /// The insert is *paged and deduplicating*: full pages shared with the
    /// longest already-cached prefix are referenced, not copied; a tail
    /// page is extended in place only when exclusively owned by the run it
    /// extends (copy-on-write otherwise); and a key already fully covered
    /// by a cached run only refreshes that run's recency. `mid_from`
    /// marks a mid-stream snapshot (the engine's finish-time runs): key
    /// positions from that boundary on are *generated continuation*, and
    /// only match tokens served past it count toward
    /// [`PrefixCacheStats::mid_stream_hit_tokens`]. `None` = plain prompt
    /// content.
    pub fn insert_from_row(&mut self, variant: &str, tokens: &[i32],
                           k: &Tensor<f32>, v: &Tensor<f32>, src_row: usize,
                           mid_from: Option<usize>) -> usize {
        if !self.cfg.enabled || tokens.is_empty() || tokens.len() < self.cfg.min_prefix {
            return 0;
        }
        let len = tokens.len();
        let r = k.rank();
        if r < 4 || k.dims != v.dims || src_row >= k.dims[1] || len > k.dims[r - 2] {
            return 0; // not a cache-shaped source, or prefix longer than it holds
        }
        let p = self.page_len();
        self.tick += 1;

        // Longest cached prefix: the sharing source, and the fully-covered
        // fast path — a key that is a prefix of a cached run adds nothing
        // (lookups already match *into* runs), so it only refreshes
        // recency. This also dedupes exact re-inserts.
        let hit = self.roots.get(variant).and_then(|rt| rt.longest(tokens));
        let match_len = hit.map(|(_, m)| m).unwrap_or(0);
        if let Some((rid, m)) = hit {
            if m == len {
                if let Some(run) = self.runs.get_mut(&rid) {
                    run.last_use = self.tick;
                }
                return 0;
            }
        }
        let (src_pages, src_len): (Vec<u64>, usize) = match hit {
            Some((rid, _)) => {
                let run = self.runs.get(&rid).expect("trie points at live run");
                (run.pages.clone(), run.key.len())
            }
            None => (Vec::new(), 0),
        };

        // Page shape: the source's row shape at one page of sequence.
        let mut pdims = k.dims.clone();
        pdims[1] = 1;
        pdims[r - 2] = p;
        let page_bytes = 2 * pdims.iter().product::<usize>() * std::mem::size_of::<f32>();
        let n_pages = len.div_ceil(p);
        if n_pages * page_bytes > self.cfg.budget_bytes {
            self.counters.rejected += 1;
            return 0;
        }

        let full_shared = (match_len / p).min(src_pages.len());
        let mut pages = Vec::with_capacity(n_pages);
        for i in 0..n_pages {
            let start = i * p;
            let cov = p.min(len - start); // this run's coverage of page i
            if i < full_shared {
                // Fully shared page: reference, don't copy.
                let pid = src_pages[i];
                self.pages.get_mut(&pid).expect("run references live page").refs += 1;
                self.counters.shared_pages += 1;
                pages.push(pid);
                continue;
            }
            if i == full_shared && match_len > start && i < src_pages.len() {
                // Boundary page: the match ends inside it. Extend in place
                // only when the source run ends exactly at the match (no
                // diverging bytes to clobber) and owns the page alone —
                // positions below the old coverage are never rewritten, so
                // even a concurrent lease on the source run stays valid.
                let shared_cov = match_len - start;
                let pid = src_pages[i];
                let exclusive = self.pages.get(&pid).map(|pg| pg.refs == 1).unwrap_or(false);
                if match_len == src_len && exclusive && shared_cov < cov {
                    let page = self.pages.get_mut(&pid).expect("exclusive page is live");
                    page.k.copy_axis1_row_seq_range_from(
                        0, shared_cov, k, src_row, start + shared_cov, cov - shared_cov,
                    );
                    page.v.copy_axis1_row_seq_range_from(
                        0, shared_cov, v, src_row, start + shared_cov, cov - shared_cov,
                    );
                    page.refs += 1;
                    self.counters.shared_pages += 1;
                    pages.push(pid);
                    continue;
                }
                // Shared-tail divergence: copy-on-write into a fresh page.
            }
            let mut pk = Tensor::<f32>::zeros(&pdims);
            let mut pv = Tensor::<f32>::zeros(&pdims);
            pk.copy_axis1_row_seq_range_from(0, 0, k, src_row, start, cov);
            pv.copy_axis1_row_seq_range_from(0, 0, v, src_row, start, cov);
            let pid = self.next_page;
            self.next_page += 1;
            self.pages.insert(pid, Page { k: pk, v: pv, refs: 1, bytes: page_bytes });
            self.resident_bytes += page_bytes;
            self.counters.copied_pages += 1;
            pages.push(pid);
        }

        let id = self.next_run;
        self.next_run += 1;
        let _replaced = self
            .roots
            .entry(variant.to_string())
            .or_default()
            .insert(tokens, id);
        debug_assert!(_replaced.is_none(), "fully-covered check said the key was absent");
        self.runs.insert(id, Run {
            variant: variant.to_string(),
            key: tokens.to_vec(),
            pages,
            leases: 0,
            last_use: self.tick,
            mid_from: mid_from.unwrap_or(len).min(len),
        });
        self.counters.inserts += 1;
        self.evict_to_budget(id)
    }

    /// Evict unleased runs (LRU first) until resident page bytes fit the
    /// budget, freeing only pages whose refcount drops to zero; stops early
    /// when only leased runs (or the run this very insert just created —
    /// evicting it would be pure churn) remain, temporarily running over
    /// budget instead.
    fn evict_to_budget(&mut self, keep: u64) -> usize {
        let mut evicted = 0;
        while self.resident_bytes > self.cfg.budget_bytes {
            let victim = self
                .runs
                .iter()
                .filter(|(&id, run)| run.leases == 0 && id != keep)
                .min_by_key(|(_, run)| run.last_use)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            let run = self.runs.remove(&id).expect("victim exists");
            let _unlinked = self
                .roots
                .get_mut(&run.variant)
                .map(|r| r.remove(&run.key))
                .unwrap_or(false);
            debug_assert!(_unlinked, "run had no trie entry");
            for pid in run.pages {
                let page = self.pages.get_mut(&pid).expect("run references live page");
                page.refs -= 1;
                if page.refs == 0 {
                    let bytes = page.bytes;
                    self.pages.remove(&pid);
                    self.resident_bytes -= bytes;
                }
            }
            self.counters.evictions += 1;
            evicted += 1;
        }
        evicted
    }

    /// True while the run is resident (test hook for lease safety).
    pub fn has_run(&self, id: u64) -> bool {
        self.runs.contains_key(&id)
    }

    /// True while the page is resident in the pool (test hook).
    pub fn has_page(&self, id: u64) -> bool {
        self.pages.contains_key(&id)
    }

    /// A run's ordered page ids, or `None` when evicted (test hook).
    pub fn run_pages(&self, id: u64) -> Option<Vec<u64>> {
        self.runs.get(&id).map(|r| r.pages.clone())
    }

    /// A run's key length in tokens, or `None` when evicted (test hook).
    pub fn run_key_len(&self, id: u64) -> Option<usize> {
        self.runs.get(&id).map(|r| r.key.len())
    }

    /// Resident run ids (test hook).
    pub fn run_ids(&self) -> Vec<u64> {
        self.runs.keys().copied().collect()
    }

    /// A resident page's refcount (test hook for the refcount-integrity
    /// property: run references + live row references must equal this).
    pub fn page_ref_count(&self, id: u64) -> Option<u32> {
        self.pages.get(&id).map(|p| p.refs)
    }

    /// Resident page ids (test hook).
    pub fn page_ids(&self) -> Vec<u64> {
        self.pages.keys().copied().collect()
    }

    pub fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            hits: self.counters.hits,
            misses: self.counters.misses,
            hit_tokens: self.counters.hit_tokens,
            mid_stream_hit_tokens: self.counters.mid_stream_hit_tokens,
            inserts: self.counters.inserts,
            rejected: self.counters.rejected,
            evictions: self.counters.evictions,
            copied_pages: self.counters.copied_pages,
            shared_pages: self.counters.shared_pages,
            resident_bytes: self.resident_bytes,
            resident_pages: self.pages.len(),
            segments: self.runs.len(),
            leases: self.runs.values().map(|r| r.leases as usize).sum(),
            page_refs: self.pages.values().map(|p| p.refs as usize).sum(),
            row_shared_pages: self.counters.row_shared_pages,
            row_copied_pages: self.counters.row_copied_pages,
            row_tail_copies: self.counters.row_tail_copies,
            row_page_refs: self.row_refs,
        }
    }
}

// ---------------------------------------------------------------------------
// Locality probe: the dispatch plane's view of prefix affinity
// ---------------------------------------------------------------------------

/// FNV-1a over a token prefix — the cheap stand-in for the radix-trie
/// lookup key that `coordinator::cluster` hashes requests by. Stable across
/// processes (no `RandomState`), so CI A/B legs see the same ring keys.
fn fnv1a_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Prefix-locality index for the replica dispatcher: maps page-aligned
/// prompt-prefix boundaries to a stable *family key*, so every turn of a
/// conversation — and every request stamped from the same template —
/// consistent-hashes to the same replica.
///
/// This is deliberately **not** the [`PrefixCache`]: that structure is
/// single-threaded state owned by one engine thread, holds the page pool
/// lock discipline, and knows nothing outside its replica. The dispatcher
/// needs a probe it can take on the submit path without any pool lock, and
/// the answer is a *routing hint*, never a correctness input — a wrong
/// guess costs one cold prefill on the target replica, nothing more. For
/// the same reason the index is variant-agnostic: all replicas run the same
/// configured verifier, and the per-variant isolation the trie enforces is
/// a property of the KV bytes, not of where a request runs.
///
/// The family-key scheme handles the multi-turn growth problem: turn 1 of a
/// conversation misses and is keyed by its *first page* (so cold siblings
/// of one template co-locate immediately); `observe` then records every
/// page-aligned boundary of the prompt under that same family key, first
/// writer wins. Turn 2 arrives as `prompt ++ answer ++ follow-up`, probes
/// longest-boundary-first, hits one of turn 1's recorded boundaries, and
/// resolves to the *identical* family key — the ring sends it home even
/// though its longest matched prefix grew.
pub struct LocalityIndex {
    page_tokens: usize,
    /// boundary hash → family key, first writer wins.
    families: HashMap<u64, u64>,
    /// Insertion order of boundary hashes, for capacity eviction.
    order: VecDeque<u64>,
    cap: usize,
}

impl LocalityIndex {
    /// Default boundary capacity: plenty for the workload's template count
    /// times typical conversation depth, small enough that the index stays
    /// cache-resident on the submit path.
    pub const DEFAULT_CAP: usize = 4096;

    pub fn new(page_tokens: usize) -> Self {
        Self::with_capacity(page_tokens, Self::DEFAULT_CAP)
    }

    pub fn with_capacity(page_tokens: usize, cap: usize) -> Self {
        LocalityIndex {
            page_tokens: page_tokens.max(1),
            families: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
        }
    }

    /// Page-aligned prefix lengths of `prompt`, shortest first; a prompt
    /// shorter than one page contributes its whole length so it still has
    /// a key.
    fn boundaries(&self, prompt_len: usize) -> Vec<usize> {
        let p = self.page_tokens;
        if prompt_len < p {
            return if prompt_len == 0 { Vec::new() } else { vec![prompt_len] };
        }
        (1..=prompt_len / p).map(|i| i * p).collect()
    }

    /// Resolve the family key this prompt routes by. Scans the prompt's
    /// page-aligned boundaries longest-first and returns the first recorded
    /// family (`hit = true`); an unseen prompt falls back to the hash of
    /// its first page (`hit = false`), which is exactly the key `observe`
    /// will then record its boundaries under. Read-only and lock-free state
    /// aside from the caller's own synchronization.
    pub fn probe(&self, prompt: &[i32]) -> (u64, bool) {
        let bounds = self.boundaries(prompt.len());
        for &len in bounds.iter().rev() {
            if let Some(&family) = self.families.get(&fnv1a_tokens(&prompt[..len])) {
                return (family, true);
            }
        }
        let anchor = bounds.first().copied().unwrap_or(0);
        (fnv1a_tokens(&prompt[..anchor]), false)
    }

    /// Record this prompt's boundaries under its resolved family key and
    /// return `(family, hit)` as [`LocalityIndex::probe`] would. First
    /// writer wins per boundary: once a boundary belongs to a family it is
    /// never re-pointed, which is what keeps a conversation's ring key
    /// stable across turns.
    pub fn observe(&mut self, prompt: &[i32]) -> (u64, bool) {
        let (family, hit) = self.probe(prompt);
        for len in self.boundaries(prompt.len()) {
            let h = fnv1a_tokens(&prompt[..len]);
            if self.families.contains_key(&h) {
                continue;
            }
            self.families.insert(h, family);
            self.order.push_back(h);
            while self.order.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.families.remove(&old);
                }
            }
        }
        (family, hit)
    }

    /// Recorded boundary count (capacity accounting, tests).
    pub fn len(&self) -> usize {
        self.families.len()
    }

    pub fn is_empty(&self) -> bool {
        self.families.is_empty()
    }
}

#[cfg(test)]
mod locality_tests {
    use super::*;

    const P: usize = 4;

    fn prompt(template: i32, body: &[i32]) -> Vec<i32> {
        let mut t: Vec<i32> = (0..8).map(|i| template * 100 + i).collect();
        t.extend_from_slice(body);
        t
    }

    #[test]
    fn multi_turn_resubmits_keep_one_family_key() {
        let mut ix = LocalityIndex::new(P);
        let turn1 = prompt(1, &[7, 8, 9]);
        let (f1, hit1) = ix.observe(&turn1);
        assert!(!hit1, "first sighting is a miss");
        // Turn 2 = turn 1 ++ answer ++ follow-up, well past new boundaries.
        let mut turn2 = turn1.clone();
        turn2.extend_from_slice(&[20, 21, 22, 23, 24, 25, 26, 27, 30, 31]);
        let (f2, hit2) = ix.observe(&turn2);
        assert!(hit2, "turn 2 hits a turn-1 boundary");
        assert_eq!(f1, f2, "family key is stable as the prefix grows");
        // Turn 3 keeps the chain going from turn 2's longer boundaries.
        let mut turn3 = turn2.clone();
        turn3.extend_from_slice(&[40, 41, 42, 43, 44]);
        let (f3, hit3) = ix.probe(&turn3);
        assert!(hit3);
        assert_eq!(f1, f3);
    }

    #[test]
    fn same_template_cold_requests_co_locate() {
        let mut ix = LocalityIndex::new(P);
        let (fa, _) = ix.observe(&prompt(1, &[7, 8, 9]));
        // A sibling stamped from the same template, different body, shares
        // the template pages — same family even though its tail diverges.
        let (fb, hit) = ix.observe(&prompt(1, &[50, 60]));
        assert!(hit, "template pages were recorded by the first sibling");
        assert_eq!(fa, fb);
        // A different template resolves to a different family.
        let (fc, hit_c) = ix.observe(&prompt(2, &[7, 8, 9]));
        assert!(!hit_c);
        assert_ne!(fa, fc);
    }

    #[test]
    fn short_prompts_still_key_and_capacity_evicts_oldest() {
        let mut ix = LocalityIndex::with_capacity(P, 4);
        let (f, hit) = ix.observe(&[1, 2]); // shorter than one page
        assert!(!hit);
        assert_eq!(ix.probe(&[1, 2]), (f, true));
        assert!(!ix.probe(&[]).1, "empty prompt never hits");
        // Flood past the cap: the oldest boundaries fall out of the map.
        for t in 10..20 {
            ix.observe(&prompt(t, &[]));
        }
        assert!(ix.len() <= 4, "index bounded by its capacity");
        assert!(!ix.probe(&[1, 2]).1, "oldest boundary evicted");
        // Hashing is deterministic: a fresh index resolves the same keys.
        let mut ix2 = LocalityIndex::new(P);
        let (g, _) = ix2.observe(&[1, 2]);
        assert_eq!(f, g, "family keys are process-stable (FNV, no RandomState)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [usize; 5] = [2, 1, 2, 16, 4]; // [L, 1, H, S, hd]
    const PAGE: usize = 4; // page_tokens
    const PAGE_BYTES: usize = 2 * 2 * 2 * PAGE * 4 * 4; // k+v pair, f32

    fn cfg(budget_pages: usize) -> PrefixCacheConfig {
        PrefixCacheConfig {
            enabled: true,
            budget_bytes: budget_pages * PAGE_BYTES,
            min_prefix: 2,
            page_tokens: PAGE,
            mid_stream: true,
        }
    }

    /// A row pair whose position `s` holds `fill + s` (checks that splice
    /// moves the right sequence positions).
    fn row(fill: f32) -> (Tensor<f32>, Tensor<f32>) {
        let mut k = Tensor::<f32>::zeros(&DIMS);
        for l in 0..DIMS[0] {
            for h in 0..DIMS[2] {
                for s in 0..DIMS[3] {
                    for d in 0..DIMS[4] {
                        let off = (((l * DIMS[1]) * DIMS[2] + h) * DIMS[3] + s) * DIMS[4] + d;
                        k.data[off] = fill + s as f32;
                    }
                }
            }
        }
        let v = k.clone();
        (k, v)
    }

    /// A row pair whose position `s` holds `tokens[s]` — the shape real KV
    /// sharing relies on: identical token prefixes mean identical bytes.
    fn row_for(tokens: &[i32]) -> (Tensor<f32>, Tensor<f32>) {
        assert!(tokens.len() <= DIMS[3]);
        let mut k = Tensor::<f32>::zeros(&DIMS);
        let mut v = Tensor::<f32>::zeros(&DIMS);
        for l in 0..DIMS[0] {
            for h in 0..DIMS[2] {
                for (s, &t) in tokens.iter().enumerate() {
                    for d in 0..DIMS[4] {
                        let off = (((l * DIMS[1]) * DIMS[2] + h) * DIMS[3] + s) * DIMS[4] + d;
                        k.data[off] = t as f32;
                        v.data[off] = t as f32 + 0.5;
                    }
                }
            }
        }
        (k, v)
    }

    fn spliced(c: &PrefixCache, l: &Lease) -> (Tensor<f32>, Tensor<f32>) {
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.splice(l, &mut dk, &mut dv).expect("splice");
        (dk, dv)
    }

    #[test]
    fn longest_prefix_match_with_min_prefix_floor() {
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(10.0);
        assert_eq!(c.insert("fp32", &[1, 2, 3], &k, &v), 0);
        assert_eq!(c.insert("fp32", &[1, 2, 3, 4, 5], &k, &v), 0);

        // Deepest cached match wins.
        let l = c.lookup("fp32", &[1, 2, 3, 4, 5, 6, 7]).expect("hit");
        assert_eq!(l.len(), 5);
        c.release(l);
        // A query ending *inside* the longer key is served by that run's
        // leading positions: all 4 query tokens match.
        let l = c.lookup("fp32", &[1, 2, 3, 4]).expect("hit");
        assert_eq!(l.len(), 4);
        c.release(l);
        // Shared tokens below min_prefix don't hit (only 1 common token
        // along [1, 9]).
        assert!(c.lookup("fp32", &[1, 9, 9]).is_none());
        // Unknown variant roots are isolated.
        assert!(c.lookup("w8a8", &[1, 2, 3, 4, 5]).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
        assert_eq!(s.hit_tokens, 9);
        assert_eq!(s.leases, 0);
        assert_eq!(s.mid_stream_hit_tokens, 0, "prompt runs are not mid-stream");
    }

    #[test]
    fn partial_match_into_a_longer_run_serves_the_shared_prefix() {
        // The serving-shape case: one cached request `template ++ body_a`
        // must serve the shared template to a request `template ++ body_b`
        // (and an exact duplicate capped one token short must hit at
        // len - 1). Neither query is a whole cached key.
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(50.0);
        let template = [1, 8, 8, 8];
        let full: Vec<i32> = template.iter().chain(&[41, 42]).copied().collect();
        c.insert("fp32", &full, &k, &v);

        // template ++ other body: matches exactly the template tokens.
        let query: Vec<i32> = template.iter().chain(&[77, 78, 79]).copied().collect();
        let l = c.lookup("fp32", &query).expect("template hit");
        assert_eq!(l.len(), template.len());
        // Splice serves only the matched positions, not the whole run.
        let (dk, _dv) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, 3, 0]), 53.0, "last matched position copied");
        assert_eq!(
            dk.at(&[0, 0, 0, 4, 0]),
            0.0,
            "run positions past the match stay out"
        );
        c.release(l);

        // Exact duplicate, capped one token short (the engine's hit cap).
        let l = c.lookup("fp32", &full[..full.len() - 1]).expect("duplicate hit");
        assert_eq!(l.len(), full.len() - 1);
        c.release(l);
    }

    #[test]
    fn match_ending_mid_page_never_leaks_the_trailing_pages_remainder() {
        // Regression (paged-store edge): a run of 7 tokens spans pages
        // [0..4) and [4..7). Resubmitting the prompt one token shorter
        // matches 6 tokens — the splice must copy exactly one token of the
        // trailing page, not its full coverage, and certainly not the
        // page's uncovered tail positions.
        let mut c = PrefixCache::new(cfg(8));
        let key = [9, 9, 9, 9, 5, 6, 7];
        let (k, v) = row_for(&key);
        c.insert("fp32", &key, &k, &v);
        let l = c.lookup("fp32", &key[..key.len() - 1]).expect("hit");
        assert_eq!(l.len(), 6, "one-token-shorter resubmit matches len-1");
        let (dk, dv) = spliced(&c, &l);
        for s in 0..6 {
            assert_eq!(dk.at(&[1, 0, 1, s, 2]), key[s] as f32, "position {s}");
            assert_eq!(dv.at(&[1, 0, 1, s, 2]), key[s] as f32 + 0.5, "position {s}");
        }
        assert_eq!(dk.at(&[0, 0, 0, 6, 0]), 0.0, "unmatched covered token stays out");
        assert_eq!(dk.at(&[0, 0, 0, 7, 0]), 0.0, "uncovered page tail stays out");
        c.release(l);
    }

    #[test]
    fn radix_edges_split_on_divergence() {
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(0.0);
        c.insert("fp32", &[7, 7, 7, 1], &k, &v);
        c.insert("fp32", &[7, 7, 7, 2, 2], &k, &v); // splits the [7,7,7,1] edge
        for (query, want) in [
            (&[7, 7, 7, 1, 5][..], 4usize),
            (&[7, 7, 7, 2, 2][..], 5),
            // diverges after the 3-token spine: served by either deeper
            // run's leading positions
            (&[7, 7, 7, 9][..], 3),
            (&[7, 7][..], 2),
        ] {
            let l = c.lookup("fp32", query).unwrap_or_else(|| panic!("miss on {query:?}"));
            assert_eq!(l.len(), want, "query {query:?}");
            c.release(l);
        }
        // A key fully covered by a cached run inserts nothing new (lookups
        // already match into runs), so the trie holds only maximal keys.
        let before = c.stats().segments;
        assert_eq!(c.insert("fp32", &[7, 7], &k, &v), 0);
        assert_eq!(c.stats().segments, before, "covered key must not add a run");
    }

    #[test]
    fn splice_copies_only_the_valid_prefix_and_validates_shapes() {
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(100.0);
        c.insert("fp32", &[1, 2, 3], &k, &v);
        let l = c.lookup("fp32", &[1, 2, 3, 4]).expect("hit");
        let (dk, mut dv) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, 0, 0]), 100.0);
        assert_eq!(dk.at(&[1, 0, 1, 2, 3]), 102.0);
        assert_eq!(dk.at(&[0, 0, 0, 3, 0]), 0.0, "beyond the prefix stays zero");
        // A destination whose sequence extent cannot hold the match is an
        // error, not a corrupt copy (shorter-but-sufficient extents are
        // fine — pages are position-strided, not row-shaped).
        let mut short = Tensor::<f32>::zeros(&[2, 1, 2, 2, 4]);
        assert!(c.splice(&l, &mut short, &mut dv).is_err());
        // Mismatched head dims are rejected too.
        let mut bad_h = Tensor::<f32>::zeros(&[2, 1, 3, 8, 4]);
        assert!(c.splice(&l, &mut bad_h, &mut dv).is_err());
        c.release(l);
    }

    #[test]
    fn shared_template_pages_are_referenced_not_copied() {
        let mut c = PrefixCache::new(cfg(16));
        let template: Vec<i32> = vec![3; 2 * PAGE]; // two full pages
        let a: Vec<i32> = template.iter().chain(&[10, 11]).copied().collect();
        let b: Vec<i32> = template.iter().chain(&[20]).copied().collect();
        let (ka, va) = row_for(&a);
        let (kb, vb) = row_for(&b);

        c.insert("fp32", &a, &ka, &va);
        let s = c.stats();
        assert_eq!(s.resident_pages, 3, "ceil(10/4) pages, not a max_seq row");
        assert_eq!(s.resident_bytes, 3 * PAGE_BYTES, "residency is page-granular");
        assert_eq!(s.copied_pages, 3);

        c.insert("fp32", &b, &kb, &vb);
        let s = c.stats();
        assert_eq!(s.segments, 2);
        assert_eq!(
            s.copied_pages, 4,
            "second insert copies only its divergent tail page"
        );
        assert_eq!(s.shared_pages, 2, "the two full template pages are shared");
        assert_eq!(s.resident_pages, 4);
        assert_eq!(s.page_refs, 6, "3 + 3 run references over 4 physical pages");
        assert!(s.page_share_ratio() > 1.0);

        // Both runs really reference the same physical template pages.
        let ids = c.run_ids();
        assert_eq!(ids.len(), 2);
        let p0 = c.run_pages(ids[0]).unwrap();
        let p1 = c.run_pages(ids[1]).unwrap();
        assert_eq!(p0[..2], p1[..2], "template pages shared by id");
        assert_ne!(p0[2], p1[2], "tails diverge");

        // And each serves its own content correctly through a splice.
        let l = c.lookup("fp32", &b).expect("hit");
        assert_eq!(l.len(), b.len());
        let (dk, _) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, 8, 0]), 20.0, "b's tail, not a's");
        c.release(l);
    }

    #[test]
    fn one_run_serves_concurrent_leases_with_zero_pool_copies() {
        // The zero-copy acceptance gate: two admissions leasing the same
        // page-run concurrently move no pool pages at all — splices read
        // pages into the callers' scratch, the pool itself never copies.
        let mut c = PrefixCache::new(cfg(8));
        let key = [5, 5, 5, 5, 5, 1];
        let (k, v) = row_for(&key);
        c.insert("fp32", &key, &k, &v);
        let copied = c.stats().copied_pages;

        let l1 = c.lookup("fp32", &key[..key.len() - 1]).expect("hit 1");
        let l2 = c.lookup("fp32", &key[..key.len() - 1]).expect("hit 2");
        assert_eq!(l1.id(), l2.id(), "one physical run backs both admissions");
        assert_eq!(c.stats().leases, 2);
        let (dk1, _) = spliced(&c, &l1);
        let (dk2, _) = spliced(&c, &l2);
        assert_eq!(dk1, dk2);
        let s = c.stats();
        assert_eq!(s.copied_pages, copied, "concurrent service copied pool pages");
        assert_eq!(s.resident_pages, 2);
        c.release(l1);
        c.release(l2);
        // Re-inserting the duplicate adds nothing either.
        assert_eq!(c.insert("fp32", &key, &k, &v), 0);
        assert_eq!(c.stats().copied_pages, copied);
        assert_eq!(c.stats().leases, 0);
    }

    #[test]
    fn tail_page_extends_in_place_when_exclusive_and_cows_when_shared() {
        let mut c = PrefixCache::new(cfg(16));
        let base: Vec<i32> = vec![2, 2, 2, 2, 7, 8]; // pages [0..4), [4..6)
        let (kb, vb) = row_for(&base);
        c.insert("fp32", &base, &kb, &vb);
        assert_eq!(c.stats().resident_pages, 2);

        // Extension while the tail page is exclusively owned: in place, no
        // new page (mid-stream shape: prompt run extended by generation —
        // positions past `base.len()` are the generated continuation).
        let ext: Vec<i32> = base.iter().chain(&[9, 9]).copied().collect();
        let (ke, ve) = row_for(&ext);
        c.insert_from_row("fp32", &ext, &ke, &ve, 0, Some(base.len()));
        let s = c.stats();
        assert_eq!(s.resident_pages, 2, "in-place tail extension allocates nothing");
        assert_eq!(s.copied_pages, 2, "still only the base run's two copies");
        let l = c.lookup("fp32", &ext).expect("hit");
        assert_eq!(l.len(), ext.len());
        let (dk, _) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, 6, 0]), 9.0, "extended positions readable");
        assert_eq!(dk.at(&[0, 0, 0, 5, 0]), 8.0, "old coverage untouched");
        c.release(l);
        // Mid-stream accounting counts only the generated tokens served,
        // not the prompt prefix the match rode through.
        assert_eq!(
            c.stats().mid_stream_hit_tokens,
            (ext.len() - base.len()) as u64
        );

        // A diverging sibling cannot extend the (now shared) tail page in
        // place: it copies on write.
        let div: Vec<i32> = base[..5].iter().chain(&[30, 31]).copied().collect();
        let (kd, vd) = row_for(&div);
        let pages_before = c.stats().resident_pages;
        c.insert("fp32", &div, &kd, &vd);
        let s = c.stats();
        assert_eq!(s.resident_pages, pages_before + 1, "divergent tail copied");
        // The original run still serves its own bytes.
        let l = c.lookup("fp32", &ext).expect("hit");
        let (dk, _) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, 5, 0]), 8.0, "COW left the shared run intact");
        c.release(l);
    }

    #[test]
    fn insert_from_a_multi_row_source_snapshots_the_selected_row() {
        // The mid-stream path snapshots straight out of the batched group
        // cache: [L, B, H, S, hd] with B > 1, row selected by index.
        let mut c = PrefixCache::new(cfg(8));
        let gdims = [2usize, 3, 2, 8, 4];
        let mut gk = Tensor::<f32>::zeros(&gdims);
        let mut gv = Tensor::<f32>::zeros(&gdims);
        // Row 1 holds position-coded values; other rows hold garbage.
        for l in 0..2 {
            for b in 0..3 {
                for h in 0..2 {
                    for s in 0..8 {
                        for d in 0..4 {
                            let off = ((((l * 3 + b) * 2 + h) * 8) + s) * 4 + d;
                            gk.data[off] = if b == 1 { 40.0 + s as f32 } else { -1.0 };
                            gv.data[off] = if b == 1 { 40.5 + s as f32 } else { -1.0 };
                        }
                    }
                }
            }
        }
        c.insert_from_row("fp32", &[4, 4, 4, 4, 4], &gk, &gv, 1, Some(3));
        let l = c.lookup("fp32", &[4, 4, 4, 4, 4, 6]).expect("hit");
        assert_eq!(l.len(), 5);
        assert_eq!(
            c.stats().mid_stream_hit_tokens,
            2,
            "only the 2 tokens past the prompt boundary count as mid-stream"
        );
        let (dk, dv) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, 0, 0]), 40.0);
        assert_eq!(dk.at(&[1, 0, 1, 4, 3]), 44.0);
        assert_eq!(dv.at(&[1, 0, 1, 4, 3]), 44.5);
        assert_eq!(dk.at(&[0, 0, 0, 5, 0]), 0.0);
        c.release(l);
    }

    #[test]
    fn insert_dedups_and_lru_evicts_oldest_unleased() {
        let mut c = PrefixCache::new(cfg(2));
        let (k, v) = row(0.0);
        assert_eq!(c.insert("fp32", &[1, 1], &k, &v), 0);
        assert_eq!(c.insert("fp32", &[1, 1], &k, &v), 0, "duplicate key: no new run");
        assert_eq!(c.stats().segments, 1);
        assert_eq!(c.insert("fp32", &[2, 2], &k, &v), 0);
        // Touch [1,1] so [2,2] is the LRU victim.
        let l = c.lookup("fp32", &[1, 1]).expect("hit");
        c.release(l);
        assert_eq!(c.insert("fp32", &[3, 3], &k, &v), 1, "one eviction to fit");
        assert!(c.lookup("fp32", &[2, 2]).is_none(), "LRU run evicted");
        let l = c.lookup("fp32", &[1, 1]).expect("recently-used survives");
        c.release(l);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.stats().resident_bytes <= c.config().budget_bytes);
    }

    #[test]
    fn eviction_frees_only_unshared_pages() {
        let mut c = PrefixCache::new(cfg(4));
        let template: Vec<i32> = vec![6; PAGE]; // one full page
        let a: Vec<i32> = template.iter().chain(&[1]).copied().collect();
        let b: Vec<i32> = template.iter().chain(&[2]).copied().collect();
        let (ka, va) = row_for(&a);
        let (kb, vb) = row_for(&b);
        c.insert("fp32", &a, &ka, &va); // pages: T, tail_a
        c.insert("fp32", &b, &kb, &vb); // pages: T (shared), tail_b
        assert_eq!(c.stats().resident_pages, 3);
        let b_lease = c.lookup("fp32", &b).expect("hit");
        let b_pages = c.run_pages(b_lease.id()).unwrap();
        // Force eviction pressure: a 4-page insert on a 4-page budget.
        let big: Vec<i32> = (0..16).map(|i| 50 + i).collect();
        let (kg, vg) = row_for(&big);
        c.insert("fp32", &big, &kg, &vg);
        // Run a was the unleased LRU victim; its tail page is gone but the
        // template page survives because run b still references it.
        assert!(c.lookup("fp32", &a).map(|l| { let n = l.len(); c.release(l); n })
                    .map(|n| n < a.len()).unwrap_or(true),
                "run a should no longer serve its full key");
        for pid in &b_pages {
            assert!(c.has_page(*pid), "page {pid} of the leased run was freed");
        }
        let (dk, _) = spliced(&c, &b_lease);
        assert_eq!(dk.at(&[0, 0, 0, 4, 0]), 2.0, "b still serves through shared pages");
        c.release(b_lease);
    }

    #[test]
    fn leased_runs_are_never_evicted() {
        let mut c = PrefixCache::new(cfg(1));
        let (k, v) = row(0.0);
        c.insert("fp32", &[1, 1], &k, &v);
        let lease = c.lookup("fp32", &[1, 1]).expect("hit");
        let id = lease.id();
        // Budget is one page; these inserts each demand an eviction, but
        // the only other resident run is leased.
        c.insert("fp32", &[2, 2], &k, &v);
        c.insert("fp32", &[3, 3], &k, &v);
        assert!(c.has_run(id), "leased run evicted under pressure");
        assert!(
            c.stats().resident_bytes > c.config().budget_bytes,
            "cache should run over budget rather than free a lease"
        );
        // Splice still works mid-pressure.
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.splice(&lease, &mut dk, &mut dv).expect("leased splice");
        c.release(lease);
        // Once released, the next insert can reclaim it.
        c.insert("fp32", &[4, 4], &k, &v);
        assert!(!c.has_run(id), "released LRU run reclaimed");
        assert!(c.stats().resident_bytes <= c.config().budget_bytes);
        assert_eq!(c.stats().leases, 0);
    }

    #[test]
    fn oversize_run_and_disabled_cache_reject_cleanly() {
        let mut c = PrefixCache::new(PrefixCacheConfig {
            enabled: true,
            budget_bytes: PAGE_BYTES / 2,
            min_prefix: 2,
            page_tokens: PAGE,
            mid_stream: true,
        });
        let (k, v) = row(0.0);
        assert_eq!(c.insert("fp32", &[1, 1], &k, &v), 0);
        assert_eq!(c.stats().rejected, 1);
        assert_eq!(c.stats().segments, 0);
        assert_eq!(c.stats().resident_pages, 0);

        let mut off = PrefixCache::new(PrefixCacheConfig::off());
        assert_eq!(off.insert("fp32", &[1, 1], &k, &v), 0);
        assert!(off.lookup("fp32", &[1, 1]).is_none());
        assert_eq!(off.stats(), PrefixCacheStats::default());
    }

    #[test]
    fn lease_row_pages_shares_full_pages_and_copies_only_the_tail() {
        let mut c = PrefixCache::new(cfg(16));
        let key: Vec<i32> = (0..2 * PAGE as i32 + 2).map(|i| 100 + i).collect();
        let (k, v) = row_for(&key);
        c.insert("fp32", &key, &k, &v);
        let run_pages = c.run_pages(c.run_ids()[0]).unwrap();
        let before = c.stats();

        let rp = c.lease_row_pages("fp32", &key, &k, &v, 0).expect("lease");
        assert_eq!((rp.shared, rp.copied, rp.tail_copied), (2, 0, 1),
                   "fully-cached admission: zero full-page copies");
        assert_eq!(rp.pages[..2], run_pages[..2], "full pages shared by id");
        assert_ne!(rp.pages[2], run_pages[2], "tail page is private");
        let s = c.stats();
        assert_eq!(s.resident_pages, before.resident_pages + 1, "only the tail allocated");
        assert_eq!(s.row_copied_pages, 0);
        assert_eq!(s.row_shared_pages, 2);
        assert_eq!(s.row_tail_copies, 1);
        assert_eq!(s.row_page_refs, 3);
        assert_eq!(c.page_ref_count(rp.pages[0]), Some(2), "run + row");
        assert_eq!(c.page_ref_count(rp.pages[2]), Some(1), "row only");

        // The private tail really holds the row's KV.
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.read_page_into(rp.pages[2], 0, &mut dk, &mut dv, 0, 2 * PAGE, 2).unwrap();
        assert_eq!(dk.at(&[0, 0, 0, 2 * PAGE, 0]), key[2 * PAGE] as f32);

        // Releasing the row frees only the private tail; shared pages
        // survive on the run's references.
        c.release_row_pages(&rp.pages);
        let s = c.stats();
        assert_eq!(s.row_page_refs, 0);
        assert_eq!(s.resident_pages, before.resident_pages);
        assert!(!c.has_page(rp.pages[2]), "private tail freed at zero refs");
        assert!(c.has_page(rp.pages[0]), "shared page survives");
    }

    #[test]
    fn lease_row_pages_with_cache_disabled_copies_everything() {
        // The pool still serves as the rows' page allocator when the cache
        // is off: no runs, no sharing, every page private.
        let mut c = PrefixCache::new(PrefixCacheConfig {
            page_tokens: PAGE,
            ..PrefixCacheConfig::off()
        });
        let key: Vec<i32> = (0..PAGE as i32 + 1).collect();
        let (k, v) = row_for(&key);
        let rp = c.lease_row_pages("fp32", &key, &k, &v, 0).expect("lease");
        assert_eq!((rp.shared, rp.copied, rp.tail_copied), (0, 1, 1));
        assert_eq!(c.stats().resident_pages, 2);
        assert_eq!(c.stats().segments, 0, "no run materialized");
        c.release_row_pages(&rp.pages);
        assert_eq!(c.stats().resident_pages, 0, "all refs returned to zero");
        assert_eq!(c.stats().resident_bytes, 0);
    }

    #[test]
    fn write_row_page_refuses_shared_pages_and_writes_private_ones() {
        let mut c = PrefixCache::new(cfg(16));
        let key: Vec<i32> = vec![4; PAGE];
        let (k, v) = row_for(&key);
        c.insert("fp32", &key, &k, &v);
        let rp = c.lease_row_pages("fp32", &key, &k, &v, 0).expect("lease");
        assert_eq!(rp.shared, 1);
        assert!(
            c.write_row_page(rp.pages[0], 0, &k, &v, 0, 0, 1).is_err(),
            "rows never write shared pages"
        );
        let pid = c.alloc_row_page(&DIMS);
        c.write_row_page(pid, 1, &k, &v, 0, 2, 2).expect("private write");
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.read_page_into(pid, 1, &mut dk, &mut dv, 0, 0, 2).unwrap();
        assert_eq!(dk.at(&[0, 0, 0, 0, 0]), 4.0, "wrote source position 2");
        assert_eq!(dk.at(&[0, 0, 0, 2, 0]), 0.0, "beyond the range untouched");
        c.release_row_pages(&rp.pages);
        c.release_row_pages(&[pid]);
        assert_eq!(c.stats().row_page_refs, 0);
    }

    #[test]
    fn insert_pages_snapshots_by_reference_with_zero_copies() {
        let mut c = PrefixCache::new(cfg(16));
        // A "finished row": page table built cold (nothing cached yet).
        let key: Vec<i32> = (0..PAGE as i32 + 2).map(|i| 60 + i).collect();
        let (k, v) = row_for(&key);
        let rp = c.lease_row_pages("fp32", &key, &k, &v, 0).expect("lease");
        let copied_before = c.stats().copied_pages;
        let pages_before = c.stats().resident_pages;

        assert_eq!(c.insert_pages("fp32", &key, &rp.pages, Some(2)), 0);
        let s = c.stats();
        assert_eq!(s.copied_pages, copied_before, "snapshot moved zero pages");
        assert_eq!(s.resident_pages, pages_before, "snapshot allocated zero pages");
        assert_eq!(s.segments, 1);
        assert_eq!(c.page_ref_count(rp.pages[0]), Some(2), "row + run");

        // The run serves the content even after the row leaves — including
        // the partial-tail positions its key covers.
        c.release_row_pages(&rp.pages);
        let l = c.lookup("fp32", &key).expect("hit");
        assert_eq!(l.len(), key.len());
        let (dk, _) = spliced(&c, &l);
        assert_eq!(dk.at(&[0, 0, 0, PAGE + 1, 0]), key[PAGE + 1] as f32,
                   "partial-tail position served");
        c.release(l);
        assert_eq!(c.stats().mid_stream_hit_tokens, (key.len() - 2) as u64);

        // Duplicate snapshot of a covered key adds nothing.
        assert_eq!(c.insert_pages("fp32", &key, &rp.pages, Some(2)), 0);
        assert_eq!(c.stats().segments, 1);
        // A page table too short for its key is refused quietly.
        assert_eq!(c.insert_pages("fp32", &vec![9; 3 * PAGE], &rp.pages, None), 0);
    }

    #[test]
    fn stats_derivations() {
        let s = PrefixCacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(PrefixCacheStats::default().hit_rate(), 0.0);
        let s = PrefixCacheStats { page_refs: 6, resident_pages: 4, ..Default::default() };
        assert!((s.page_share_ratio() - 1.5).abs() < 1e-12);
        assert_eq!(PrefixCacheStats::default().page_share_ratio(), 0.0);
    }
}

//! Shared-prefix KV reuse: a radix-trie index over committed token
//! sequences mapping to reference-counted single-row KV segments, with a
//! byte-budget LRU evictor.
//!
//! At serving scale the paper's five task families are heavily templated —
//! requests share long system-prompt prefixes — yet every admission paid a
//! full prefill chunk over the whole prompt. This module lets the engine
//! run admission as *longest-prefix-match, then suffix-only prefill*:
//!
//! * **Index**: one compressed radix trie per verifier weight variant over
//!   committed token sequences. Keying by variant matters — a `w8a8`-
//!   prefilled prefix is not bit-exact KV for a class the fidelity governor
//!   demoted to `fp32`, so cross-variant reuse would silently break the
//!   engine's bit-identity guarantees.
//! * **Segments**: `[L, 1, H, S, hd]` single-row KV snapshots holding the
//!   first `len` sequence positions of a committed prefix (later positions
//!   zeroed). A snapshot is taken at admission completion, so the cache
//!   only ever holds KV the verifier actually committed.
//! * **Leases**: [`PrefixCache::lookup`] returns a [`Lease`] that pins the
//!   segment (refcount) until [`PrefixCache::release`]; the evictor never
//!   frees a leased segment, so a splice in flight can never read freed
//!   memory no matter what inserts happen in between.
//! * **Eviction**: inserts that push resident bytes over `budget_bytes`
//!   evict unleased segments in least-recently-used order. When every
//!   resident segment is leased the cache temporarily exceeds its budget
//!   rather than corrupt a lease; the next insert re-tries.
//!
//! Correctness note (why suffix-only prefill is bit-exact): attention is
//! causal, so the KV a prefill writes for positions `0..h` depends only on
//! tokens `0..h`. A cached segment whose key equals the request's first `h`
//! prompt tokens therefore holds exactly the KV the request's own prefill
//! would have computed at the same variant, and running the chunk with
//! write offset `pos = h` over the remaining tokens reproduces the cold
//! path bit for bit.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::Tensor;

/// Tuning knobs for the prefix cache. `Default` is *enabled* with a 256 MiB
/// budget — reuse is lossless by construction, so it is on unless a bench
/// explicitly wants cold admissions ([`PrefixCacheConfig::off`]).
#[derive(Debug, Clone)]
pub struct PrefixCacheConfig {
    /// Master switch. Disabled: no lookups, no snapshots, zero overhead.
    pub enabled: bool,
    /// Resident-segment byte budget the LRU evictor enforces (leased
    /// segments are exempt while leased).
    pub budget_bytes: usize,
    /// Shortest prefix worth caching or matching: a tiny shared prefix
    /// saves less prefill than the row copy costs.
    pub min_prefix: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig {
            enabled: true,
            budget_bytes: 256 << 20,
            min_prefix: 4,
        }
    }
}

impl PrefixCacheConfig {
    /// Disabled (cold-admission A/B baseline).
    pub fn off() -> Self {
        PrefixCacheConfig { enabled: false, ..Default::default() }
    }
}

/// A pinned reference to one cached segment. Obtained from
/// [`PrefixCache::lookup`]; the segment cannot be evicted until the lease
/// is handed back via [`PrefixCache::release`]. Not `Clone` — one lookup,
/// one release.
#[derive(Debug)]
pub struct Lease {
    id: u64,
    len: usize,
}

impl Lease {
    /// Segment id (stable for the segment's lifetime; test hook).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Matched prefix length in tokens — the positions admission may skip.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Point-in-time counters (monotonic except `resident_bytes` / `segments`
/// / `leases`, which are levels).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Prompt tokens served from cache instead of prefill.
    pub hit_tokens: u64,
    pub inserts: u64,
    /// Inserts refused because a single segment exceeds the whole budget.
    pub rejected: u64,
    pub evictions: u64,
    pub resident_bytes: usize,
    pub segments: usize,
    /// Leases currently outstanding (refcounts not yet released).
    pub leases: usize,
}

impl PrefixCacheStats {
    /// hits / (hits + misses); 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            return 0.0;
        }
        self.hits as f64 / n as f64
    }
}

/// One resident KV snapshot.
struct Segment {
    variant: String,
    /// Token key (the committed prefix); kept so eviction can unlink the
    /// trie node. Tiny next to the KV bytes it indexes.
    key: Vec<i32>,
    /// Valid sequence positions (`0..len`); the rest of the row is zero.
    len: usize,
    bytes: usize,
    refs: u32,
    last_use: u64,
    k: Tensor<f32>,
    v: Tensor<f32>,
}

/// Longest common prefix length of two token slices.
fn lcp(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Compressed radix-trie node: each edge carries a non-empty token label;
/// a node's `seg` is the segment cached for the exact prefix spelled by the
/// path from the root.
#[derive(Default)]
struct Node {
    seg: Option<u64>,
    edges: Vec<(Vec<i32>, Node)>,
}

impl Node {
    /// Deepest usable match of `tokens` against the cached keys:
    /// `(segment id, match length)`. The walk may stop *inside* an edge or
    /// at a key-less interior node — every key in the subtree below the
    /// stop point extends `tokens[..match]`, and by causality the first
    /// `match` KV positions of any such segment are exactly the KV for
    /// `tokens[..match]`. So the cache serves partial matches *into*
    /// longer cached prefixes (template + body A serving template + body
    /// B), not just whole cached keys.
    fn longest(&self, tokens: &[i32]) -> Option<(u64, usize)> {
        let mut node = self;
        let mut depth = 0usize;
        let mut rest = tokens;
        loop {
            let edge = node
                .edges
                .iter()
                .find(|(l, _)| !rest.is_empty() && l.first() == rest.first());
            let Some((label, child)) = edge else {
                // The query ends or diverges at this node: the common
                // prefix is exactly `depth`, shared by every key under it.
                return node.any_seg().map(|id| (id, depth));
            };
            let c = lcp(label, rest);
            if c < label.len() {
                // Stopped mid-edge: every key under `child` starts with
                // `tokens[..depth + c]`.
                return child.any_seg().map(|id| (id, depth + c));
            }
            depth += c;
            rest = &rest[c..];
            node = child;
        }
    }

    /// Any segment id in this subtree (pre-order). Trie invariant: every
    /// leaf holds a segment, so this is `None` only on an empty root.
    fn any_seg(&self) -> Option<u64> {
        if let Some(id) = self.seg {
            return Some(id);
        }
        self.edges.iter().find_map(|(_, c)| c.any_seg())
    }

    /// Segment cached for exactly `tokens`, if any.
    fn exact(&self, tokens: &[i32]) -> Option<u64> {
        if tokens.is_empty() {
            return self.seg;
        }
        for (label, child) in &self.edges {
            let c = lcp(label, tokens);
            if c == 0 {
                continue;
            }
            if c == label.len() {
                return child.exact(&tokens[c..]);
            }
            return None; // diverges inside the edge
        }
        None
    }

    /// Insert `id` at `tokens`, splitting an edge if the key diverges
    /// mid-label. Returns a previously-stored id at exactly this key.
    fn insert(&mut self, tokens: &[i32], id: u64) -> Option<u64> {
        if tokens.is_empty() {
            return self.seg.replace(id);
        }
        for (label, child) in &mut self.edges {
            let c = lcp(label, tokens);
            if c == 0 {
                continue;
            }
            if c == label.len() {
                return child.insert(&tokens[c..], id);
            }
            // Split: `label[..c]` stays on this edge, the old child moves
            // under `label[c..]` below a fresh intermediate node.
            let tail = label.split_off(c);
            let mut old_child = Node::default();
            std::mem::swap(child, &mut old_child);
            child.edges.push((tail, old_child));
            return child.insert(&tokens[c..], id);
        }
        let leaf = Node { seg: Some(id), edges: Vec::new() };
        self.edges.push((tokens.to_vec(), leaf));
        None
    }

    /// Remove the segment at exactly `tokens`; prunes empty leaves and
    /// re-merges pass-through nodes so the trie stays compressed. Returns
    /// whether the key was present.
    fn remove(&mut self, tokens: &[i32]) -> bool {
        if tokens.is_empty() {
            return self.seg.take().is_some();
        }
        let mut removed = false;
        let mut prune = None;
        for (i, (label, child)) in self.edges.iter_mut().enumerate() {
            let c = lcp(label, tokens);
            if c == 0 {
                continue;
            }
            if c < label.len() {
                return false;
            }
            removed = child.remove(&tokens[c..]);
            if removed {
                if child.seg.is_none() && child.edges.is_empty() {
                    prune = Some(i);
                } else if child.seg.is_none() && child.edges.len() == 1 {
                    let (clabel, cchild) = child.edges.pop().expect("len checked");
                    label.extend(clabel);
                    *child = cchild;
                }
            }
            break;
        }
        if let Some(i) = prune {
            self.edges.swap_remove(i);
        }
        removed
    }
}

/// Internal monotonic counters (levels are derived on demand).
#[derive(Default)]
struct Counters {
    hits: u64,
    misses: u64,
    hit_tokens: u64,
    inserts: u64,
    rejected: u64,
    evictions: u64,
}

/// The cache itself. Owned by the engine (single-threaded, like the rest of
/// the step loop); concurrency stays in the router layer.
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    /// One radix root per weight variant (see module docs on why reuse must
    /// not cross variants).
    roots: BTreeMap<String, Node>,
    segments: BTreeMap<u64, Segment>,
    next_id: u64,
    /// Logical clock for LRU recency (bumped per lookup/insert).
    tick: u64,
    resident_bytes: usize,
    counters: Counters,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> Self {
        PrefixCache {
            cfg,
            roots: BTreeMap::new(),
            segments: BTreeMap::new(),
            next_id: 1,
            tick: 0,
            resident_bytes: 0,
            counters: Counters::default(),
        }
    }

    pub fn config(&self) -> &PrefixCacheConfig {
        &self.cfg
    }

    /// Deepest cached match of `tokens` under `variant`, at least
    /// `min_prefix` (and at least one) token long. A hit pins the segment
    /// (lease) and refreshes its recency; every call counts toward the hit
    /// rate. The lease's `len()` is the *match* length — it may be shorter
    /// than the backing segment, whose leading positions then serve it.
    pub fn lookup(&mut self, variant: &str, tokens: &[i32]) -> Option<Lease> {
        if !self.cfg.enabled {
            return None;
        }
        self.tick += 1;
        let hit = self
            .roots
            .get(variant)
            .and_then(|r| r.longest(tokens))
            .filter(|&(_, len)| len >= self.cfg.min_prefix.max(1));
        match hit {
            Some((id, len)) => {
                let seg = self.segments.get_mut(&id).expect("trie points at live segment");
                debug_assert!(seg.len >= len, "match longer than its segment");
                seg.refs += 1;
                seg.last_use = self.tick;
                self.counters.hits += 1;
                self.counters.hit_tokens += len as u64;
                Some(Lease { id, len })
            }
            None => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Copy a leased match's prefix (`0..lease.len()` sequence positions of
    /// the backing segment) into a zeroed single-row cache pair of the same
    /// shape.
    pub fn splice(&self, lease: &Lease, k_dst: &mut Tensor<f32>,
                  v_dst: &mut Tensor<f32>) -> Result<()> {
        let seg = self
            .segments
            .get(&lease.id)
            .ok_or_else(|| anyhow!("lease {} has no live segment", lease.id))?;
        if seg.k.dims != k_dst.dims || seg.v.dims != v_dst.dims {
            bail!(
                "segment dims {:?} incompatible with destination {:?}",
                seg.k.dims, k_dst.dims
            );
        }
        if lease.len > seg.len {
            bail!("lease length {} exceeds segment length {}", lease.len, seg.len);
        }
        k_dst.copy_seq_prefix_from(&seg.k, lease.len);
        v_dst.copy_seq_prefix_from(&seg.v, lease.len);
        Ok(())
    }

    /// Hand a lease back; the segment becomes evictable again once its
    /// refcount returns to zero.
    pub fn release(&mut self, lease: Lease) {
        if let Some(seg) = self.segments.get_mut(&lease.id) {
            debug_assert!(seg.refs > 0, "release without matching lease");
            seg.refs = seg.refs.saturating_sub(1);
        }
    }

    /// Snapshot the first `tokens.len()` positions of an advanced
    /// single-row cache pair under (`variant`, `tokens`), then evict
    /// least-recently-used unleased segments until the budget holds.
    /// Returns the number of segments evicted. A prefix already cached only
    /// refreshes its recency; one larger than the whole budget is rejected.
    pub fn insert(&mut self, variant: &str, tokens: &[i32], k: &Tensor<f32>,
                  v: &Tensor<f32>) -> usize {
        if !self.cfg.enabled || tokens.len() < self.cfg.min_prefix {
            return 0;
        }
        let len = tokens.len();
        if k.rank() < 2 || len > k.dims[k.rank() - 2] {
            return 0; // prefix longer than the row holds; nothing to snapshot
        }
        self.tick += 1;
        if let Some(id) = self.roots.get(variant).and_then(|r| r.exact(tokens)) {
            if let Some(seg) = self.segments.get_mut(&id) {
                seg.last_use = self.tick;
            }
            return 0;
        }
        let bytes = (k.numel() + v.numel()) * std::mem::size_of::<f32>();
        if bytes > self.cfg.budget_bytes {
            self.counters.rejected += 1;
            return 0;
        }
        let mut sk = Tensor::zeros(&k.dims);
        sk.copy_seq_prefix_from(k, len);
        let mut sv = Tensor::zeros(&v.dims);
        sv.copy_seq_prefix_from(v, len);
        let id = self.next_id;
        self.next_id += 1;
        let _replaced = self
            .roots
            .entry(variant.to_string())
            .or_default()
            .insert(tokens, id);
        debug_assert!(_replaced.is_none(), "exact() said the key was absent");
        self.segments.insert(id, Segment {
            variant: variant.to_string(),
            key: tokens.to_vec(),
            len,
            bytes,
            refs: 0,
            last_use: self.tick,
            k: sk,
            v: sv,
        });
        self.resident_bytes += bytes;
        self.counters.inserts += 1;
        self.evict_to_budget(id)
    }

    /// Evict unleased segments (LRU first) until resident bytes fit the
    /// budget; stops early when only leased segments (or the segment this
    /// very insert just created — evicting it would be pure churn) remain,
    /// temporarily running over budget instead.
    fn evict_to_budget(&mut self, keep: u64) -> usize {
        let mut evicted = 0;
        while self.resident_bytes > self.cfg.budget_bytes {
            let victim = self
                .segments
                .iter()
                .filter(|(&id, s)| s.refs == 0 && id != keep)
                .min_by_key(|(_, s)| s.last_use)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            let seg = self.segments.remove(&id).expect("victim exists");
            self.resident_bytes -= seg.bytes;
            let _unlinked = self
                .roots
                .get_mut(&seg.variant)
                .map(|r| r.remove(&seg.key))
                .unwrap_or(false);
            debug_assert!(_unlinked, "segment had no trie entry");
            self.counters.evictions += 1;
            evicted += 1;
        }
        evicted
    }

    /// True while the segment is resident (test hook for lease safety).
    pub fn has_segment(&self, id: u64) -> bool {
        self.segments.contains_key(&id)
    }

    pub fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            hits: self.counters.hits,
            misses: self.counters.misses,
            hit_tokens: self.counters.hit_tokens,
            inserts: self.counters.inserts,
            rejected: self.counters.rejected,
            evictions: self.counters.evictions,
            resident_bytes: self.resident_bytes,
            segments: self.segments.len(),
            leases: self.segments.values().map(|s| s.refs as usize).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [usize; 5] = [2, 1, 2, 8, 4]; // [L, 1, H, S, hd]
    const ROW_BYTES: usize = 2 * 2 * 2 * 8 * 4 * 4; // k+v, f32

    fn cfg(budget_rows: usize) -> PrefixCacheConfig {
        PrefixCacheConfig {
            enabled: true,
            budget_bytes: budget_rows * ROW_BYTES,
            min_prefix: 2,
        }
    }

    /// A row pair whose position `s` holds `fill + s` (checks that splice
    /// moves the right sequence positions).
    fn row(fill: f32) -> (Tensor<f32>, Tensor<f32>) {
        let mut k = Tensor::<f32>::zeros(&DIMS);
        for l in 0..DIMS[0] {
            for h in 0..DIMS[2] {
                for s in 0..DIMS[3] {
                    for d in 0..DIMS[4] {
                        let off = (((l * DIMS[1]) * DIMS[2] + h) * DIMS[3] + s) * DIMS[4] + d;
                        k.data[off] = fill + s as f32;
                    }
                }
            }
        }
        let v = k.clone();
        (k, v)
    }

    #[test]
    fn longest_prefix_match_with_min_prefix_floor() {
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(10.0);
        assert_eq!(c.insert("fp32", &[1, 2, 3], &k, &v), 0);
        assert_eq!(c.insert("fp32", &[1, 2, 3, 4, 5], &k, &v), 0);

        // Deepest cached match wins.
        let l = c.lookup("fp32", &[1, 2, 3, 4, 5, 6, 7]).expect("hit");
        assert_eq!(l.len(), 5);
        c.release(l);
        // A query ending *inside* the longer key is served by that
        // segment's leading positions: all 4 query tokens match.
        let l = c.lookup("fp32", &[1, 2, 3, 4]).expect("hit");
        assert_eq!(l.len(), 4);
        c.release(l);
        // Shared tokens below min_prefix don't hit (only 1 common token
        // along [1, 9]).
        assert!(c.lookup("fp32", &[1, 9, 9]).is_none());
        // Unknown variant roots are isolated.
        assert!(c.lookup("w8a8", &[1, 2, 3, 4, 5]).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
        assert_eq!(s.hit_tokens, 9);
        assert_eq!(s.leases, 0);
    }

    #[test]
    fn partial_match_into_a_longer_segment_serves_the_shared_prefix() {
        // The serving-shape case: one cached request `template ++ body_a`
        // must serve the shared template to a request `template ++ body_b`
        // (and an exact duplicate capped one token short must hit at
        // len - 1). Neither query is a whole cached key.
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(50.0);
        let template = [1, 8, 8, 8];
        let full: Vec<i32> = template.iter().chain(&[41, 42]).copied().collect();
        c.insert("fp32", &full, &k, &v);

        // template ++ other body: matches exactly the template tokens.
        let query: Vec<i32> = template.iter().chain(&[77, 78, 79]).copied().collect();
        let l = c.lookup("fp32", &query).expect("template hit");
        assert_eq!(l.len(), template.len());
        // Splice serves only the matched positions, not the whole segment.
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.splice(&l, &mut dk, &mut dv).expect("splice");
        assert_eq!(dk.at(&[0, 0, 0, 3, 0]), 53.0, "last matched position copied");
        assert_eq!(
            dk.at(&[0, 0, 0, 4, 0]),
            0.0,
            "segment positions past the match stay out"
        );
        c.release(l);

        // Exact duplicate, capped one token short (the engine's hit cap).
        let l = c.lookup("fp32", &full[..full.len() - 1]).expect("duplicate hit");
        assert_eq!(l.len(), full.len() - 1);
        c.release(l);
    }

    #[test]
    fn radix_edges_split_on_divergence() {
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(0.0);
        c.insert("fp32", &[7, 7, 7, 1], &k, &v);
        c.insert("fp32", &[7, 7, 7, 2, 2], &k, &v); // splits the [7,7,7,1] edge
        c.insert("fp32", &[7, 7], &k, &v); // node on the shared spine
        for (query, want) in [
            (&[7, 7, 7, 1, 5][..], 4usize),
            (&[7, 7, 7, 2, 2][..], 5),
            // diverges after the 3-token spine: served by either deeper
            // segment's leading positions
            (&[7, 7, 7, 9][..], 3),
            (&[7, 7][..], 2),
        ] {
            let l = c.lookup("fp32", query).unwrap_or_else(|| panic!("miss on {query:?}"));
            assert_eq!(l.len(), want, "query {query:?}");
            c.release(l);
        }
    }

    #[test]
    fn splice_copies_only_the_valid_prefix() {
        let mut c = PrefixCache::new(cfg(8));
        let (k, v) = row(100.0);
        c.insert("fp32", &[1, 2, 3], &k, &v);
        let l = c.lookup("fp32", &[1, 2, 3, 4]).expect("hit");
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.splice(&l, &mut dk, &mut dv).expect("splice");
        assert_eq!(dk.at(&[0, 0, 0, 0, 0]), 100.0);
        assert_eq!(dk.at(&[1, 0, 1, 2, 3]), 102.0);
        assert_eq!(dk.at(&[0, 0, 0, 3, 0]), 0.0, "beyond the prefix stays zero");
        // Shape mismatch is an error, not a corrupt copy.
        let mut bad = Tensor::<f32>::zeros(&[2, 1, 2, 6, 4]);
        assert!(c.splice(&l, &mut bad, &mut dv).is_err());
        c.release(l);
    }

    #[test]
    fn insert_dedups_and_lru_evicts_oldest_unleased() {
        let mut c = PrefixCache::new(cfg(2));
        let (k, v) = row(0.0);
        assert_eq!(c.insert("fp32", &[1, 1], &k, &v), 0);
        assert_eq!(c.insert("fp32", &[1, 1], &k, &v), 0, "duplicate key: no new segment");
        assert_eq!(c.stats().segments, 1);
        assert_eq!(c.insert("fp32", &[2, 2], &k, &v), 0);
        // Touch [1,1] so [2,2] is the LRU victim.
        let l = c.lookup("fp32", &[1, 1]).expect("hit");
        c.release(l);
        assert_eq!(c.insert("fp32", &[3, 3], &k, &v), 1, "one eviction to fit");
        assert!(c.lookup("fp32", &[2, 2]).is_none(), "LRU segment evicted");
        let l = c.lookup("fp32", &[1, 1]).expect("recently-used survives");
        c.release(l);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.stats().resident_bytes <= c.config().budget_bytes);
    }

    #[test]
    fn leased_segments_are_never_evicted() {
        let mut c = PrefixCache::new(cfg(1));
        let (k, v) = row(0.0);
        c.insert("fp32", &[1, 1], &k, &v);
        let lease = c.lookup("fp32", &[1, 1]).expect("hit");
        let id = lease.id();
        // Budget is one row; these inserts each demand an eviction, but the
        // only other resident segment is leased.
        c.insert("fp32", &[2, 2], &k, &v);
        c.insert("fp32", &[3, 3], &k, &v);
        assert!(c.has_segment(id), "leased segment evicted under pressure");
        assert!(
            c.stats().resident_bytes > c.config().budget_bytes,
            "cache should run over budget rather than free a lease"
        );
        // Splice still works mid-pressure.
        let mut dk = Tensor::<f32>::zeros(&DIMS);
        let mut dv = Tensor::<f32>::zeros(&DIMS);
        c.splice(&lease, &mut dk, &mut dv).expect("leased splice");
        c.release(lease);
        // Once released, the next insert can reclaim it.
        c.insert("fp32", &[4, 4], &k, &v);
        assert!(!c.has_segment(id), "released LRU segment reclaimed");
        assert!(c.stats().resident_bytes <= c.config().budget_bytes);
        assert_eq!(c.stats().leases, 0);
    }

    #[test]
    fn oversize_segment_and_disabled_cache_reject_cleanly() {
        let mut c = PrefixCache::new(PrefixCacheConfig {
            enabled: true,
            budget_bytes: ROW_BYTES / 2,
            min_prefix: 2,
        });
        let (k, v) = row(0.0);
        assert_eq!(c.insert("fp32", &[1, 1], &k, &v), 0);
        assert_eq!(c.stats().rejected, 1);
        assert_eq!(c.stats().segments, 0);

        let mut off = PrefixCache::new(PrefixCacheConfig::off());
        assert_eq!(off.insert("fp32", &[1, 1], &k, &v), 0);
        assert!(off.lookup("fp32", &[1, 1]).is_none());
        assert_eq!(off.stats(), PrefixCacheStats::default());
    }

    #[test]
    fn hit_rate_derivation() {
        let s = PrefixCacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(PrefixCacheStats::default().hit_rate(), 0.0);
    }
}

//! Per-class adaptive draft-depth controller: makes speculation *depth* a
//! serving-time decision that survives request boundaries.
//!
//! The paper runs a static `gamma` per run, but speedup is the product of
//! acceptance length and verification cost (Eq. 11/12): drafting past the
//! depth a workload actually accepts buys nothing and still pays verify
//! traffic for the rejected tail. Draft & Verify (PAPERS.md) sets draft
//! length online from acceptance statistics; this controller does that per
//! *request class* (the same task-tag key the fidelity governor uses), so
//! the statistics accumulate across requests and turns instead of being
//! relearned from a constant on every admission:
//!
//! * Every committed step feeds `(drafted, accepted)` for its row's class
//!   into a per-class accepted-per-draft EWMA ([`GammaController::record`]).
//! * At draft time the engine resolves each row's depth cap:
//!   `clamp(round(ewma + headroom), 1, cap)` — deep enough to capture
//!   acceptance streaks, shallow enough to bound wasted verification
//!   ([`GammaController::resolve`], pure like the governor's `resolve`).
//! * A fresh admission seeds its drafter's *intra-request* EWMA from the
//!   class prior ([`GammaController::prior`] →
//!   `Drafter::seed_depth_prior`), so a second turn drafts at the class's
//!   learned depth on its first step instead of the cold-start constant.
//!
//! Invariants (mirrored from the governor, asserted by the unit tests
//! below and the property tests in `rust/tests/prop_coordinator.rs`):
//!
//! 1. `resolve` is bounded: `0` exactly when `cap == 0`, else in
//!    `[1, cap]` for any configuration and any recorded history.
//! 2. An unseen class resolves to the full cap — no evidence, no clamp.
//! 3. Depth recovers when acceptance recovers: the EWMA has no absorbing
//!    floor, so a class throttled during an acceptance collapse climbs
//!    back once `record` sees long accepted prefixes again.
//! 4. The class map is bounded at [`MAX_CLASSES`]; past the cap unseen
//!    tags fold into one shared [`OVERFLOW_CLASS`] that is tracked and
//!    resolved like any other class (same folding rule as the governor).
//! 5. Depth choices never change committed tokens: speculative decoding
//!    is lossless, so the controller moves *cost* (drafted-but-rejected
//!    tokens), never outputs — CI's checksum A/B holds with it on or off.

use std::collections::BTreeMap;

/// Tuning knobs for the depth policy. The constants match the drafter's
/// previous per-request EWMA (`alpha` 0.2, `headroom` +2.0), so a
/// single-class workload behaves like the old path with a longer memory.
#[derive(Debug, Clone)]
pub struct GammaConfig {
    /// Master switch. Disabled: every class resolves to the full cap and
    /// `prior` never seeds a drafter (the static-gamma A/B reference).
    pub enabled: bool,
    /// EWMA smoothing factor for accepted-per-draft.
    pub alpha: f64,
    /// Depth margin past the acceptance level: speculate a little deeper
    /// than the recent accept length to capture streaks.
    pub headroom: f64,
}

impl Default for GammaConfig {
    fn default() -> Self {
        GammaConfig { enabled: true, alpha: 0.2, headroom: 2.0 }
    }
}

impl GammaConfig {
    /// The static-gamma reference configuration.
    pub fn off() -> Self {
        GammaConfig { enabled: false, ..Default::default() }
    }
}

/// Per-class depth bookkeeping.
#[derive(Debug, Clone)]
pub struct ClassGamma {
    /// EWMA of accepted tokens per drafting step.
    pub accept_ewma: f64,
    /// Drafting steps observed (steps with `drafted > 0`).
    pub steps: u64,
    /// Lifetime drafted tokens.
    pub drafted: u64,
    /// Lifetime accepted tokens.
    pub accepted: u64,
}

impl ClassGamma {
    fn fresh(first_accepted: usize) -> Self {
        ClassGamma {
            accept_ewma: first_accepted as f64,
            steps: 0,
            drafted: 0,
            accepted: 0,
        }
    }
}

/// Cap on distinct tracked classes — same bound and folding rule as the
/// governor's: the key is the client-supplied task tag, so past the cap
/// unseen tags share one overflow class instead of growing state forever.
const MAX_CLASSES: usize = 256;
const OVERFLOW_CLASS: &str = "<overflow>";

/// The controller itself: per-class EWMAs keyed like the governor's class
/// map. Owned by the engine; `resolve` runs once per active row per step
/// and `record` once per committed row — both a bounded BTreeMap probe.
pub struct GammaController {
    cfg: GammaConfig,
    classes: BTreeMap<String, ClassGamma>,
}

impl GammaController {
    pub fn new(cfg: GammaConfig) -> Self {
        GammaController { cfg, classes: BTreeMap::new() }
    }

    pub fn cfg(&self) -> &GammaConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The tracked key for `class`: itself while known or while the map has
    /// room, the shared overflow class once the cap is hit.
    fn key<'a>(&self, class: &'a str) -> &'a str {
        if self.classes.contains_key(class) || self.classes.len() < MAX_CLASSES {
            class
        } else {
            OVERFLOW_CLASS
        }
    }

    /// Effective draft depth for one row of `class` under `cap` (the
    /// engine's `gamma_cap` — configured gamma already clamped to the
    /// exported chunk). Pure: planning and drafting of one step agree.
    ///
    /// Returns 0 exactly when `cap == 0` (a row with no KV room drafts
    /// nothing — the same early return that fixes the drafter's
    /// `clamp(1, 0)` panic); otherwise the result is in `[1, cap]`.
    pub fn resolve(&self, class: &str, cap: usize) -> usize {
        if cap == 0 {
            return 0;
        }
        if !self.cfg.enabled {
            return cap;
        }
        match self.classes.get(self.key(class)) {
            Some(st) => {
                let g = (st.accept_ewma + self.cfg.headroom).round() as usize;
                g.clamp(1, cap)
            }
            // No evidence yet: draft at the full cap, like the old path's
            // first request.
            None => cap,
        }
    }

    /// The class's accepted-per-draft prior, for seeding a fresh drafter's
    /// intra-request EWMA at admission. `None` while the class is unseen
    /// (the drafter keeps its cold-start constant) or when disabled.
    pub fn prior(&self, class: &str) -> Option<f64> {
        if !self.cfg.enabled {
            return None;
        }
        self.classes.get(self.key(class)).map(|st| st.accept_ewma)
    }

    /// Record one committed step's outcome for `class`. Steps that drafted
    /// nothing carry no depth evidence and are skipped (mirrors the
    /// drafter's own `observe_outcome` gate). The first observation seeds
    /// the EWMA at its own accepted length instead of decaying from an
    /// arbitrary constant.
    pub fn record(&mut self, class: &str, drafted: usize, accepted: usize) {
        if drafted == 0 {
            return;
        }
        let key = self.key(class).to_string();
        let alpha = self.cfg.alpha;
        let st = self
            .classes
            .entry(key)
            .or_insert_with(|| ClassGamma::fresh(accepted));
        if st.steps > 0 {
            st.accept_ewma = (1.0 - alpha) * st.accept_ewma + alpha * accepted as f64;
        }
        st.steps += 1;
        st.drafted += drafted as u64;
        st.accepted += accepted as u64;
    }

    /// Per-class view for stats endpoints and tests.
    pub fn class(&self, class: &str) -> Option<&ClassGamma> {
        self.classes.get(class)
    }

    pub fn classes(&self) -> impl Iterator<Item = (&String, &ClassGamma)> {
        self.classes.iter()
    }

    /// Lifetime drafting steps across every class.
    pub fn total_steps(&self) -> u64 {
        self.classes.values().map(|c| c.steps).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> GammaController {
        GammaController::new(GammaConfig::default())
    }

    #[test]
    fn disabled_controller_resolves_full_cap_and_never_seeds() {
        let mut g = GammaController::new(GammaConfig::off());
        g.record("c", 5, 0);
        assert_eq!(g.resolve("c", 8), 8);
        assert_eq!(g.resolve("c", 0), 0);
        assert_eq!(g.prior("c"), None);
    }

    #[test]
    fn unseen_class_resolves_full_cap() {
        let g = ctl();
        assert_eq!(g.resolve("never-seen", 5), 5);
        assert_eq!(g.prior("never-seen"), None);
    }

    #[test]
    fn zero_cap_resolves_zero_for_any_state() {
        let mut g = ctl();
        g.record("c", 8, 8);
        assert_eq!(g.resolve("c", 0), 0, "cap 0 must not clamp(1, 0)");
        assert_eq!(g.resolve("unseen", 0), 0);
    }

    #[test]
    fn collapse_shrinks_and_recovery_restores_depth() {
        let mut g = ctl();
        for _ in 0..20 {
            g.record("c", 8, 8);
        }
        assert_eq!(g.resolve("c", 8), 8, "healthy class drafts deep");
        for _ in 0..40 {
            g.record("c", 8, 0); // acceptance collapse
        }
        assert_eq!(g.resolve("c", 8), 2, "floor at ewma~0 + headroom");
        for _ in 0..40 {
            g.record("c", 8, 8);
        }
        assert!(g.resolve("c", 8) >= 7, "depth recovers with acceptance");
    }

    #[test]
    fn first_observation_seeds_ewma_at_its_own_accept_length() {
        let mut g = ctl();
        g.record("c", 6, 6);
        assert_eq!(g.prior("c"), Some(6.0));
        assert_eq!(g.resolve("c", 10), 8, "6 + headroom 2");
    }

    #[test]
    fn zero_draft_steps_carry_no_evidence() {
        let mut g = ctl();
        g.record("c", 0, 0);
        assert!(g.class("c").is_none(), "draft misses must not seed a class");
    }

    #[test]
    fn resolve_is_bounded_for_any_config_and_history() {
        for &(alpha, headroom) in
            &[(0.0, 0.0), (1.0, 100.0), (0.2, 2.0), (0.5, -3.0), (0.9, 1e9)]
        {
            let mut g = GammaController::new(GammaConfig { enabled: true, alpha, headroom });
            for i in 0..50usize {
                g.record("c", 1 + i % 9, i % 10);
                for cap in 0..10 {
                    let r = g.resolve("c", cap);
                    if cap == 0 {
                        assert_eq!(r, 0);
                    } else {
                        assert!(
                            (1..=cap).contains(&r),
                            "resolve out of bounds: {r} for cap {cap} (a={alpha}, h={headroom})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn class_map_is_bounded_and_overflow_tags_still_resolve() {
        let mut g = ctl();
        for i in 0..MAX_CLASSES + 50 {
            g.record(&format!("class-{i}"), 8, 8);
        }
        assert!(
            g.classes().count() <= MAX_CLASSES + 1,
            "class map must stay bounded, got {}",
            g.classes().count()
        );
        assert!(g.class(OVERFLOW_CLASS).is_some(), "excess tags fold into overflow");
        // Overflow is governed like any other class: collapse recorded by
        // one untracked tag throttles every other untracked tag.
        for _ in 0..40 {
            g.record("some-novel-tag", 8, 0);
        }
        assert_eq!(g.resolve("a-different-novel-tag", 8), 2);
        assert_eq!(g.resolve("class-0", 8), 8, "tracked classes unaffected");
    }
}

//! Layer-3 coordinator: the paper's serving-system contribution. Continuous
//! batching over leased KV rows (`kv`), per-request speculative state
//! (`request`), policy-ordered admission with deadlines and cancellation
//! (`scheduler`), paged shared-prefix KV reuse for suffix-only prefill —
//! page-granular sharing, mid-stream snapshots, boot warm-up
//! (`prefixcache`), cost-guided elastic step planning (`plan`), the
//! adaptive-precision fidelity governor (`governor`), the per-class
//! adaptive draft-depth controller (`gamma`), the decode loop
//! (`engine`), call accounting for the cost model (`calls`), the threaded
//! front door with correlated completion routing (`router`), and the
//! replica-fleet dispatch plane — locality-hashing dispatch with
//! work-stealing spillover over N engine replicas (`cluster`).

pub mod calls;
pub mod cluster;
pub mod engine;
pub mod gamma;
pub mod governor;
pub mod kv;
pub mod plan;
pub mod prefixcache;
pub mod request;
pub mod router;
pub mod scheduler;

pub use calls::{CallLog, CallRecord, FnKind};
pub use cluster::{aggregate, build_ring, dispatch_decision, replica_of_id, ring_assign,
                  ClusterConfig, ClusterHandle, ClusterSnapshot, DispatchInfo,
                  DispatchPolicy, DispatchSnapshot};
pub use engine::{DrafterKind, Engine, EngineConfig};
pub use gamma::{ClassGamma, GammaConfig, GammaController};
pub use governor::{Governor, GovernorConfig, Route, Transition};
pub use kv::{BatchGroup, PagedGroup, RowStore};
pub use plan::{best_bucket, pack_prefill_riders, plan_step, PlanCtx, PlanRow, PrefillPending,
               PrefillRider, StepPlan, SubBatch, VariantCtx};
pub use prefixcache::{Lease, LocalityIndex, PrefixCache, PrefixCacheConfig, PrefixCacheStats};
pub use request::{Completion, FinishReason, GenParams, PrefillProgress, Priority, Request,
                  RequestState, StageBreakdown};
pub use router::{BucketStat, ConfigEcho, EngineHandle, GammaClassStat, GovernorSnapshot,
                 KvSnapshot, PrefillSnapshot, PrefixSnapshot, RouterStats, StatsSnapshot,
                 Ticket, VariantCalls};
pub use scheduler::{SchedPolicy, Scheduler};

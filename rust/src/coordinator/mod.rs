//! Layer-3 coordinator: the paper's serving-system contribution. Continuous
//! batching over leased KV rows (`kv`), per-request speculative state
//! (`request`), the decode loop (`engine`), call accounting for the cost
//! model (`calls`) and the threaded front door (`router`).

pub mod calls;
pub mod engine;
pub mod kv;
pub mod request;
pub mod router;

pub use calls::{CallLog, CallRecord, FnKind};
pub use engine::{DrafterKind, Engine, EngineConfig};
pub use kv::BatchGroup;
pub use request::{Completion, FinishReason, GenParams, Request, RequestState};
pub use router::EngineHandle;

//! Elastic step planning: partition the active rows of a step into
//! sub-batches and pick, per sub-batch, the cheapest exported **(batch
//! bucket, verifier variant)** pair — so low-occupancy groups stop reading
//! idle KV rows, decode-only rows stop paying full verify-chunk traffic, and
//! each request class verifies at the precision the fidelity governor
//! resolved for it (paper Eq. 11/12: verification cost is memory traffic,
//! and traffic scales with both the bucket actually executed and the bytes
//! per weight of the variant actually streamed).
//!
//! One [`StepPlan`] is built per engine step from the per-row
//! [`PlanRow`]s (draft length + resolved variant) and executed as a
//! gather → run_chunk → scatter pipeline per sub-batch (see
//! `coordinator::kv` for the row movement, `coordinator::engine` for the
//! driver and `coordinator::governor` for how a row's variant is chosen).
//! A row's draft length is itself class-resolved upstream: with
//! `adaptive_gamma` on, the engine clamps each row's drafter to the depth
//! `coordinator::gamma` resolved for its request class, so the draft
//! lengths the planner packs — and the `tokens_used` each priced call
//! executes — already reflect per-class acceptance history rather than the
//! static configured gamma. The planner stays policy-free either way: like
//! variant assignment, depth is decided before planning; the planner only
//! prices and packs what it is handed.
//!
//! ## Bucket/variant-selection invariants
//!
//! * A sub-batch is **variant-homogeneous**: one chunk call streams one
//!   variant's weights, so rows resolved to different variants never share a
//!   call. The planner does not second-guess the governor — variant
//!   assignment is a fidelity decision, the planner only prices and packs
//!   within it.
//! * A sub-batch's bucket is the **smallest bucket its variant exports that
//!   fits its rows**; when every bucket is smaller than the group, the group
//!   splits across multiple sub-batches of the largest bucket (never
//!   silently truncated, never a bucket the manifest doesn't export).
//! * Every active row lands in **exactly one** sub-batch of the chosen plan.
//! * A sub-batch is function-homogeneous in what it *executes*: it runs one
//!   exported fn (`verify` or `decode`). Decode-only rows may ride along in
//!   a same-variant verify sub-batch's spare rows — that call's weight
//!   stream is already paid, so the ride is free in the cost model — but a
//!   `decode` sub-batch never contains a drafting row.
//! * Per variant group, between the candidate shapes (monolithic configured
//!   bucket, shrunk single call, split by function) the planner commits to
//!   the one with the lowest [`PerfModel::plan_cost`] at that group's
//!   variant; ties prefer fewer calls, and a shape whose bucket the variant
//!   does not export is never committed to. When the configured bucket is
//!   exported (the normal case) the chosen cost is monotonically <= the
//!   monolithic cost — summed over groups, `modeled_s <= monolithic_s`, and
//!   the gap is surfaced as the `planned_savings_s` metric.
//! * Planning is deterministic: variant groups are planned in variant-index
//!   order and rows within a group are ordered longest-draft-first (ties by
//!   row index), so a split group packs similar draft lengths together and
//!   per-sub-batch `tokens_used` maxima stay small.
//!
//! ## Rider-packing invariants (prefill chunks riding decode steps)
//!
//! After the plan is chosen, [`pack_prefill_riders`] fills remaining spare
//! capacity with pending admission-prefill chunks (see
//! `coordinator::engine`'s resumable admission state machine). The packing
//! obeys:
//!
//! * **Same variant** — a chunk only rides a sub-batch streaming the
//!   variant its admission resolved to, mirroring the decode-rider rule
//!   (and the prefix cache's one-variant-per-run bit-identity contract).
//! * **Bucket cost never grows** — a rider occupies a spare row the chosen
//!   bucket already pays KV/activation traffic for, and consumes at most
//!   `sb.chunk` positions (`take <= chunk`), so the sub-batch's priced
//!   shape `(bucket, tokens_used)` can only grow in `tokens_used` up to
//!   the chunk the call executes anyway. The plan's bucket choice is never
//!   revisited for a rider.
//! * **At most one chunk per pending row per step** — a prefilling row
//!   advances by one chunk per planned pass, keeping the step a single
//!   plan → gather → execute → scatter pipeline.
//! * **Stall fallback** — a pending row that finds no same-variant spare
//!   slot gets a *dedicated* single-row `FnKind::Prefill` sub-batch (the
//!   monolithic admission shape, `rows` empty, the chunk described by its
//!   one rider). Those are the steps the `decode_stall_steps` counter
//!   tallies when decode rows were active; riding chunks book the avoided
//!   dedicated-call price to `prefill_stall_saved_s` instead.
//! * **Load-adaptive chunk shrink** — under a deep admission queue
//!   (`shed_load`) a dedicated call drops from the full prefill window to
//!   the exported single-row verify program (`FnKind::Verify`, bucket 1,
//!   `ctx.verify_chunk` positions) when the variant exports one: slower
//!   ingest for that row, but the step's priced bound shrinks by the
//!   window/verify-chunk ratio, smoothing live rows' TPOT while the queue
//!   drains (counted by `prefill_shed_chunks`).
//! * Riders never change committed-row semantics: `SubBatch::rows` is
//!   still exactly the decode/verify rows, and every consumer of the plan
//!   (governor audits, commit loop) iterates `rows` untouched.

use anyhow::{bail, Result};

use crate::perfmodel::PerfModel;

use super::calls::FnKind;

/// Exported bucket lists for one verifier weight variant the step may
/// execute (from the manifest via `ModelEntry::buckets`, sorted ascending).
pub struct VariantCtx<'a> {
    pub name: &'a str,
    pub verify_buckets: &'a [usize],
    pub decode_buckets: &'a [usize],
}

/// Everything the planner needs about the engine's configuration, borrowed
/// for one `plan_step` call.
pub struct PlanCtx<'a> {
    pub perf: &'a PerfModel,
    /// Verifier variants this step may execute; [`SubBatch::variant`] and
    /// [`PlanRow::variant`] index into this list. Entry 0 is the engine's
    /// primary (configured) variant; entry 1, when present, the fidelity
    /// governor's reference variant.
    pub variants: &'a [VariantCtx<'a>],
    pub n_layers: usize,
    /// The engine's configured construction-time bucket (the monolithic
    /// fallback shape; seed behavior).
    pub full_bucket: usize,
    /// Positions per row of the exported verify chunk (`gamma_max + 1`).
    pub verify_chunk: usize,
    /// `false` forces the monolithic plan at `full_bucket` — one call per
    /// variant group (bit-compatible with the pre-planner engine when a
    /// single variant is in play; used by equivalence tests and A/B
    /// benches).
    pub elastic: bool,
}

/// One active row's planning input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanRow {
    /// Tokens the row's drafter proposed this step (0 = decode-only).
    pub draft_len: usize,
    /// Index into [`PlanCtx::variants`] of the verifier variant this row's
    /// request class resolved to (the fidelity governor's decision).
    pub variant: usize,
}

impl PlanRow {
    pub fn new(draft_len: usize, variant: usize) -> Self {
        PlanRow { draft_len, variant }
    }
}

/// One chunk execution of a step: which rows run, through which exported
/// (variant, fn, bucket), and the token accounting the call log records for
/// it.
#[derive(Debug, Clone, PartialEq)]
pub struct SubBatch {
    pub fn_kind: FnKind,
    /// Index into [`PlanCtx::variants`] of the weight variant this call
    /// streams.
    pub variant: usize,
    /// Exported batch bucket to execute at (scratch-cache shape).
    pub bucket: usize,
    /// Positions the artifact executes per row (1 for decode, the verify
    /// chunk otherwise).
    pub chunk: usize,
    /// Indices into the step's row list; scratch row `i` carries `rows[i]`.
    pub rows: Vec<usize>,
    /// Pending-admission prefill chunks filling spare rows after `rows`
    /// (scratch row `rows.len() + j` carries `riders[j]`); empty until
    /// [`pack_prefill_riders`] runs. A dedicated prefill sub-batch has
    /// empty `rows` and exactly one rider.
    pub riders: Vec<PrefillRider>,
    /// `1 + longest draft` among `rows` (what the cost model prices).
    pub tokens_used: usize,
    /// Sum over `rows` of `1 + draft len` (chunk-efficiency numerator);
    /// rider takes are added when they pack.
    pub useful_tokens: usize,
}

impl SubBatch {
    fn new(fn_kind: FnKind, variant: usize, bucket: usize, chunk: usize,
           rows: Vec<usize>, draft_lens: &[usize]) -> Self {
        debug_assert!(!rows.is_empty());
        let tokens_used = rows.iter().map(|&i| draft_lens[i] + 1).max().unwrap_or(1);
        let useful_tokens = rows.iter().map(|&i| draft_lens[i] + 1).sum();
        SubBatch {
            fn_kind, variant, bucket, chunk, rows, riders: Vec::new(),
            tokens_used, useful_tokens,
        }
    }

    /// Free capacity left in the selected bucket (riders occupy slots too).
    pub fn spare(&self) -> usize {
        self.bucket.saturating_sub(self.rows.len() + self.riders.len())
    }
}

/// One pending admission-prefill chunk packed into a sub-batch's spare
/// capacity (or into a dedicated prefill sub-batch when nothing had room).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillRider {
    /// Index into the `pending` list handed to [`pack_prefill_riders`].
    pub pending: usize,
    /// Suffix tokens this chunk consumes (`<= sb.chunk` when riding).
    pub take: usize,
    /// Modeled seconds of dedicated-prefill stall the ride avoided
    /// ([`PerfModel::prefill_stall_saved_s`]); `0.0` for a dedicated
    /// sub-batch — nothing was avoided, the stall happened.
    pub saved_s: f64,
}

/// One partially-prefilled row awaiting its next chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillPending {
    /// Prompt-suffix tokens still to prefill (`> 0`).
    pub remaining: usize,
    /// Index into [`PlanCtx::variants`] of the row's admission variant.
    pub variant: usize,
}

/// Fill the chosen plan's spare capacity with pending prefill chunks (see
/// the module doc's rider-packing invariants). Every pending row advances
/// by exactly one chunk: riding a same-variant sub-batch's spare slot when
/// one exists, otherwise as a dedicated single-row sub-batch appended to
/// the plan. Dedicated calls are priced into both `modeled_s` and
/// `monolithic_s` (the stall costs the same in either shape, so the
/// planner-savings invariant is unchanged).
///
/// `shed_load` is the load-adaptive chunk-size switch: chunk shapes must
/// match an exported program exactly, so the only sizes a dedicated call
/// can run at are the full prefill window (`FnKind::Prefill`, bucket 1)
/// and the much shorter single-row verify chunk (`ctx.verify_chunk`,
/// available whenever the variant exports a b1 verify program — the same
/// program whose KV bytes mid-stream snapshots already rely on matching
/// prefill's). Under shed, a dedicated chunk takes the verify shape:
/// admission ingests fewer positions per step, but the step's priced time
/// bound drops by the window/verify-chunk ratio, smoothing live rows'
/// TPOT while a deep queue drains.
pub fn pack_prefill_riders(ctx: &PlanCtx, plan: &mut StepPlan,
                           pending: &[PrefillPending], prefill_chunk: usize,
                           shed_load: bool) {
    for (pi, p) in pending.iter().enumerate() {
        debug_assert!(p.remaining > 0);
        let slot = plan.sub_batches.iter_mut().find(|sb| {
            sb.fn_kind != FnKind::Prefill && sb.variant == p.variant && sb.spare() > 0
        });
        if let Some(sb) = slot {
            let take = p.remaining.min(sb.chunk);
            let saved_s = ctx.perf.prefill_stall_saved_s(
                ctx.variants[p.variant].name, ctx.n_layers, take,
            );
            sb.riders.push(PrefillRider { pending: pi, take, saved_s });
            sb.useful_tokens += take;
            // The call executes `chunk` positions either way; the rider can
            // only raise the *priced* token count up to that ceiling.
            sb.tokens_used = sb.tokens_used.max(take);
        } else {
            let shed = shed_load
                && ctx.verify_chunk < prefill_chunk
                && ctx.variants[p.variant].verify_buckets.contains(&1);
            let (fn_kind, chunk) = if shed {
                (FnKind::Verify, ctx.verify_chunk)
            } else {
                (FnKind::Prefill, prefill_chunk)
            };
            let take = p.remaining.min(chunk);
            let cost = ctx
                .perf
                .price_parts(ctx.variants[p.variant].name, ctx.n_layers, 1, take)
                .total();
            plan.sub_batches.push(SubBatch {
                fn_kind,
                variant: p.variant,
                bucket: 1,
                chunk,
                rows: Vec::new(),
                riders: vec![PrefillRider { pending: pi, take, saved_s: 0.0 }],
                tokens_used: take,
                useful_tokens: take,
            });
            plan.modeled_s += cost;
            plan.monolithic_s += cost;
        }
    }
}

/// The committed plan for one step, with the modeled cost of what was chosen
/// and of the monolithic shape it replaced (their gap is the planner's win).
#[derive(Debug, Clone)]
pub struct StepPlan {
    pub sub_batches: Vec<SubBatch>,
    /// `PerfModel::plan_cost` of the chosen sub-batches (seconds).
    pub modeled_s: f64,
    /// Cost of the monolithic configured-bucket shape (one call per variant
    /// group), clamped to at least `modeled_s` for any group whose variant
    /// does not export the configured bucket — so `modeled_s <=
    /// monolithic_s` (and the planner-savings metric's >= 0 guarantee)
    /// holds unconditionally.
    pub monolithic_s: f64,
}

/// Smallest bucket (ascending list) that fits `n` rows; the largest
/// available when none fits (the caller then splits); `None` when the list
/// is empty.
pub fn best_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .or_else(|| buckets.last().copied())
}

/// Pack one (variant, function)-homogeneous group of rows into sub-batches,
/// splitting over the largest bucket when the group is oversize. `idxs`
/// index into `draft_lens`.
fn pack(fn_kind: FnKind, variant: usize, chunk: usize, mut idxs: Vec<usize>,
        draft_lens: &[usize], buckets: &[usize]) -> Result<Vec<SubBatch>> {
    if idxs.is_empty() {
        return Ok(Vec::new());
    }
    if buckets.is_empty() {
        bail!("no '{}' buckets exported for this variant", fn_kind.name());
    }
    // Longest drafts first (ties by index): when the group must split,
    // similar-length work shares a call and per-call tokens_used stays low.
    idxs.sort_by_key(|&i| (std::cmp::Reverse(draft_lens[i]), i));
    let mut out = Vec::new();
    let mut start = 0;
    while start < idxs.len() {
        let left = idxs.len() - start;
        let bucket = best_bucket(buckets, left).expect("non-empty bucket list");
        let take = left.min(bucket);
        out.push(SubBatch::new(
            fn_kind, variant, bucket, chunk, idxs[start..start + take].to_vec(), draft_lens,
        ));
        start += take;
    }
    Ok(out)
}

fn plan_cost(ctx: &PlanCtx, sbs: &[SubBatch]) -> f64 {
    sbs.iter()
        .map(|sb| {
            ctx.perf.plan_cost(
                ctx.variants[sb.variant].name,
                ctx.n_layers,
                &[(sb.bucket, sb.tokens_used)],
            )
        })
        .sum()
}

/// Plan one variant group (`idxs` all resolved to `ctx.variants[vi]`).
/// Returns the chosen sub-batches plus (chosen, monolithic) modeled costs.
fn plan_group(ctx: &PlanCtx, vi: usize, idxs: Vec<usize>,
              draft_lens: &[usize]) -> Result<(Vec<SubBatch>, f64, f64)> {
    let v = &ctx.variants[vi];
    let any_draft = idxs.iter().any(|&i| draft_lens[i] > 0);

    // The single-call function: verify when anything drafted; decode when
    // nothing did (falling back to verify if decode isn't exported).
    let (mono_fn, mono_chunk, mono_buckets) = if any_draft || v.decode_buckets.is_empty() {
        (FnKind::Verify, ctx.verify_chunk, v.verify_buckets)
    } else {
        (FnKind::Decode, 1usize, v.decode_buckets)
    };

    // Monolithic shape: the fixed construction-time bucket, one call.
    let mono = vec![SubBatch::new(
        mono_fn, vi, ctx.full_bucket, mono_chunk, idxs.clone(), draft_lens,
    )];
    let mono_cost = plan_cost(ctx, &mono);
    if !ctx.elastic {
        if mono_buckets.contains(&ctx.full_bucket) {
            return Ok((mono, mono_cost, mono_cost));
        }
        // The configured bucket isn't exported for this variant (e.g. a
        // governed group demoted to a reference with a different bucket
        // set): even in monolithic mode, never commit an unexecutable
        // shape — pack over the variant's own buckets instead. The
        // monolithic baseline is clamped up to the packed cost so the
        // `modeled_s <= monolithic_s` invariant (and the derived
        // planned-savings metric's >= 0 guarantee) holds even when packing
        // an unexecutable baseline costs more than its fiction would have.
        let packed = pack(mono_fn, vi, mono_chunk, idxs, draft_lens, mono_buckets)?;
        let packed_cost = plan_cost(ctx, &packed);
        return Ok((packed, packed_cost, mono_cost.max(packed_cost)));
    }

    // Candidate 1 — shrink: same single-function grouping, smallest
    // exported bucket that fits the occupancy.
    let shrunk = pack(mono_fn, vi, mono_chunk, idxs.clone(), draft_lens, mono_buckets)?;

    // Candidate 2 — split by required function: drafting rows verify,
    // decode-only rows first ride along in spare verify capacity (that
    // weight stream is already paid), the remainder runs as 1-token decode
    // sub-batches that skip the verify chunk's padding traffic entirely.
    let split = if any_draft
        && idxs.iter().any(|&i| draft_lens[i] == 0)
        && !v.decode_buckets.is_empty()
    {
        let verify_idx: Vec<usize> =
            idxs.iter().copied().filter(|&i| draft_lens[i] > 0).collect();
        let decode_idx: Vec<usize> =
            idxs.iter().copied().filter(|&i| draft_lens[i] == 0).collect();
        let mut sbs = pack(
            FnKind::Verify, vi, ctx.verify_chunk, verify_idx, draft_lens, v.verify_buckets,
        )?;
        let mut decode_iter = decode_idx.into_iter();
        'fill: for sb in sbs.iter_mut() {
            while sb.spare() > 0 {
                match decode_iter.next() {
                    Some(i) => {
                        sb.rows.push(i);
                        sb.useful_tokens += 1; // a decode row uses 1 position
                    }
                    None => break 'fill,
                }
            }
        }
        let leftover: Vec<usize> = decode_iter.collect();
        sbs.extend(pack(FnKind::Decode, vi, 1, leftover, draft_lens, v.decode_buckets)?);
        Some(sbs)
    } else {
        None
    };

    // Commit to the cheapest candidate; ties prefer the earlier (fewer
    // calls / closer to monolithic) shape.
    let mut best = shrunk;
    let mut best_cost = plan_cost(ctx, &best);
    if let Some(split) = split {
        let c = plan_cost(ctx, &split);
        if c < best_cost {
            best = split;
            best_cost = c;
        }
    }
    if mono_cost < best_cost && mono_buckets.contains(&ctx.full_bucket) {
        // Only reachable when the manifest exports full_bucket but shrink
        // picked a larger-than-configured bucket (never happens when
        // full_bucket is in the list, since shrink is monotone) — kept as a
        // guard. A full_bucket the variant does NOT export prices cheaper
        // here too, but committing to it would fail at run_chunk, so an
        // executable candidate always wins over an unexecutable one.
        best = mono;
        best_cost = mono_cost;
    }
    // Same clamp as the elastic=false path: when the monolithic shape is
    // not executable for this variant, it can price below what the
    // exported buckets allow — report the baseline as at least the chosen
    // cost so savings never go negative.
    let mono_baseline = if mono_buckets.contains(&ctx.full_bucket) {
        mono_cost
    } else {
        mono_cost.max(best_cost)
    };
    Ok((best, best_cost, mono_baseline))
}

/// Build the step plan for the given per-row inputs (one entry per active
/// row, in group-row order).
pub fn plan_step(ctx: &PlanCtx, rows: &[PlanRow]) -> Result<StepPlan> {
    if rows.is_empty() {
        bail!("plan_step on an empty step");
    }
    if ctx.variants.is_empty() {
        bail!("plan_step with no variants");
    }
    if let Some(bad) = rows.iter().find(|r| r.variant >= ctx.variants.len()) {
        bail!(
            "row variant index {} out of range ({} variants)",
            bad.variant, ctx.variants.len()
        );
    }
    let draft_lens: Vec<usize> = rows.iter().map(|r| r.draft_len).collect();

    // Plan each variant group independently (costs are additive and groups
    // are disjoint, so per-group optimization is globally optimal), in
    // variant-index order for determinism.
    let mut sub_batches = Vec::new();
    let (mut modeled_s, mut monolithic_s) = (0.0, 0.0);
    for vi in 0..ctx.variants.len() {
        let idxs: Vec<usize> =
            (0..rows.len()).filter(|&i| rows[i].variant == vi).collect();
        if idxs.is_empty() {
            continue;
        }
        let (sbs, chosen, mono) = plan_group(ctx, vi, idxs, &draft_lens)?;
        sub_batches.extend(sbs);
        modeled_s += chosen;
        monolithic_s += mono;
    }
    Ok(StepPlan { sub_batches, modeled_s, monolithic_s })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{CostModelCfg, ModelCfg};
    use std::collections::BTreeMap;

    fn device(bf16_ops: f64, launch_s: f64) -> CostModelCfg {
        CostModelCfg {
            device: "sim".into(),
            hbm_bw_bytes_per_s: 1.6e12,
            int8_ops_per_s: 2.0 * bf16_ops,
            bf16_ops_per_s: bf16_ops,
            bytes_per_weight: BTreeMap::from([
                ("fp32".to_string(), 2.0),
                ("w8a8".to_string(), 1.0),
            ]),
            kernel_launch_s: launch_s,
            drafter_cost_per_token_s: 1e-6,
        }
    }

    fn small_model() -> ModelCfg {
        ModelCfg {
            name: "m".into(), vocab_size: 64, d_model: 32, n_layers: 2,
            n_heads: 8, ffn_dim: 64, max_seq: 4096, prefill_len: 128,
            gamma_max: 8, head_dim: 64,
        }
    }

    /// Tiny weights, long resident sequence, memory-bound device: shrinking
    /// the bucket (fewer idle KV rows read) is the dominant lever.
    fn kv_heavy() -> PerfModel {
        PerfModel::new(device(188e12, 2e-5), small_model())
    }

    /// Same model on a compute-starved device with cheap launches: the
    /// padded verify-chunk attention over the long sequence dominates, so
    /// splitting decode-only rows out of the verify chunk pays for the
    /// extra call.
    fn pad_heavy() -> PerfModel {
        PerfModel::new(device(1e12, 1e-9), small_model())
    }

    /// Big dense layers, short sequence — every extra call re-streams the
    /// weights, so one call wins.
    fn weight_heavy() -> PerfModel {
        let model = ModelCfg {
            name: "m".into(), vocab_size: 32000, d_model: 4096, n_layers: 32,
            n_heads: 8, ffn_dim: 11008, max_seq: 64, prefill_len: 32,
            gamma_max: 8, head_dim: 16,
        };
        PerfModel::new(device(188e12, 2e-5), model)
    }

    fn vctx<'a>(buckets: &'a [usize]) -> Vec<VariantCtx<'a>> {
        vec![VariantCtx { name: "fp32", verify_buckets: buckets, decode_buckets: buckets }]
    }

    fn ctx<'a>(perf: &'a PerfModel, variants: &'a [VariantCtx<'a>], full: usize,
               elastic: bool) -> PlanCtx<'a> {
        PlanCtx {
            perf,
            variants,
            n_layers: perf.model.n_layers,
            full_bucket: full,
            verify_chunk: 9,
            elastic,
        }
    }

    fn prows(lens: &[usize]) -> Vec<PlanRow> {
        lens.iter().map(|&l| PlanRow::new(l, 0)).collect()
    }

    fn rows_of(plan: &StepPlan) -> Vec<usize> {
        let mut r: Vec<usize> =
            plan.sub_batches.iter().flat_map(|sb| sb.rows.clone()).collect();
        r.sort_unstable();
        r
    }

    #[test]
    fn best_bucket_edge_cases() {
        assert_eq!(best_bucket(&[], 1), None, "no bucket large enough (none at all)");
        assert_eq!(best_bucket(&[1, 2, 4], 2), Some(2), "exact fit");
        assert_eq!(best_bucket(&[1, 2, 4], 3), Some(4), "next bucket up");
        assert_eq!(best_bucket(&[1, 2, 4], 9), Some(4), "oversize group takes largest");
        assert_eq!(best_bucket(&[4], 1), Some(4), "only a big bucket exported");
    }

    #[test]
    fn oversize_group_splits_across_largest_bucket() {
        let sbs =
            pack(FnKind::Verify, 0, 9, (0..10).collect(), &[1usize; 10], &[1, 2, 4]).unwrap();
        assert_eq!(sbs.len(), 3, "10 rows over b4 -> 4+4+2");
        assert_eq!(sbs[0].rows.len(), 4);
        assert_eq!(sbs[1].rows.len(), 4);
        assert_eq!(sbs[2].rows.len(), 2);
        assert_eq!(sbs[2].bucket, 2, "tail picks the smallest fit");
        let mut all: Vec<usize> = sbs.iter().flat_map(|s| s.rows.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>(), "no row lost or duplicated");
    }

    #[test]
    fn packing_groups_similar_draft_lengths() {
        // 4 rows over b2 buckets: the two long drafts share a call so the
        // short call's tokens_used stays at 2, not 6.
        let sbs = pack(FnKind::Verify, 0, 9, vec![0, 1, 2, 3], &[5, 1, 5, 1], &[2]).unwrap();
        assert_eq!(sbs.len(), 2);
        assert_eq!(sbs[0].rows, vec![0, 2]);
        assert_eq!(sbs[0].tokens_used, 6);
        assert_eq!(sbs[1].rows, vec![1, 3]);
        assert_eq!(sbs[1].tokens_used, 2);
    }

    #[test]
    fn empty_bucket_list_errors_and_elastic_false_is_monolithic() {
        let perf = kv_heavy();
        let buckets = [1usize, 4];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, false);
        let plan = plan_step(&c, &prows(&[3, 0, 0])).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].bucket, 4, "configured bucket, seed behavior");
        assert_eq!(plan.modeled_s, plan.monolithic_s);

        let none: [usize; 0] = [];
        let vs_none =
            vec![VariantCtx { name: "fp32", verify_buckets: &none, decode_buckets: &none }];
        let c = ctx(&perf, &vs_none, 4, true);
        assert!(plan_step(&c, &prows(&[3])).is_err(), "drafting with no verify buckets");
        assert!(plan_step(&c, &prows(&[])).is_err(), "empty step");
        let c = ctx(&perf, &vs, 4, true);
        assert!(
            plan_step(&c, &[PlanRow::new(3, 1)]).is_err(),
            "row variant index out of range"
        );
    }

    #[test]
    fn monolithic_mode_never_commits_an_unexported_bucket() {
        // elastic=false with a configured bucket the variant doesn't export
        // (reachable when a governed group demotes to a reference with a
        // different bucket set): the plan must pack over the variant's own
        // buckets instead of committing a call run_chunk would reject.
        let perf = kv_heavy();
        let buckets = [1usize, 2];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, false);
        let plan = plan_step(&c, &prows(&[3, 0, 0])).unwrap();
        assert!(
            plan.sub_batches.iter().all(|sb| buckets.contains(&sb.bucket)),
            "unexported bucket committed: {plan:?}"
        );
        assert_eq!(rows_of(&plan), vec![0, 1, 2]);
    }

    #[test]
    fn occupancy_one_shrinks_to_the_small_bucket() {
        for perf in [kv_heavy(), weight_heavy()] {
            let buckets = [1usize, 4];
            let vs = vctx(&buckets);
            let c = ctx(&perf, &vs, 4, true);
            let plan = plan_step(&c, &prows(&[3])).unwrap();
            assert_eq!(plan.sub_batches.len(), 1);
            assert_eq!(plan.sub_batches[0].bucket, 1, "1 row never reads 4 rows of KV");
            assert_eq!(plan.sub_batches[0].fn_kind, FnKind::Verify);
            assert!(plan.modeled_s < plan.monolithic_s);
        }
    }

    #[test]
    fn all_decode_rows_use_the_decode_function() {
        let perf = kv_heavy();
        let buckets = [1usize, 4];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, true);
        let plan = plan_step(&c, &prows(&[0, 0])).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].fn_kind, FnKind::Decode);
        assert_eq!(plan.sub_batches[0].chunk, 1);
        assert_eq!(plan.sub_batches[0].bucket, 4, "2 rows need the b4 bucket here");
        // the monolithic shape is already a decode call at b4 (seed
        // behavior), so shrink cannot improve on it here
        assert_eq!(plan.modeled_s, plan.monolithic_s);
    }

    #[test]
    fn decode_rows_ride_spare_verify_capacity_for_free() {
        // 1 verify + 1 decode row with buckets {2,4}: the verify call runs
        // at b2 with a spare row, so the decode row rides along — one call.
        let perf = weight_heavy();
        let buckets = [2usize, 4];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, true);
        let plan = plan_step(&c, &prows(&[4, 0])).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        let sb = &plan.sub_batches[0];
        assert_eq!(sb.fn_kind, FnKind::Verify);
        assert_eq!(sb.bucket, 2);
        assert_eq!(rows_of(&plan), vec![0, 1]);
        assert_eq!(sb.tokens_used, 5, "decode rider doesn't raise the max");
        assert_eq!(sb.useful_tokens, 6, "5 verify positions + 1 decode position");
        assert!(plan.modeled_s < plan.monolithic_s);
    }

    #[test]
    fn mixed_step_splits_when_padding_is_dear_and_stays_single_when_weights_are() {
        let buckets = [1usize, 2, 4];
        let lens = [6usize, 0, 0, 0]; // 1 drafting row drags 3 decode rows

        let pad = pad_heavy();
        let vs = vctx(&buckets);
        let c = ctx(&pad, &vs, 4, true);
        let plan = plan_step(&c, &prows(&lens)).unwrap();
        assert!(plan.sub_batches.len() > 1, "pad-heavy: split {plan:?}");
        assert!(plan.sub_batches.iter().any(|sb| sb.bucket < 4));
        assert!(plan.sub_batches.iter().any(|sb| sb.fn_kind == FnKind::Decode));
        assert!(
            plan.sub_batches
                .iter()
                .filter(|sb| sb.fn_kind == FnKind::Decode)
                .all(|sb| sb.rows.iter().all(|&i| lens[i] == 0)),
            "a decode sub-batch never contains a drafting row"
        );
        assert_eq!(rows_of(&plan), vec![0, 1, 2, 3]);
        assert!(plan.modeled_s < plan.monolithic_s);

        let wh = weight_heavy();
        let vs = vctx(&buckets);
        let c = ctx(&wh, &vs, 4, true);
        let plan = plan_step(&c, &prows(&lens)).unwrap();
        assert_eq!(
            plan.sub_batches.len(), 1,
            "weight-heavy: an extra call re-streams the weights, keep one"
        );
        assert_eq!(rows_of(&plan), vec![0, 1, 2, 3]);
        assert!(plan.modeled_s <= plan.monolithic_s);
    }

    #[test]
    fn unexported_configured_bucket_never_wins_the_plan() {
        // Engine configured at b1 but verify only exported at b4: the
        // monolithic b1 shape prices cheapest yet cannot execute — the
        // planner must commit to the exported bucket instead.
        let perf = kv_heavy();
        let buckets = [4usize];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 1, true);
        let plan = plan_step(&c, &prows(&[3])).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].bucket, 4, "must pick an exported bucket");
    }

    #[test]
    fn chosen_plan_never_costs_more_than_monolithic() {
        // sweep a grid of occupancy patterns under every cost regime
        for perf in [kv_heavy(), pad_heavy(), weight_heavy()] {
            let buckets = [1usize, 2, 4];
            let vs = vctx(&buckets);
            let c = ctx(&perf, &vs, 4, true);
            for pat in [
                vec![0], vec![5], vec![0, 0], vec![5, 0], vec![5, 5],
                vec![5, 0, 0], vec![5, 5, 5, 5], vec![8, 4, 0, 2],
            ] {
                let plan = plan_step(&c, &prows(&pat)).unwrap();
                assert!(
                    plan.modeled_s <= plan.monolithic_s + 1e-15,
                    "plan for {pat:?} regressed: {plan:?}"
                );
                let mut rows = rows_of(&plan);
                rows.dedup();
                assert_eq!(rows, (0..pat.len()).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn mixed_variants_never_share_a_sub_batch_and_use_their_own_buckets() {
        // Primary w8a8 exports only b4; the fp32 reference exports {1, 4}.
        // Rows 0/2 are healthy (w8a8), rows 1/3 demoted (fp32): the plan
        // must keep the variants in disjoint sub-batches, pick each group's
        // bucket from its own list, and stay <= the per-group monolithic
        // cost.
        let perf = kv_heavy();
        let w8a8_buckets = [4usize];
        let fp32_buckets = [1usize, 4];
        let vs = vec![
            VariantCtx {
                name: "w8a8",
                verify_buckets: &w8a8_buckets,
                decode_buckets: &w8a8_buckets,
            },
            VariantCtx {
                name: "fp32",
                verify_buckets: &fp32_buckets,
                decode_buckets: &fp32_buckets,
            },
        ];
        let c = ctx(&perf, &vs, 4, true);
        let rows = vec![
            PlanRow::new(3, 0),
            PlanRow::new(2, 1),
            PlanRow::new(0, 0),
            PlanRow::new(0, 1),
        ];
        let plan = plan_step(&c, &rows).unwrap();
        assert_eq!(rows_of(&plan), vec![0, 1, 2, 3], "every row planned once");
        for sb in &plan.sub_batches {
            let vi = sb.variant;
            assert!(
                sb.rows.iter().all(|&i| rows[i].variant == vi),
                "sub-batch mixes variants: {plan:?}"
            );
            let exported = if sb.fn_kind == FnKind::Decode {
                vs[vi].decode_buckets
            } else {
                vs[vi].verify_buckets
            };
            assert!(exported.contains(&sb.bucket), "unexported bucket: {plan:?}");
        }
        assert!(plan.sub_batches.iter().any(|sb| sb.variant == 0));
        assert!(plan.sub_batches.iter().any(|sb| sb.variant == 1));
        assert!(plan.modeled_s <= plan.monolithic_s + 1e-15);
        // The w8a8 group is stuck at b4; the fp32 drafting+decode rows can
        // shrink to b-below-4 calls — so at least one fp32 sub-batch is
        // smaller than the configured bucket on this KV-bound device.
        assert!(
            plan.sub_batches.iter().any(|sb| sb.variant == 1 && sb.bucket < 4),
            "fp32 group should shrink: {plan:?}"
        );

        // elastic=false: one monolithic call per variant group, never mixed.
        let c = ctx(&perf, &vs, 4, false);
        let plan = plan_step(&c, &rows).unwrap();
        assert_eq!(plan.sub_batches.len(), 2, "one call per variant group");
        assert!(plan.sub_batches.iter().all(|sb| sb.bucket == 4));
        assert_eq!(plan.modeled_s, plan.monolithic_s);
    }

    #[test]
    fn prefill_chunk_rides_spare_capacity_and_books_the_saving() {
        // 1 verify row in a b2 bucket leaves one spare slot: the pending
        // prefill chunk rides it, capped at the verify chunk, without
        // touching the committed rows or the bucket choice.
        let perf = weight_heavy();
        let buckets = [2usize, 4];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, true);
        let mut plan = plan_step(&c, &prows(&[4])).unwrap();
        let (modeled, mono) = (plan.modeled_s, plan.monolithic_s);
        pack_prefill_riders(
            &c, &mut plan, &[PrefillPending { remaining: 40, variant: 0 }], 128, false,
        );
        assert_eq!(plan.sub_batches.len(), 1, "no dedicated call appended");
        let sb = &plan.sub_batches[0];
        assert_eq!(sb.rows, vec![0], "committed rows untouched");
        assert_eq!(sb.riders.len(), 1);
        assert_eq!(sb.riders[0].pending, 0);
        assert_eq!(sb.riders[0].take, 9, "take capped at the verify chunk");
        assert!(sb.riders[0].saved_s > 0.0, "the avoided dedicated call is priced");
        assert_eq!(sb.spare(), 0, "the rider consumed the spare slot");
        assert_eq!(sb.tokens_used, 9, "priced tokens grow up to the chunk ceiling");
        assert_eq!(sb.useful_tokens, 5 + 9);
        assert_eq!(plan.modeled_s, modeled, "riding is free in the plan cost");
        assert_eq!(plan.monolithic_s, mono);

        // A short remainder takes only what is left.
        let mut plan = plan_step(&c, &prows(&[4])).unwrap();
        pack_prefill_riders(
            &c, &mut plan, &[PrefillPending { remaining: 3, variant: 0 }], 128, false,
        );
        assert_eq!(plan.sub_batches[0].riders[0].take, 3);
    }

    #[test]
    fn prefill_chunk_without_spare_capacity_gets_a_dedicated_call() {
        // Occupancy 1 shrinks to the b1 bucket: no spare slot, so the
        // pending row falls back to a dedicated single-row prefill
        // sub-batch priced into both cost sides (savings gap unchanged).
        let perf = kv_heavy();
        let buckets = [1usize, 4];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, true);
        let mut plan = plan_step(&c, &prows(&[3])).unwrap();
        assert_eq!(plan.sub_batches[0].spare(), 0);
        let gap = plan.monolithic_s - plan.modeled_s;
        pack_prefill_riders(
            &c, &mut plan, &[PrefillPending { remaining: 200, variant: 0 }], 128, false,
        );
        assert_eq!(plan.sub_batches.len(), 2);
        let ded = &plan.sub_batches[1];
        assert_eq!(ded.fn_kind, FnKind::Prefill);
        assert_eq!(ded.bucket, 1);
        assert_eq!(ded.chunk, 128);
        assert!(ded.rows.is_empty());
        assert_eq!(ded.riders.len(), 1);
        assert_eq!(ded.riders[0].take, 128, "take capped at the prefill chunk");
        assert_eq!(ded.riders[0].saved_s, 0.0, "a stall saves nothing");
        assert_eq!(ded.tokens_used, 128);
        assert!(
            (plan.monolithic_s - plan.modeled_s - gap).abs() < 1e-15,
            "dedicated cost lands on both sides"
        );
    }

    #[test]
    fn shed_load_shrinks_dedicated_chunks_to_the_verify_program() {
        // Same no-spare setup as above, but with a deep queue (shed_load):
        // the dedicated call reroutes through the exported single-row
        // verify program — verify-chunk positions instead of the full
        // prefill window — so the step's priced time bound shrinks too.
        let perf = kv_heavy();
        let buckets = [1usize, 4];
        let vs = vctx(&buckets);
        let c = ctx(&perf, &vs, 4, true);
        let mut plan = plan_step(&c, &prows(&[3])).unwrap();
        assert_eq!(plan.sub_batches[0].spare(), 0);
        let gap = plan.monolithic_s - plan.modeled_s;
        let full = plan.modeled_s;
        pack_prefill_riders(
            &c, &mut plan, &[PrefillPending { remaining: 200, variant: 0 }], 128, true,
        );
        assert_eq!(plan.sub_batches.len(), 2);
        let ded = &plan.sub_batches[1];
        assert_eq!(ded.fn_kind, FnKind::Verify, "shed uses the verify program");
        assert_eq!(ded.bucket, 1);
        assert_eq!(ded.chunk, 9, "chunk shrinks to the verify window");
        assert_eq!(ded.riders[0].take, 9, "take capped at the shrunk chunk");
        assert!(ded.rows.is_empty());
        assert!(
            (plan.monolithic_s - plan.modeled_s - gap).abs() < 1e-15,
            "shed cost still lands on both sides"
        );
        // The shed step must price strictly below the same step with a
        // full-window dedicated call — that gap is the TPOT smoothing.
        let shed_cost = plan.modeled_s - full;
        let full_cost = c.perf.price_parts("fp32", c.n_layers, 1, 128).total();
        assert!(shed_cost < full_cost, "shed chunk must be cheaper per step");

        // A variant without an exported b1 verify program cannot shed: the
        // dedicated call keeps the full prefill shape.
        let v1_buckets = [4usize];
        let vs2 = vec![
            VariantCtx { name: "w8a8", verify_buckets: &buckets, decode_buckets: &buckets },
            VariantCtx {
                name: "fp32",
                verify_buckets: &v1_buckets,
                decode_buckets: &v1_buckets,
            },
        ];
        let c2 = ctx(&perf, &vs2, 4, true);
        let mut plan = plan_step(&c2, &prows(&[3])).unwrap();
        pack_prefill_riders(
            &c2, &mut plan, &[PrefillPending { remaining: 200, variant: 1 }], 128, true,
        );
        let ded = plan.sub_batches.last().unwrap();
        assert_eq!(ded.fn_kind, FnKind::Prefill, "no b1 verify export: no shed");
        assert_eq!(ded.chunk, 128);

        // Shed never grows the chunk: a prefill window already at or below
        // the verify chunk stays on the prefill program.
        let mut plan = plan_step(&c, &prows(&[3])).unwrap();
        pack_prefill_riders(
            &c, &mut plan, &[PrefillPending { remaining: 200, variant: 0 }], 8, true,
        );
        let ded = plan.sub_batches.last().unwrap();
        assert_eq!(ded.fn_kind, FnKind::Prefill);
        assert_eq!(ded.chunk, 8);
    }

    #[test]
    fn prefill_riders_respect_variant_and_one_chunk_per_row() {
        // Spare capacity exists only at variant 0; the variant-1 pending
        // row must NOT ride it. Two variant-0 pending rows each get exactly
        // one chunk: the first rides the spare slot, the second (slot now
        // full) falls back to a dedicated call.
        let perf = kv_heavy();
        let buckets = [2usize, 4];
        let vs = vec![
            VariantCtx { name: "w8a8", verify_buckets: &buckets, decode_buckets: &buckets },
            VariantCtx { name: "fp32", verify_buckets: &buckets, decode_buckets: &buckets },
        ];
        let c = ctx(&perf, &vs, 4, true);
        let mut plan = plan_step(&c, &[PlanRow::new(4, 0)]).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].spare(), 1);
        let pending = [
            PrefillPending { remaining: 50, variant: 1 },
            PrefillPending { remaining: 50, variant: 0 },
            PrefillPending { remaining: 50, variant: 0 },
        ];
        pack_prefill_riders(&c, &mut plan, &pending, 64, false);
        assert_eq!(plan.sub_batches.len(), 3, "two dedicated calls appended");
        assert_eq!(plan.sub_batches[0].riders.len(), 1, "one ride in the spare slot");
        assert_eq!(plan.sub_batches[0].riders[0].pending, 1, "same-variant row rides");
        let ded: Vec<&SubBatch> =
            plan.sub_batches.iter().filter(|sb| sb.fn_kind == FnKind::Prefill).collect();
        assert_eq!(ded.len(), 2);
        assert_eq!(ded[0].variant, 1, "cross-variant row stalled");
        assert_eq!(ded[0].riders[0].pending, 0);
        assert_eq!(ded[1].variant, 0, "no spare left for the third row");
        assert_eq!(ded[1].riders[0].pending, 2);
        // Exactly one chunk per pending row this step.
        let mut seen: Vec<usize> = plan
            .sub_batches
            .iter()
            .flat_map(|sb| sb.riders.iter().map(|r| r.pending))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        // A dedicated prefill sub-batch is never a rider target.
        assert!(ded.iter().all(|sb| sb.riders.len() == 1));
    }

    #[test]
    fn quantized_variant_prices_below_reference_for_the_same_shape() {
        // The planner's cost hook must see the variant's bytes/weight: the
        // same (bucket, tokens) sub-batch priced at w8a8 is strictly
        // cheaper than at fp32 on a weight-dominated model.
        let perf = weight_heavy();
        let buckets = [1usize, 4];
        let mk = |name: &'static str| {
            vec![VariantCtx { name, verify_buckets: &buckets, decode_buckets: &buckets }]
        };
        let (vq, vf) = (mk("w8a8"), mk("fp32"));
        let cq = ctx(&perf, &vq, 4, true);
        let cf = ctx(&perf, &vf, 4, true);
        let pq = plan_step(&cq, &prows(&[5])).unwrap();
        let pf = plan_step(&cf, &prows(&[5])).unwrap();
        assert_eq!(pq.sub_batches[0].bucket, pf.sub_batches[0].bucket);
        assert!(pq.modeled_s < pf.modeled_s, "w8a8 plan must price below fp32");
    }
}

//! Elastic step planning: partition the active rows of a step into
//! sub-batches and pick, per sub-batch, the cheapest exported batch bucket —
//! so low-occupancy groups stop reading idle KV rows and decode-only rows
//! stop paying full verify-chunk traffic (paper Eq. 11/12: verification cost
//! is memory traffic, and traffic scales with the bucket actually executed).
//!
//! One [`StepPlan`] is built per engine step from the per-row draft lengths
//! and executed as a gather → run_chunk → scatter pipeline per sub-batch
//! (see `coordinator::kv` for the row movement and `coordinator::engine` for
//! the driver).
//!
//! ## Bucket-selection invariants
//!
//! * A sub-batch's bucket is the **smallest exported bucket that fits its
//!   rows**; when every bucket is smaller than the group, the group splits
//!   across multiple sub-batches of the largest bucket (never silently
//!   truncated, never a bucket the manifest doesn't export).
//! * Every active row lands in **exactly one** sub-batch of the chosen plan.
//! * A sub-batch is function-homogeneous in what it *executes*: it runs one
//!   exported fn (`verify` or `decode`). Decode-only rows may ride along in
//!   a verify sub-batch's spare rows — that call's weight stream is already
//!   paid, so the ride is free in the cost model — but a `decode` sub-batch
//!   never contains a drafting row.
//! * Between the candidate shapes (monolithic configured bucket, shrunk
//!   single call, split by function) the planner commits to the one with the
//!   lowest [`PerfModel::plan_cost`]; ties prefer fewer calls, and a shape
//!   whose bucket the manifest does not export is never committed to. When
//!   the configured bucket is exported (the normal case) the chosen cost is
//!   monotonically <= the monolithic cost, and the gap is surfaced as the
//!   `planned_savings_s` metric.
//! * Planning is deterministic: rows are ordered longest-draft-first (ties
//!   by row index), so a split group packs similar draft lengths together
//!   and per-sub-batch `tokens_used` maxima stay small.

use anyhow::{bail, Result};

use crate::perfmodel::PerfModel;

use super::calls::FnKind;

/// Everything the planner needs about the engine's configuration, borrowed
/// for one `plan_step` call. Bucket lists come from the manifest
/// (`ModelEntry::buckets`) and must be sorted ascending.
pub struct PlanCtx<'a> {
    pub perf: &'a PerfModel,
    /// Verifier variant the step executes (prices the weight stream).
    pub variant: &'a str,
    pub n_layers: usize,
    /// The engine's configured construction-time bucket (the monolithic
    /// fallback shape; seed behavior).
    pub full_bucket: usize,
    /// Positions per row of the exported verify chunk (`gamma_max + 1`).
    pub verify_chunk: usize,
    pub verify_buckets: &'a [usize],
    pub decode_buckets: &'a [usize],
    /// `false` forces the monolithic single-call plan at `full_bucket`
    /// (bit-compatible with the pre-planner engine; used by equivalence
    /// tests and A/B benches).
    pub elastic: bool,
}

/// One chunk execution of a step: which rows run, through which exported
/// (fn, bucket), and the token accounting the call log records for it.
#[derive(Debug, Clone, PartialEq)]
pub struct SubBatch {
    pub fn_kind: FnKind,
    /// Exported batch bucket to execute at (scratch-cache shape).
    pub bucket: usize,
    /// Positions the artifact executes per row (1 for decode, the verify
    /// chunk otherwise).
    pub chunk: usize,
    /// Indices into the step's draft list; scratch row `i` carries
    /// `rows[i]`.
    pub rows: Vec<usize>,
    /// `1 + longest draft` among `rows` (what the cost model prices).
    pub tokens_used: usize,
    /// Sum over `rows` of `1 + draft len` (chunk-efficiency numerator).
    pub useful_tokens: usize,
}

impl SubBatch {
    fn new(fn_kind: FnKind, bucket: usize, chunk: usize, rows: Vec<usize>,
           draft_lens: &[usize]) -> Self {
        debug_assert!(!rows.is_empty());
        let tokens_used = rows.iter().map(|&i| draft_lens[i] + 1).max().unwrap_or(1);
        let useful_tokens = rows.iter().map(|&i| draft_lens[i] + 1).sum();
        SubBatch { fn_kind, bucket, chunk, rows, tokens_used, useful_tokens }
    }

    /// Free capacity left in the selected bucket.
    pub fn spare(&self) -> usize {
        self.bucket.saturating_sub(self.rows.len())
    }
}

/// The committed plan for one step, with the modeled cost of what was chosen
/// and of the monolithic shape it replaced (their gap is the planner's win).
#[derive(Debug, Clone)]
pub struct StepPlan {
    pub sub_batches: Vec<SubBatch>,
    /// `PerfModel::plan_cost` of the chosen sub-batches (seconds).
    pub modeled_s: f64,
    /// Cost of the monolithic single call at the configured bucket.
    pub monolithic_s: f64,
}

/// Smallest bucket (ascending list) that fits `n` rows; the largest
/// available when none fits (the caller then splits); `None` when the list
/// is empty.
pub fn best_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .or_else(|| buckets.last().copied())
}

/// Pack one function-homogeneous group of rows into sub-batches, splitting
/// over the largest bucket when the group is oversize. `idxs` index into
/// `draft_lens`.
fn pack(fn_kind: FnKind, chunk: usize, mut idxs: Vec<usize>, draft_lens: &[usize],
        buckets: &[usize]) -> Result<Vec<SubBatch>> {
    if idxs.is_empty() {
        return Ok(Vec::new());
    }
    if buckets.is_empty() {
        bail!("no '{}' buckets exported for this variant", fn_kind.name());
    }
    // Longest drafts first (ties by index): when the group must split,
    // similar-length work shares a call and per-call tokens_used stays low.
    idxs.sort_by_key(|&i| (std::cmp::Reverse(draft_lens[i]), i));
    let mut out = Vec::new();
    let mut start = 0;
    while start < idxs.len() {
        let left = idxs.len() - start;
        let bucket = best_bucket(buckets, left).expect("non-empty bucket list");
        let take = left.min(bucket);
        out.push(SubBatch::new(
            fn_kind, bucket, chunk, idxs[start..start + take].to_vec(), draft_lens,
        ));
        start += take;
    }
    Ok(out)
}

fn plan_cost(ctx: &PlanCtx, sbs: &[SubBatch]) -> f64 {
    let parts: Vec<(usize, usize)> =
        sbs.iter().map(|sb| (sb.bucket, sb.tokens_used)).collect();
    ctx.perf.plan_cost(ctx.variant, ctx.n_layers, &parts)
}

/// Build the step plan for the given per-row draft lengths (one entry per
/// active row, in group-row order).
pub fn plan_step(ctx: &PlanCtx, draft_lens: &[usize]) -> Result<StepPlan> {
    if draft_lens.is_empty() {
        bail!("plan_step on an empty step");
    }
    let n = draft_lens.len();
    let all: Vec<usize> = (0..n).collect();
    let any_draft = draft_lens.iter().any(|&d| d > 0);

    // The single-call function: verify when anything drafted; decode when
    // nothing did (falling back to verify if decode isn't exported).
    let (mono_fn, mono_chunk, mono_buckets) = if any_draft || ctx.decode_buckets.is_empty() {
        (FnKind::Verify, ctx.verify_chunk, ctx.verify_buckets)
    } else {
        (FnKind::Decode, 1usize, ctx.decode_buckets)
    };

    // Monolithic shape: the fixed construction-time bucket, one call.
    let mono = vec![SubBatch::new(
        mono_fn, ctx.full_bucket, mono_chunk, all.clone(), draft_lens,
    )];
    let mono_cost = plan_cost(ctx, &mono);
    if !ctx.elastic {
        return Ok(StepPlan { sub_batches: mono, modeled_s: mono_cost, monolithic_s: mono_cost });
    }

    // Candidate 1 — shrink: same single-function grouping, smallest
    // exported bucket that fits the occupancy.
    let shrunk = pack(mono_fn, mono_chunk, all, draft_lens, mono_buckets)?;

    // Candidate 2 — split by required function: drafting rows verify,
    // decode-only rows first ride along in spare verify capacity (that
    // weight stream is already paid), the remainder runs as 1-token decode
    // sub-batches that skip the verify chunk's padding traffic entirely.
    let split = if any_draft
        && draft_lens.iter().any(|&d| d == 0)
        && !ctx.decode_buckets.is_empty()
    {
        let verify_idx: Vec<usize> = (0..n).filter(|&i| draft_lens[i] > 0).collect();
        let decode_idx: Vec<usize> = (0..n).filter(|&i| draft_lens[i] == 0).collect();
        let mut sbs =
            pack(FnKind::Verify, ctx.verify_chunk, verify_idx, draft_lens, ctx.verify_buckets)?;
        let mut decode_iter = decode_idx.into_iter();
        'fill: for sb in sbs.iter_mut() {
            while sb.spare() > 0 {
                match decode_iter.next() {
                    Some(i) => {
                        sb.rows.push(i);
                        sb.useful_tokens += 1; // a decode row uses 1 position
                    }
                    None => break 'fill,
                }
            }
        }
        let leftover: Vec<usize> = decode_iter.collect();
        sbs.extend(pack(FnKind::Decode, 1, leftover, draft_lens, ctx.decode_buckets)?);
        Some(sbs)
    } else {
        None
    };

    // Commit to the cheapest candidate; ties prefer the earlier (fewer
    // calls / closer to monolithic) shape.
    let mut best = shrunk;
    let mut best_cost = plan_cost(ctx, &best);
    if let Some(split) = split {
        let c = plan_cost(ctx, &split);
        if c < best_cost {
            best = split;
            best_cost = c;
        }
    }
    if mono_cost < best_cost && mono_buckets.contains(&ctx.full_bucket) {
        // Only reachable when the manifest exports full_bucket but shrink
        // picked a larger-than-configured bucket (never happens when
        // full_bucket is in the list, since shrink is monotone) — kept as a
        // guard. A full_bucket the manifest does NOT export prices cheaper
        // here too, but committing to it would fail at run_chunk, so an
        // executable candidate always wins over an unexecutable one.
        best = mono;
        best_cost = mono_cost;
    }
    Ok(StepPlan { sub_batches: best, modeled_s: best_cost, monolithic_s: mono_cost })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{CostModelCfg, ModelCfg};
    use std::collections::BTreeMap;

    fn device(bf16_ops: f64, launch_s: f64) -> CostModelCfg {
        CostModelCfg {
            device: "sim".into(),
            hbm_bw_bytes_per_s: 1.6e12,
            int8_ops_per_s: 2.0 * bf16_ops,
            bf16_ops_per_s: bf16_ops,
            bytes_per_weight: BTreeMap::from([
                ("fp32".to_string(), 2.0),
                ("w8a8".to_string(), 1.0),
            ]),
            kernel_launch_s: launch_s,
            drafter_cost_per_token_s: 1e-6,
        }
    }

    fn small_model() -> ModelCfg {
        ModelCfg {
            name: "m".into(), vocab_size: 64, d_model: 32, n_layers: 2,
            n_heads: 8, ffn_dim: 64, max_seq: 4096, prefill_len: 128,
            gamma_max: 8, head_dim: 64,
        }
    }

    /// Tiny weights, long resident sequence, memory-bound device: shrinking
    /// the bucket (fewer idle KV rows read) is the dominant lever.
    fn kv_heavy() -> PerfModel {
        PerfModel::new(device(188e12, 2e-5), small_model())
    }

    /// Same model on a compute-starved device with cheap launches: the
    /// padded verify-chunk attention over the long sequence dominates, so
    /// splitting decode-only rows out of the verify chunk pays for the
    /// extra call.
    fn pad_heavy() -> PerfModel {
        PerfModel::new(device(1e12, 1e-9), small_model())
    }

    /// Big dense layers, short sequence — every extra call re-streams the
    /// weights, so one call wins.
    fn weight_heavy() -> PerfModel {
        let model = ModelCfg {
            name: "m".into(), vocab_size: 32000, d_model: 4096, n_layers: 32,
            n_heads: 8, ffn_dim: 11008, max_seq: 64, prefill_len: 32,
            gamma_max: 8, head_dim: 16,
        };
        PerfModel::new(device(188e12, 2e-5), model)
    }

    fn ctx<'a>(perf: &'a PerfModel, buckets: &'a [usize], elastic: bool) -> PlanCtx<'a> {
        PlanCtx {
            perf,
            variant: "fp32",
            n_layers: perf.model.n_layers,
            full_bucket: *buckets.last().unwrap(),
            verify_chunk: 9,
            verify_buckets: buckets,
            decode_buckets: buckets,
            elastic,
        }
    }

    fn rows_of(plan: &StepPlan) -> Vec<usize> {
        let mut r: Vec<usize> =
            plan.sub_batches.iter().flat_map(|sb| sb.rows.clone()).collect();
        r.sort_unstable();
        r
    }

    #[test]
    fn best_bucket_edge_cases() {
        assert_eq!(best_bucket(&[], 1), None, "no bucket large enough (none at all)");
        assert_eq!(best_bucket(&[1, 2, 4], 2), Some(2), "exact fit");
        assert_eq!(best_bucket(&[1, 2, 4], 3), Some(4), "next bucket up");
        assert_eq!(best_bucket(&[1, 2, 4], 9), Some(4), "oversize group takes largest");
        assert_eq!(best_bucket(&[4], 1), Some(4), "only a big bucket exported");
    }

    #[test]
    fn oversize_group_splits_across_largest_bucket() {
        let sbs =
            pack(FnKind::Verify, 9, (0..10).collect(), &[1usize; 10], &[1, 2, 4]).unwrap();
        assert_eq!(sbs.len(), 3, "10 rows over b4 -> 4+4+2");
        assert_eq!(sbs[0].rows.len(), 4);
        assert_eq!(sbs[1].rows.len(), 4);
        assert_eq!(sbs[2].rows.len(), 2);
        assert_eq!(sbs[2].bucket, 2, "tail picks the smallest fit");
        let mut all: Vec<usize> = sbs.iter().flat_map(|s| s.rows.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>(), "no row lost or duplicated");
    }

    #[test]
    fn packing_groups_similar_draft_lengths() {
        // 4 rows over b2 buckets: the two long drafts share a call so the
        // short call's tokens_used stays at 2, not 6.
        let sbs = pack(FnKind::Verify, 9, vec![0, 1, 2, 3], &[5, 1, 5, 1], &[2]).unwrap();
        assert_eq!(sbs.len(), 2);
        assert_eq!(sbs[0].rows, vec![0, 2]);
        assert_eq!(sbs[0].tokens_used, 6);
        assert_eq!(sbs[1].rows, vec![1, 3]);
        assert_eq!(sbs[1].tokens_used, 2);
    }

    #[test]
    fn empty_bucket_list_errors_and_elastic_false_is_monolithic() {
        let perf = kv_heavy();
        let buckets = [1usize, 4];
        let mut c = ctx(&perf, &buckets, false);
        let plan = plan_step(&c, &[3, 0, 0]).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].bucket, 4, "configured bucket, seed behavior");
        assert_eq!(plan.modeled_s, plan.monolithic_s);

        c.elastic = true;
        c.verify_buckets = &[];
        assert!(plan_step(&c, &[3]).is_err(), "drafting with no verify buckets");
        assert!(plan_step(&c, &[]).is_err(), "empty step");
    }

    #[test]
    fn occupancy_one_shrinks_to_the_small_bucket() {
        for perf in [kv_heavy(), weight_heavy()] {
            let buckets = [1usize, 4];
            let c = ctx(&perf, &buckets, true);
            let plan = plan_step(&c, &[3]).unwrap();
            assert_eq!(plan.sub_batches.len(), 1);
            assert_eq!(plan.sub_batches[0].bucket, 1, "1 row never reads 4 rows of KV");
            assert_eq!(plan.sub_batches[0].fn_kind, FnKind::Verify);
            assert!(plan.modeled_s < plan.monolithic_s);
        }
    }

    #[test]
    fn all_decode_rows_use_the_decode_function() {
        let perf = kv_heavy();
        let buckets = [1usize, 4];
        let c = ctx(&perf, &buckets, true);
        let plan = plan_step(&c, &[0, 0]).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].fn_kind, FnKind::Decode);
        assert_eq!(plan.sub_batches[0].chunk, 1);
        assert_eq!(plan.sub_batches[0].bucket, 4, "2 rows need the b4 bucket here");
        // the monolithic shape is already a decode call at b4 (seed
        // behavior), so shrink cannot improve on it here
        assert_eq!(plan.modeled_s, plan.monolithic_s);
    }

    #[test]
    fn decode_rows_ride_spare_verify_capacity_for_free() {
        // 1 verify + 1 decode row with buckets {2,4}: the verify call runs
        // at b2 with a spare row, so the decode row rides along — one call.
        let perf = weight_heavy();
        let buckets = [2usize, 4];
        let c = ctx(&perf, &buckets, true);
        let plan = plan_step(&c, &[4, 0]).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        let sb = &plan.sub_batches[0];
        assert_eq!(sb.fn_kind, FnKind::Verify);
        assert_eq!(sb.bucket, 2);
        assert_eq!(rows_of(&plan), vec![0, 1]);
        assert_eq!(sb.tokens_used, 5, "decode rider doesn't raise the max");
        assert_eq!(sb.useful_tokens, 6, "5 verify positions + 1 decode position");
        assert!(plan.modeled_s < plan.monolithic_s);
    }

    #[test]
    fn mixed_step_splits_when_padding_is_dear_and_stays_single_when_weights_are() {
        let buckets = [1usize, 2, 4];
        let lens = [6usize, 0, 0, 0]; // 1 drafting row drags 3 decode rows

        let pad = pad_heavy();
        let c = ctx(&pad, &buckets, true);
        let plan = plan_step(&c, &lens).unwrap();
        assert!(plan.sub_batches.len() > 1, "pad-heavy: split {plan:?}");
        assert!(plan.sub_batches.iter().any(|sb| sb.bucket < 4));
        assert!(plan.sub_batches.iter().any(|sb| sb.fn_kind == FnKind::Decode));
        assert!(
            plan.sub_batches
                .iter()
                .filter(|sb| sb.fn_kind == FnKind::Decode)
                .all(|sb| sb.rows.iter().all(|&i| lens[i] == 0)),
            "a decode sub-batch never contains a drafting row"
        );
        assert_eq!(rows_of(&plan), vec![0, 1, 2, 3]);
        assert!(plan.modeled_s < plan.monolithic_s);

        let wh = weight_heavy();
        let c = ctx(&wh, &buckets, true);
        let plan = plan_step(&c, &lens).unwrap();
        assert_eq!(
            plan.sub_batches.len(), 1,
            "weight-heavy: an extra call re-streams the weights, keep one"
        );
        assert_eq!(rows_of(&plan), vec![0, 1, 2, 3]);
        assert!(plan.modeled_s <= plan.monolithic_s);
    }

    #[test]
    fn unexported_configured_bucket_never_wins_the_plan() {
        // Engine configured at b1 but verify only exported at b4: the
        // monolithic b1 shape prices cheapest yet cannot execute — the
        // planner must commit to the exported bucket instead.
        let perf = kv_heavy();
        let buckets = [4usize];
        let mut c = ctx(&perf, &buckets, true);
        c.full_bucket = 1;
        let plan = plan_step(&c, &[3]).unwrap();
        assert_eq!(plan.sub_batches.len(), 1);
        assert_eq!(plan.sub_batches[0].bucket, 4, "must pick an exported bucket");
    }

    #[test]
    fn chosen_plan_never_costs_more_than_monolithic() {
        // sweep a grid of occupancy patterns under every cost regime
        for perf in [kv_heavy(), pad_heavy(), weight_heavy()] {
            let buckets = [1usize, 2, 4];
            let c = ctx(&perf, &buckets, true);
            for pat in [
                vec![0], vec![5], vec![0, 0], vec![5, 0], vec![5, 5],
                vec![5, 0, 0], vec![5, 5, 5, 5], vec![8, 4, 0, 2],
            ] {
                let plan = plan_step(&c, &pat).unwrap();
                assert!(
                    plan.modeled_s <= plan.monolithic_s + 1e-15,
                    "plan for {pat:?} regressed: {plan:?}"
                );
                let mut rows = rows_of(&plan);
                rows.dedup();
                assert_eq!(rows, (0..pat.len()).collect::<Vec<_>>());
            }
        }
    }
}

//! Online fidelity governor: makes verification *precision* a serving-time
//! decision instead of a construction-time pin.
//!
//! The paper's W8A8 verifier halves verification memory traffic "as long as
//! the quantization does not flip the top-1 prediction" (§4.5, Eq. 12) —
//! a workload-dependent property, not a global one. The governor audits that
//! assumption online, per *request class* (the request's task tag):
//!
//! * A sampled fraction (`audit_rate`) of sub-batches executed at the
//!   primary (quantized) variant is **shadow re-verified** against the
//!   reference variant: the same tokens and the same pre-advance KV run
//!   through the reference weights, and per-row top-1 agreement plus the
//!   acceptance-length delta feed a per-class EWMA. Shadow outputs are
//!   discarded — audits never touch committed state or request RNGs.
//! * When a class's agreement EWMA sinks below `floor` (after at least
//!   `min_audits` audits since its last transition — the hysteresis window)
//!   the class **demotes**: its verification, decode and prefill calls run
//!   the reference variant. Requests admitted after the demotion are
//!   bit-exact full-precision end to end; a request already mid-generation
//!   keeps the KV prefix its quantized calls wrote, so the guarantee for it
//!   covers only the remaining steps.
//! * A demoted class is **probed** every `probe_after_steps` engine steps:
//!   the quantized variant shadows the (now-reference) primary call. When
//!   the EWMA recovers above `floor + promote_margin` (again gated by the
//!   hysteresis window) the class re-promotes.
//!
//! State-machine invariants (documented here, asserted by the property
//! tests in `rust/tests/prop_coordinator.rs` and the unit tests below):
//!
//! 1. A class starts `Healthy` with an optimistic agreement of 1.0; with
//!    perfect audit agreement it never demotes.
//! 2. With agreement forced to zero a class demotes after exactly
//!    `max(min_audits, ⌈ln(floor)/ln(1-alpha)⌉)` audits — bounded, so a
//!    degraded verifier can only mis-commit for a bounded window.
//! 3. Transitions only happen in `record_audit`; `resolve` is pure, so the
//!    variant a step plans with is the variant it executes.
//! 4. Audits and probes change only governor state, never the committed
//!    token stream of the step that carried them.

use std::collections::BTreeMap;

use crate::util::rng::Pcg;

/// Tuning knobs of the precision policy. `Default` is *disabled*; turn it
/// on with [`GovernorConfig::on`] (or from the CLI via `--governor`).
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Master switch. Disabled: every class resolves to the primary variant
    /// and no audit is ever scheduled (zero overhead).
    pub enabled: bool,
    /// Reference (audit / fallback) weight variant — the precision ground
    /// truth a demoted class serves at.
    pub reference: String,
    /// Fraction of primary-variant sub-batches shadow-audited (sampled on
    /// the governor's own seeded stream, so runs are reproducible).
    pub audit_rate: f64,
    /// Top-1 agreement floor: a class whose agreement EWMA sinks below this
    /// demotes to the reference variant.
    pub floor: f64,
    /// Hysteresis window: audits a class must accumulate since its last
    /// transition before it may transition again (damps flapping).
    pub min_audits: u32,
    /// EWMA smoothing factor for agreement and acceptance-length delta.
    pub alpha: f64,
    /// Re-promotion requires agreement above `floor + promote_margin`
    /// (asymmetric thresholds are the second half of the hysteresis).
    pub promote_margin: f64,
    /// Engine steps a demoted class waits between re-promotion probes.
    pub probe_after_steps: u64,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            enabled: false,
            reference: "fp32".into(),
            audit_rate: 0.125,
            floor: 0.98,
            min_audits: 4,
            alpha: 0.25,
            promote_margin: 0.005,
            probe_after_steps: 16,
        }
    }
}

impl GovernorConfig {
    /// The default policy, enabled.
    pub fn on() -> Self {
        GovernorConfig { enabled: true, ..Default::default() }
    }
}

/// Which variant a request class's model calls execute at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// The engine's configured (typically quantized) verifier.
    Primary,
    /// The governor's reference (full-precision) variant.
    Reference,
}

/// A state transition returned by [`Governor::record_audit`] so the caller
/// can surface it in metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    Demoted,
    Promoted,
}

/// Per-class audit bookkeeping.
#[derive(Debug, Clone)]
pub struct ClassState {
    demoted: bool,
    /// Next engine step at which a demoted class may probe the primary.
    next_probe: u64,
    /// EWMA of per-row top-1 agreement between quantized and reference
    /// logits over audited positions (optimistic start: 1.0).
    pub agreement: f64,
    /// EWMA of (quantized accepted length − reference accepted length).
    pub accept_delta: f64,
    /// Audits since the last transition (the hysteresis gate).
    audits_since_flip: u32,
    /// Lifetime audits recorded for this class.
    pub audits: u64,
}

impl ClassState {
    fn fresh() -> Self {
        ClassState {
            demoted: false,
            next_probe: 0,
            agreement: 1.0,
            accept_delta: 0.0,
            audits_since_flip: 0,
            audits: 0,
        }
    }

    pub fn is_demoted(&self) -> bool {
        self.demoted
    }
}

/// Cap on distinct tracked classes. The class key is the client-supplied
/// task tag, so an unbounded map would let a high-cardinality (or
/// adversarial) workload grow governor state for the process lifetime;
/// past the cap, unseen tags fold into one shared [`OVERFLOW_CLASS`] that
/// is audited and governed like any other class.
const MAX_CLASSES: usize = 256;
const OVERFLOW_CLASS: &str = "<overflow>";

/// The governor itself: per-class states plus the audit sampler. Owned by
/// the engine; everything here is cheap enough for the hot loop (a bounded
/// BTreeMap keyed by short task strings, touched once per audited row).
pub struct Governor {
    cfg: GovernorConfig,
    classes: BTreeMap<String, ClassState>,
    rng: Pcg,
    step: u64,
    pub demotions: u64,
    pub promotions: u64,
}

impl Governor {
    pub fn new(cfg: GovernorConfig, seed: u64) -> Self {
        Governor {
            cfg,
            classes: BTreeMap::new(),
            rng: Pcg::seeded(seed ^ 0x4745_4F56),
            step: 0,
            demotions: 0,
            promotions: 0,
        }
    }

    pub fn cfg(&self) -> &GovernorConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Advance the governor's step clock (drives probe scheduling).
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// The tracked key for `class`: itself while known or while the map has
    /// room, the shared overflow class once the cap is hit.
    fn key<'a>(&self, class: &'a str) -> &'a str {
        if self.classes.contains_key(class) || self.classes.len() < MAX_CLASSES {
            class
        } else {
            OVERFLOW_CLASS
        }
    }

    /// Which variant `class`'s calls execute at. Pure: planning and
    /// execution of one step always agree.
    pub fn resolve(&self, class: &str) -> Route {
        if !self.cfg.enabled {
            return Route::Primary;
        }
        match self.classes.get(self.key(class)) {
            Some(st) if st.demoted => Route::Reference,
            _ => Route::Primary,
        }
    }

    /// Sample whether a primary-variant sub-batch should be shadow-audited.
    pub fn should_audit(&mut self) -> bool {
        self.cfg.enabled && self.rng.bool_with(self.cfg.audit_rate.clamp(0.0, 1.0))
    }

    /// Is `class` demoted and due for a re-promotion probe this step?
    pub fn probe_due(&self, class: &str) -> bool {
        self.cfg.enabled
            && self
                .classes
                .get(self.key(class))
                .is_some_and(|st| st.demoted && self.step >= st.next_probe)
    }

    /// Push a demoted class's next probe out by a full window without
    /// recording anything — used when a due probe could not execute (e.g.
    /// the shadow variant doesn't export the needed shape), so the engine
    /// doesn't re-attempt it on every subsequent sub-batch.
    pub fn defer_probe(&mut self, class: &str) {
        if !self.cfg.enabled {
            return;
        }
        let key = self.key(class).to_string();
        if let Some(st) = self.classes.get_mut(&key) {
            if st.demoted {
                st.next_probe = self.step + self.cfg.probe_after_steps;
            }
        }
    }

    /// Record one audit sample for `class`: top-1 `agreement` over the
    /// class's verified positions in one shadow call and the mean
    /// acceptance-length delta (quantized − reference). One shadow
    /// execution yields at most one sample per class (the engine aggregates
    /// its rows), so `min_audits` counts independent shadow events. Applies
    /// the EWMA and the demote/promote rules; returns the transition, if
    /// any.
    pub fn record_audit(
        &mut self,
        class: &str,
        agreement: f64,
        accept_delta: f64,
    ) -> Option<Transition> {
        let key = self.key(class).to_string();
        let cfg = &self.cfg;
        let st = self
            .classes
            .entry(key)
            .or_insert_with(ClassState::fresh);
        st.audits += 1;
        st.audits_since_flip = st.audits_since_flip.saturating_add(1);
        st.agreement = (1.0 - cfg.alpha) * st.agreement + cfg.alpha * agreement;
        st.accept_delta = (1.0 - cfg.alpha) * st.accept_delta + cfg.alpha * accept_delta;
        if st.demoted {
            // This audit *was* a probe; schedule the next one.
            st.next_probe = self.step + cfg.probe_after_steps;
        }
        if st.audits_since_flip < cfg.min_audits {
            return None; // inside the hysteresis window
        }
        if !st.demoted && st.agreement < cfg.floor {
            st.demoted = true;
            st.next_probe = self.step + cfg.probe_after_steps;
            st.audits_since_flip = 0;
            self.demotions += 1;
            return Some(Transition::Demoted);
        }
        // Promote threshold clamped strictly below 1.0: agreement is an
        // EWMA of values in [0, 1] and only approaches 1.0 asymptotically,
        // so an unclamped `floor + margin >= 1.0` (e.g. floor 0.995 with
        // the default margin) would make re-promotion unreachable and pin
        // the class on the reference — while still paying probe traffic —
        // forever.
        let promote_at = (cfg.floor + cfg.promote_margin).min(1.0 - 1e-9);
        if st.demoted && st.agreement > promote_at {
            st.demoted = false;
            st.audits_since_flip = 0;
            self.promotions += 1;
            return Some(Transition::Promoted);
        }
        None
    }

    /// Per-class view for stats endpoints and tests.
    pub fn class(&self, class: &str) -> Option<&ClassState> {
        self.classes.get(class)
    }

    pub fn classes(&self) -> impl Iterator<Item = (&String, &ClassState)> {
        self.classes.iter()
    }

    /// Lifetime audits across every class.
    pub fn total_audits(&self) -> u64 {
        self.classes.values().map(|c| c.audits).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gov(min_audits: u32, floor: f64) -> Governor {
        Governor::new(
            GovernorConfig {
                enabled: true,
                min_audits,
                floor,
                alpha: 0.25,
                promote_margin: 0.005,
                probe_after_steps: 4,
                ..Default::default()
            },
            1,
        )
    }

    #[test]
    fn disabled_governor_is_inert() {
        let mut g = Governor::new(GovernorConfig::default(), 0);
        assert!(!g.enabled());
        assert_eq!(g.resolve("x"), Route::Primary);
        assert!(!g.should_audit());
        assert!(!g.probe_due("x"));
    }

    #[test]
    fn perfect_agreement_never_demotes() {
        let mut g = gov(2, 0.98);
        for _ in 0..500 {
            g.begin_step();
            assert_eq!(g.record_audit("gsm8k", 1.0, 0.0), None);
            assert_eq!(g.resolve("gsm8k"), Route::Primary);
        }
        assert_eq!(g.demotions, 0);
    }

    #[test]
    fn forced_disagreement_demotes_exactly_at_the_hysteresis_window() {
        let mut g = gov(4, 0.98);
        g.begin_step();
        for i in 1..=3u32 {
            assert_eq!(g.record_audit("c", 0.0, -1.0), None, "audit {i} inside window");
            assert_eq!(g.resolve("c"), Route::Primary, "no transition inside window");
        }
        // alpha 0.25: EWMA is 0.75^4 ≈ 0.32 < 0.98 at the 4th audit.
        assert_eq!(g.record_audit("c", 0.0, -1.0), Some(Transition::Demoted));
        assert_eq!(g.resolve("c"), Route::Reference);
        assert_eq!(g.demotions, 1);
        assert!(g.class("c").unwrap().is_demoted());
        assert!(g.class("c").unwrap().accept_delta < 0.0);
    }

    #[test]
    fn classes_are_independent() {
        let mut g = gov(1, 0.98);
        g.begin_step();
        g.record_audit("bad", 0.0, 0.0);
        g.record_audit("good", 1.0, 0.0);
        assert_eq!(g.resolve("bad"), Route::Reference);
        assert_eq!(g.resolve("good"), Route::Primary);
        assert_eq!(g.resolve("never-seen"), Route::Primary);
    }

    #[test]
    fn probe_schedule_and_repromotion() {
        let mut g = gov(2, 0.9);
        g.begin_step(); // step 1
        g.record_audit("c", 0.0, 0.0);
        assert_eq!(g.record_audit("c", 0.0, 0.0), Some(Transition::Demoted));
        // probe only after probe_after_steps (4) more steps
        assert!(!g.probe_due("c"), "probe immediately after demotion");
        for _ in 0..4 {
            g.begin_step();
        }
        assert!(g.probe_due("c"), "probe due after the wait");
        // healthy probes recover the EWMA; promotion needs the window AND
        // floor + margin
        let mut promoted_at = None;
        for i in 1..=64 {
            // a probe happened: record_audit reschedules next_probe
            if g.record_audit("c", 1.0, 0.0) == Some(Transition::Promoted) {
                promoted_at = Some(i);
                break;
            }
            assert!(!g.probe_due("c"), "probe rescheduled after audit");
            for _ in 0..4 {
                g.begin_step();
            }
        }
        let n = promoted_at.expect("healthy probes must re-promote");
        assert!(n >= 2, "promotion inside the hysteresis window");
        assert_eq!(g.resolve("c"), Route::Primary);
        assert_eq!(g.promotions, 1);
    }

    #[test]
    fn repromotion_stays_reachable_when_floor_plus_margin_reaches_one() {
        // Regression: floor 0.995 + default margin 0.005 puts the raw
        // promote threshold at 1.0, which an EWMA of [0,1] samples can
        // never strictly exceed — the clamp must keep perfect probes able
        // to re-promote.
        let mut g = gov(2, 0.995);
        g.begin_step();
        g.record_audit("c", 0.0, 0.0);
        assert_eq!(g.record_audit("c", 0.0, 0.0), Some(Transition::Demoted));
        let mut promoted = false;
        for _ in 0..2000 {
            g.begin_step();
            if g.record_audit("c", 1.0, 0.0) == Some(Transition::Promoted) {
                promoted = true;
                break;
            }
        }
        assert!(promoted, "perfect probes must re-promote even at floor 0.995");
        assert_eq!(g.resolve("c"), Route::Primary);
    }

    #[test]
    fn class_map_is_bounded_and_overflow_tags_are_still_governed() {
        let mut g = gov(1, 0.98);
        g.begin_step();
        for i in 0..MAX_CLASSES + 50 {
            g.record_audit(&format!("class-{i}"), 1.0, 0.0);
        }
        assert!(
            g.classes().count() <= MAX_CLASSES + 1,
            "class map must stay bounded, got {}",
            g.classes().count()
        );
        assert!(g.class(OVERFLOW_CLASS).is_some(), "excess tags fold into overflow");
        // The overflow class is governed like any other: bad audits from a
        // not-individually-tracked tag still demote it, and every other
        // unseen tag resolves through it.
        g.record_audit("some-novel-tag", 0.0, 0.0);
        assert_eq!(g.resolve("a-different-novel-tag"), Route::Reference);
        assert_eq!(g.resolve("class-0"), Route::Primary, "tracked classes unaffected");
    }

    #[test]
    fn audit_sampling_tracks_rate() {
        let mut g = Governor::new(
            GovernorConfig { enabled: true, audit_rate: 0.25, ..Default::default() },
            3,
        );
        let hits = (0..4000).filter(|_| g.should_audit()).count();
        let rate = hits as f64 / 4000.0;
        assert!((rate - 0.25).abs() < 0.03, "sampled audit rate {rate}");
    }
}

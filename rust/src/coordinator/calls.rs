//! Execution accounting: every model call the engine makes, with enough
//! detail for the roofline model (`perfmodel`) to price it on the simulated
//! 910B2-class device. This is how measured acceptance dynamics (real
//! numerics) combine with the paper's Eq. 11–13 bandwidth arithmetic into
//! the table speedups (DESIGN.md §1, substitution row 2).

use crate::spec::drafter::DraftCost;

/// Which exported function a call used.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FnKind {
    Prefill,
    Decode,
    Verify,
    /// A fidelity-governor shadow call: the same chunk re-executed at the
    /// other precision variant for top-1 comparison. Its output is
    /// discarded (never committed, never scattered), but the call is real
    /// traffic and is priced like any verify/decode call of its variant.
    Audit,
}

impl FnKind {
    pub fn name(&self) -> &'static str {
        match self {
            FnKind::Prefill => "prefill",
            FnKind::Decode => "decode",
            FnKind::Verify => "verify",
            FnKind::Audit => "audit",
        }
    }
}

/// One model invocation. With the elastic step planner a single engine step
/// may emit several of these (one per executed sub-batch), each carrying the
/// bucket and token counts of the call that actually ran — not the engine's
/// configured bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct CallRecord {
    pub variant: String,
    pub fn_kind: FnKind,
    /// Batch bucket the artifact ran at (the planner-selected bucket for
    /// step sub-batches; the cost model's `kv_bytes` scales with this).
    pub batch: usize,
    /// Transformer depth of the executed variant (pruned variants < full).
    pub n_layers: usize,
    /// Rows actually carrying requests (<= batch).
    pub active_rows: usize,
    /// Max tokens *used* across the rows of this call (prefill: prompt len;
    /// verify: 1 + longest draft *in this sub-batch*). On real hardware the
    /// launch would be shaped to this, so the cost model prices it, not the
    /// padded chunk.
    pub tokens_used: usize,
    /// Positions the artifact executed per row (its fixed chunk length:
    /// prefill window, verify chunk, or 1 for decode).
    pub chunk_len: usize,
    /// Sum over active rows of the positions that carried real work
    /// (1 + that row's draft length). `useful_tokens / executed_positions`
    /// is the call's chunk efficiency.
    pub useful_tokens: usize,
    /// Measured CPU wall-clock of the PJRT execution (reported alongside
    /// modeled time for transparency; see DESIGN.md §9).
    pub wall_s: f64,
}

impl CallRecord {
    /// Positions the device really executed: every row of the bucket times
    /// the artifact's chunk length, padding included.
    pub fn executed_positions(&self) -> usize {
        self.batch * self.chunk_len
    }

    /// Useful-positions / executed-positions for this call.
    pub fn efficiency(&self) -> f64 {
        let ex = self.executed_positions();
        if ex == 0 {
            return 0.0;
        }
        self.useful_tokens as f64 / ex as f64
    }
}

/// Append-only call log for a run.
#[derive(Debug, Clone, Default)]
pub struct CallLog {
    pub records: Vec<CallRecord>,
    pub draft_cost: DraftCost,
}

impl CallLog {
    pub fn record(&mut self, rec: CallRecord) {
        self.records.push(rec);
    }

    pub fn add_draft_cost(&mut self, c: &DraftCost) {
        self.draft_cost.merge(c);
    }

    pub fn merge(&mut self, other: &CallLog) {
        self.records.extend(other.records.iter().cloned());
        self.draft_cost.merge(&other.draft_cost);
    }

    pub fn calls(&self, kind: FnKind) -> usize {
        self.records.iter().filter(|r| r.fn_kind == kind).count()
    }

    /// Aggregate chunk efficiency (useful / executed positions) over the
    /// decode+verify calls of the run — the serving-layer waste the elastic
    /// planner attacks. Prefill is excluded: its fill ratio is a property of
    /// the workload's prompt lengths, not of step planning. Governor audit
    /// calls are excluded too: they re-execute already-counted positions,
    /// so including them would double-count the same useful work.
    pub fn chunk_efficiency(&self) -> f64 {
        let (mut useful, mut executed) = (0usize, 0usize);
        for r in &self.records {
            if matches!(r.fn_kind, FnKind::Prefill | FnKind::Audit) {
                continue;
            }
            useful += r.useful_tokens;
            executed += r.executed_positions();
        }
        if executed == 0 {
            return 0.0;
        }
        useful as f64 / executed as f64
    }

    pub fn total_wall_s(&self) -> f64 {
        self.records.iter().map(|r| r.wall_s).sum()
    }

    pub fn clear(&mut self) {
        self.records.clear();
        self.draft_cost = DraftCost::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(kind: FnKind) -> CallRecord {
        CallRecord {
            variant: "fp32".into(),
            fn_kind: kind,
            batch: 4,
            n_layers: 6,
            active_rows: 3,
            tokens_used: 6,
            chunk_len: 6,
            useful_tokens: 12,
            wall_s: 0.001,
        }
    }

    #[test]
    fn log_counts_and_merges() {
        let mut a = CallLog::default();
        a.record(rec(FnKind::Verify));
        a.record(rec(FnKind::Verify));
        a.record(rec(FnKind::Prefill));
        assert_eq!(a.calls(FnKind::Verify), 2);
        assert_eq!(a.calls(FnKind::Decode), 0);
        assert!((a.total_wall_s() - 0.003).abs() < 1e-12);

        let mut b = CallLog::default();
        b.record(rec(FnKind::Decode));
        b.add_draft_cost(&DraftCost { decode_calls: 5, ..Default::default() });
        a.merge(&b);
        assert_eq!(a.records.len(), 4);
        assert_eq!(a.draft_cost.decode_calls, 5);
        a.clear();
        assert!(a.records.is_empty());
    }

    #[test]
    fn efficiency_counts_useful_over_executed() {
        let r = rec(FnKind::Verify); // 12 useful over 4x6 executed
        assert_eq!(r.executed_positions(), 24);
        assert!((r.efficiency() - 0.5).abs() < 1e-12);

        let mut log = CallLog::default();
        log.record(rec(FnKind::Verify));
        // prefill must not dilute the decode-phase efficiency
        log.record(CallRecord { useful_tokens: 0, ..rec(FnKind::Prefill) });
        assert!((log.chunk_efficiency() - 0.5).abs() < 1e-12);
        assert_eq!(CallLog::default().chunk_efficiency(), 0.0);
    }
}

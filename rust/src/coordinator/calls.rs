//! Execution accounting: every model call the engine makes, with enough
//! detail for the roofline model (`perfmodel`) to price it on the simulated
//! 910B2-class device. This is how measured acceptance dynamics (real
//! numerics) combine with the paper's Eq. 11–13 bandwidth arithmetic into
//! the table speedups (DESIGN.md §1, substitution row 2).

use crate::spec::drafter::DraftCost;

/// Which exported function a call used.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FnKind {
    Prefill,
    Decode,
    Verify,
}

impl FnKind {
    pub fn name(&self) -> &'static str {
        match self {
            FnKind::Prefill => "prefill",
            FnKind::Decode => "decode",
            FnKind::Verify => "verify",
        }
    }
}

/// One model invocation.
#[derive(Debug, Clone, PartialEq)]
pub struct CallRecord {
    pub variant: String,
    pub fn_kind: FnKind,
    /// Batch bucket the artifact ran at.
    pub batch: usize,
    /// Transformer depth of the executed variant (pruned variants < full).
    pub n_layers: usize,
    /// Rows actually carrying requests (<= batch).
    pub active_rows: usize,
    /// Max tokens *used* across rows this call (prefill: prompt len;
    /// verify: 1 + longest draft). On real hardware the launch would be
    /// shaped to this, so the cost model prices it, not the padded chunk.
    pub tokens_used: usize,
    /// Measured CPU wall-clock of the PJRT execution (reported alongside
    /// modeled time for transparency; see DESIGN.md §9).
    pub wall_s: f64,
}

/// Append-only call log for a run.
#[derive(Debug, Clone, Default)]
pub struct CallLog {
    pub records: Vec<CallRecord>,
    pub draft_cost: DraftCost,
}

impl CallLog {
    pub fn record(&mut self, rec: CallRecord) {
        self.records.push(rec);
    }

    pub fn add_draft_cost(&mut self, c: &DraftCost) {
        self.draft_cost.merge(c);
    }

    pub fn merge(&mut self, other: &CallLog) {
        self.records.extend(other.records.iter().cloned());
        self.draft_cost.merge(&other.draft_cost);
    }

    pub fn calls(&self, kind: FnKind) -> usize {
        self.records.iter().filter(|r| r.fn_kind == kind).count()
    }

    pub fn total_wall_s(&self) -> f64 {
        self.records.iter().map(|r| r.wall_s).sum()
    }

    pub fn clear(&mut self) {
        self.records.clear();
        self.draft_cost = DraftCost::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(kind: FnKind) -> CallRecord {
        CallRecord {
            variant: "fp32".into(),
            fn_kind: kind,
            batch: 4,
            n_layers: 6,
            active_rows: 3,
            tokens_used: 6,
            wall_s: 0.001,
        }
    }

    #[test]
    fn log_counts_and_merges() {
        let mut a = CallLog::default();
        a.record(rec(FnKind::Verify));
        a.record(rec(FnKind::Verify));
        a.record(rec(FnKind::Prefill));
        assert_eq!(a.calls(FnKind::Verify), 2);
        assert_eq!(a.calls(FnKind::Decode), 0);
        assert!((a.total_wall_s() - 0.003).abs() < 1e-12);

        let mut b = CallLog::default();
        b.record(rec(FnKind::Decode));
        b.add_draft_cost(&DraftCost { decode_calls: 5, ..Default::default() });
        a.merge(&b);
        assert_eq!(a.records.len(), 4);
        assert_eq!(a.draft_cost.decode_calls, 5);
        a.clear();
        assert!(a.records.is_empty());
    }
}

//! Admission scheduler: the layer between submitters and the engine's KV
//! rows.
//!
//! Submitted requests queue here instead of going straight into the batch
//! group. Each engine step asks the scheduler for the next request(s) to
//! admit; the policy decides the order, `take_expired` evicts entries whose
//! deadline passed before they could waste a prefill, and depth accounting
//! feeds the `queue_depth` gauge and the server's `stats` endpoint. The
//! scheduler is plain single-threaded state owned by the engine thread —
//! cross-thread concurrency stays in the router layer.

use std::collections::{HashSet, VecDeque};
use std::time::Instant;

use super::request::Request;
use crate::trace::{EventKind, TraceHandle};

/// Pluggable admission ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Strict arrival order.
    #[default]
    Fifo,
    /// Cheapest prefill first (shortest prompt; arrival order as tiebreak).
    /// Minimizes mean queueing delay under mixed prompt lengths.
    ShortestPromptFirst,
    /// Priority classes (`High` before `Normal` before `Low`), arrival order
    /// within a class.
    Priority,
}

impl SchedPolicy {
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s {
            "fifo" => Some(SchedPolicy::Fifo),
            "spf" | "shortest-prompt-first" => Some(SchedPolicy::ShortestPromptFirst),
            "priority" => Some(SchedPolicy::Priority),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::ShortestPromptFirst => "spf",
            SchedPolicy::Priority => "priority",
        }
    }
}

struct Queued {
    /// Arrival counter — the tiebreak for every policy.
    seq: u64,
    req: Request,
}

/// The admission queue plus its ordering policy and depth accounting.
pub struct Scheduler {
    policy: SchedPolicy,
    /// Kept in arrival order; FIFO pops the front in O(1), the other
    /// policies scan for their minimum.
    queue: VecDeque<Queued>,
    /// Ids currently queued. `contains` and the (common) miss side of
    /// `cancel` are O(1) lookups instead of queue scans — at fleet queue
    /// depths the dispatcher probes these on every cancel it routes.
    ids: HashSet<u64>,
    /// How many queued requests carry a deadline: `take_expired` runs every
    /// engine step and can skip its scan entirely for the (typical)
    /// deadline-free queue.
    deadlines: usize,
    next_seq: u64,
    peak_depth: usize,
    /// Flight-recorder handle; disabled by default (one branch per push).
    trace: TraceHandle,
}

impl Scheduler {
    pub fn new(policy: SchedPolicy) -> Self {
        Scheduler {
            policy,
            queue: VecDeque::new(),
            ids: HashSet::new(),
            deadlines: 0,
            next_seq: 0,
            peak_depth: 0,
            trace: TraceHandle::disabled(),
        }
    }

    /// Attach a flight-recorder handle (the engine wires this at spawn).
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// High-water mark of the queue depth over the scheduler's lifetime.
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    pub fn push(&mut self, req: Request) {
        self.trace.record(req.id, EventKind::Enqueued);
        self.ids.insert(req.id);
        if req.deadline_at().is_some() {
            self.deadlines += 1;
        }
        self.queue.push_back(Queued { seq: self.next_seq, req });
        self.next_seq += 1;
        self.peak_depth = self.peak_depth.max(self.queue.len());
    }

    /// Bookkeeping for a request leaving the queue by any path.
    fn forget(&mut self, req: &Request) {
        self.ids.remove(&req.id);
        if req.deadline_at().is_some() {
            self.deadlines -= 1;
        }
    }

    pub fn contains(&self, id: u64) -> bool {
        self.ids.contains(&id)
    }

    /// Remove a queued request by id (cancellation before admission).
    /// The miss side — every cancel probe for an id queued on some other
    /// replica, or already admitted — is an O(1) index lookup; only a hit
    /// pays the positional scan.
    pub fn cancel(&mut self, id: u64) -> Option<Request> {
        if !self.ids.contains(&id) {
            return None;
        }
        let idx = self.queue.iter().position(|q| q.req.id == id)?;
        let req = self.queue.remove(idx)?.req;
        self.forget(&req);
        Some(req)
    }

    /// Drain every queued request whose deadline has passed; the engine
    /// finishes them as `Cancelled` without spending a prefill. O(1) when
    /// nothing queued carries a deadline (the per-step common case).
    pub fn take_expired(&mut self, now: Instant) -> Vec<Request> {
        let mut expired = Vec::new();
        if self.deadlines == 0 {
            return expired;
        }
        let mut i = 0;
        while i < self.queue.len() {
            let blown = self.queue[i]
                .req
                .deadline_at()
                .is_some_and(|d| now >= d);
            if blown {
                let req = self.queue.remove(i).expect("index in bounds").req;
                self.forget(&req);
                expired.push(req);
            } else {
                i += 1;
            }
        }
        expired
    }

    /// Hand out the next request in policy order.
    pub fn pop(&mut self) -> Option<Request> {
        let idx = match self.policy {
            // `push_back` keeps arrival order, so FIFO is an O(1) pop.
            SchedPolicy::Fifo => {
                let q = self.queue.pop_front()?;
                self.forget(&q.req);
                return Some(q.req);
            }
            SchedPolicy::ShortestPromptFirst => self
                .queue
                .iter()
                .enumerate()
                .min_by_key(|(_, q)| (q.req.prompt.len(), q.seq))
                .map(|(i, _)| i)?,
            SchedPolicy::Priority => self
                .queue
                .iter()
                .enumerate()
                .min_by_key(|(_, q)| (q.req.params.priority, q.seq))
                .map(|(i, _)| i)?,
        };
        let req = self.queue.remove(idx)?.req;
        self.forget(&req);
        Some(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{GenParams, Priority};
    use std::time::Duration;

    fn req(id: u64, prompt_len: usize, priority: Priority) -> Request {
        let params = GenParams { priority, ..GenParams::default() };
        Request::new(id, vec![1; prompt_len], params)
    }

    #[test]
    fn fifo_pops_in_arrival_order() {
        let mut s = Scheduler::new(SchedPolicy::Fifo);
        for id in [3u64, 1, 2] {
            s.push(req(id, 4, Priority::Normal));
        }
        assert_eq!(s.depth(), 3);
        assert_eq!(s.peak_depth(), 3);
        let order: Vec<u64> = std::iter::from_fn(|| s.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![3, 1, 2]);
        assert!(s.is_empty());
    }

    #[test]
    fn spf_pops_shortest_prompt_with_fifo_tiebreak() {
        let mut s = Scheduler::new(SchedPolicy::ShortestPromptFirst);
        s.push(req(1, 9, Priority::Normal));
        s.push(req(2, 3, Priority::Normal));
        s.push(req(3, 3, Priority::Normal));
        s.push(req(4, 1, Priority::Normal));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![4, 2, 3, 1]);
    }

    #[test]
    fn priority_classes_pop_before_lower_classes() {
        let mut s = Scheduler::new(SchedPolicy::Priority);
        s.push(req(1, 4, Priority::Low));
        s.push(req(2, 4, Priority::Normal));
        s.push(req(3, 4, Priority::High));
        s.push(req(4, 4, Priority::Normal));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![3, 2, 4, 1]);
    }

    #[test]
    fn expired_requests_are_drained_not_popped() {
        let mut s = Scheduler::new(SchedPolicy::Fifo);
        // Zero deadline: expired the moment it is checked.
        let params = GenParams {
            deadline: Some(Duration::ZERO),
            ..GenParams::default()
        };
        s.push(Request::new(1, vec![1, 2], params));
        s.push(req(2, 2, Priority::Normal)); // no deadline: never expires
        let expired = s.take_expired(Instant::now());
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 1);
        assert_eq!(s.depth(), 1);
        assert_eq!(s.pop().unwrap().id, 2);
        assert!(s.take_expired(Instant::now()).is_empty());
    }

    #[test]
    fn cancel_removes_by_id() {
        let mut s = Scheduler::new(SchedPolicy::Fifo);
        for id in 1..=3u64 {
            s.push(req(id, 4, Priority::Normal));
        }
        assert!(s.contains(2));
        let c = s.cancel(2).unwrap();
        assert_eq!(c.id, 2);
        assert!(!s.contains(2));
        assert!(s.cancel(2).is_none());
        let order: Vec<u64> = std::iter::from_fn(|| s.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![1, 3]);
    }

    #[test]
    fn cancel_under_load_keeps_the_index_consistent() {
        // Fleet-depth queue: interleave pushes, pops, cancels and expiry
        // and check the id index never drifts from the queue itself.
        let mut s = Scheduler::new(SchedPolicy::Fifo);
        for id in 0..1000u64 {
            s.push(req(id, (id % 17) as usize + 1, Priority::Normal));
        }
        // Cancel every third id, including repeat cancels (misses).
        for id in (0..1000u64).step_by(3) {
            assert!(s.contains(id));
            assert_eq!(s.cancel(id).map(|r| r.id), Some(id));
            assert!(!s.contains(id));
            assert!(s.cancel(id).is_none());
        }
        // Pop half of what is left; every popped id leaves the index.
        for _ in 0..300 {
            let id = s.pop().unwrap().id;
            assert!(!s.contains(id));
        }
        // No deadlines queued: expiry is the O(1) fast path and drains
        // nothing.
        assert!(s.take_expired(Instant::now()).is_empty());
        // Drain the remainder: depth, index and queue agree to the end.
        while let Some(r) = s.pop() {
            assert!(!s.contains(r.id));
        }
        assert!(s.is_empty());
        assert_eq!(s.depth(), 0);

        // Expired requests leave the deadline count too: a queue that
        // drains its only deadline goes back to the fast path.
        let params = GenParams { deadline: Some(Duration::ZERO), ..GenParams::default() };
        s.push(Request::new(2000, vec![1, 2], params));
        s.push(req(2001, 2, Priority::Normal));
        assert_eq!(s.take_expired(Instant::now()).len(), 1);
        assert!(!s.contains(2000));
        assert!(s.contains(2001));
        assert!(s.take_expired(Instant::now()).is_empty());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [SchedPolicy::Fifo, SchedPolicy::ShortestPromptFirst, SchedPolicy::Priority] {
            assert_eq!(SchedPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(SchedPolicy::parse("nope"), None);
    }
}

//! The serving engine: continuous batching over a leased-row KV group,
//! per-request drafting, elastically-planned verification, lossless
//! rejection sampling, and full call accounting.
//!
//! One `step()` =
//!   expire  (cancel running requests whose deadline passed, free their rows)
//!   -> admit   (pop the scheduler in policy order; longest-prefix-match the
//!               prompt against the *paged* prefix cache, splice the matched
//!               run into the prefill scratch, and lease the new request a
//!               batch row carrying that prefix. Under chunked prefill (the
//!               default) admission *stops there* — the request parks as a
//!               resumable `Prefilling` row and its prompt suffix is fed by
//!               the plan/execute stages below; under
//!               `chunked_prefill = false` the whole suffix prefills here,
//!               window by window, before the first token samples. Either
//!               way the committed prompt KV is snapshotted back into the
//!               cache — a paged insert that references shared template
//!               pages instead of copying them; see
//!               `coordinator::prefixcache`. When a request finishes, its
//!               *generated* continuation extends its cached run
//!               (mid-stream snapshot), and [`Engine::warm_prefix`] can
//!               pre-populate the cache from workload templates before the
//!               first client.)
//!   -> draft   (per fully-prefilled row, via its drafter; rows whose
//!               admission prefill is still in flight don't draft — they
//!               advance one prefill chunk this step instead)
//!   -> plan    (build a [`StepPlan`]: partition rows into sub-batches by
//!               required function — decode-only vs verify — *and* by the
//!               verifier variant each row's request class resolved to, and
//!               pick each sub-batch's cheapest exported (bucket, variant)
//!               pair on the cost model; then pack each prefilling row's
//!               next chunk into the chosen sub-batches' spare capacity —
//!               see `coordinator::plan` for both sets of invariants)
//!   -> execute (per sub-batch: gather each leased row's *committed* KV
//!               positions into a pooled bucket-shaped scratch cache, run
//!               the chunk on the sub-batch's variant — `fp32` for the
//!               paper's Ngram baseline, `w8a8` for Quasar — then write the
//!               advanced rows back; a sampled fraction of governed
//!               sub-batches is shadow re-verified at the other precision
//!               first)
//!   -> commit  (rejection sampling Eq. 2-3, acceptance bookkeeping,
//!               audit agreement fed to the governor, finish handling; per
//!               sub-batch, in plan order)
//!
//! The planner is what keeps memory traffic proportional to *useful* work: a
//! batch-4 group at occupancy 1 verifies through the batch-1 bucket instead
//! of streaming four rows of KV, and decode-only rows stop riding the full
//! verify chunk when a separate 1-token decode call prices cheaper.
//! `EngineConfig::elastic = false` pins the monolithic configured-bucket
//! call (the pre-planner behavior) for equivalence tests and A/B benches.
//!
//! ## Page-table batch rows (`EngineConfig::paged_rows`, the default)
//!
//! Batch rows are **page-tables over the shared prefix-cache pool**
//! ([`super::kv::PagedGroup`]) rather than owned `[L, B, H, max_seq, hd]`
//! slabs. The ownership/COW discipline is append-only:
//!
//! * **Admission** builds the row's table with
//!   [`PrefixCache::lease_row_pages`]: every *full* page the prompt's
//!   longest cached run covers is installed by refcount bump — zero copies
//!   — and only the partial tail page (plus any uncached pages, on a cold
//!   prompt) is copied out of the prefill output. The admission-time
//!   `insert` runs first, so even a cold prompt's pages are shared with the
//!   run that was just snapshotted rather than copied twice.
//! * **Fully-committed pages are immutable.** A row only ever writes its
//!   private growth frontier (pages it references exclusively);
//!   `write_row_page` hard-errors on a shared page, so a page referenced by
//!   any live row is never mutated or COW'd out from under it, by
//!   construction. Copies happen in exactly two places: the partial tail at
//!   admission, and fresh frontier pages as generation advances.
//! * **Execute** gathers only committed positions (page-wise reads) and
//!   scatters only the newly-advanced range `[cached, cached + chunk)` —
//!   the committed prefix is never re-written, where the slab backend
//!   copies `[0, cached + chunk)` back every step.
//! * **Finish** snapshots the whole committed prefix — partial tail
//!   included — by referencing the row's own pages
//!   ([`PrefixCache::insert_pages`], pure refcount bumps), then `leave`
//!   releases the row's references; pages survive exactly as long as a run
//!   or a live row holds them.
//!
//! Resident KV drops from `batch × max_seq` slabs to the pages actually
//! committed, shared across rows with common prefixes; the modeled traffic
//! avoided is booked in the `kv_copy_saved_s` histogram.
//! `paged_rows = false` keeps the copy-based slab rows as the bit-exact A/B
//! reference (the `--no-paged-rows` bench path).
//!
//! ## Chunked admission prefill (`EngineConfig::chunked_prefill`, the default)
//!
//! A dedicated admission-time prefill stalls every decoding row behind a
//! single-row call. Chunked admission removes that stall: admission only
//! splices the cached prefix and leases the row (marking the request
//! `Prefilling`); the prompt suffix is then fed one chunk per step by the
//! planner, *riding the spare rows of the decode/verify sub-batches the
//! step executes anyway* (a rider consumes at most the sub-batch's chunk
//! positions, so the priced call shape never grows — see the rider-packing
//! invariants in `coordinator::plan`). Only when no same-variant spare slot
//! exists does a pending row fall back to a dedicated prefill sub-batch —
//! the counted `decode_stall_steps` case; rides book the avoided call price
//! to `prefill_stall_saved_s` instead. The first token samples from the
//! chunk that covers the final prompt position, drawn from the same
//! per-request RNG the monolithic path uses.
//!
//! Chunk windows near the end of the cache row clamp their write start to
//! `max_seq - chunk_len` and re-feed the overlap: KV at a position depends
//! only on the (identical) tokens at and before it, so the rewrite is
//! bit-identical and the tail lands in-bounds. Output equivalence with
//! `chunked_prefill = false` rests on that plus the cross-program KV
//! contract the prefix cache already assumes (decode/verify-program KV for
//! the same tokens matches prefill-program KV — see ROADMAP's scope notes);
//! both A/B smokes assert equal output checksums.
//!
//! ## Adaptive-precision verification (the fidelity governor)
//!
//! Verification *precision* is a per-request-class runtime decision, not a
//! construction-time pin. With `EngineConfig::governor.enabled`, the engine
//! owns a [`Governor`] whose per-class state machine decides, each step,
//! whether a class's calls (prefill, decode, verify) execute the primary
//! (typically `w8a8`) variant or the full-precision reference:
//!
//! * **Healthy** classes run the primary variant; a sampled fraction of
//!   their sub-batches is shadow re-verified against the reference (same
//!   tokens, same pre-advance KV; the shadow's advanced cache is discarded,
//!   so audits never touch committed state, request RNGs, or drafts).
//! * A class whose top-1 agreement EWMA sinks below the configured floor
//!   (after the hysteresis window) **demotes**: its calls run the reference
//!   variant. Requests *admitted after* the demotion are bit-exact
//!   full-precision end to end (their prefill already runs the reference);
//!   a request mid-generation at demotion time keeps its quantized-history
//!   KV prefix, so only its remaining steps gain full-precision logits.
//! * Demoted classes are periodically **probed** (the quantized variant
//!   shadows the reference call) and re-promote once agreement recovers
//!   above floor + margin.
//!
//! Invariants: shadow calls are logged as [`FnKind::Audit`] and priced like
//! real traffic but never scattered or committed; the variant a step *plans*
//! with is the variant it *executes* (resolution happens once, before
//! planning); and with a healthy quantized verifier the committed stream is
//! bit-identical to a reference-pinned engine whenever quantization does not
//! flip the verifier's top-1 — exactly the paper's §4.5 criterion, now
//! audited online instead of assumed.
//!
//! Submissions land in the admission [`Scheduler`] (FIFO / shortest-prompt /
//! priority policies, per-request deadlines) rather than a raw queue; the
//! engine also exposes a [`Engine::cancel`] path that frees a running
//! request's KV row and emits a `Cancelled` completion.
//!
//! The engine is deliberately single-threaded around the PJRT client (one
//! device); concurrency lives in the router/server layer which feeds it.

use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::metrics::{names, Metrics, SpecStats};
use crate::perfmodel::PerfModel;
use crate::runtime::{ModelCfg, ModelRuntime, Tensor};
use crate::spec::drafter::{DraftCost, Drafter};
use crate::spec::{verify_draft, Draft, NgramConfig, NgramDrafter, PrunedDrafter, VanillaDrafter};
use crate::tokenizer::{BOS_ID, EOS_ID};
use crate::util::rng::Pcg;

use super::calls::{CallLog, CallRecord, FnKind};
use super::gamma::{GammaConfig, GammaController};
use super::governor::{Governor, GovernorConfig, Route, Transition};
use super::kv::{BatchGroup, PagedGroup, RowStore};
use super::plan::{pack_prefill_riders, plan_step, PlanCtx, PlanRow, PrefillPending, StepPlan,
                  SubBatch, VariantCtx};
use super::prefixcache::{PrefixCache, PrefixCacheConfig};
use super::request::{Completion, FinishReason, GenParams, PrefillProgress, Request,
                     RequestState, StageBreakdown};
use super::scheduler::{SchedPolicy, Scheduler};
use crate::trace::{EventKind, FlightRecorder, PrefillMode, TraceHandle, FUNC_AUDIT,
                   FUNC_DECODE, FUNC_PREFILL, FUNC_VERIFY};

/// Which drafting strategy the engine wires per request.
#[derive(Debug, Clone)]
pub enum DrafterKind {
    /// Autoregressive baseline (paper's "Vanilla").
    Vanilla,
    /// Prompt-lookup decoding (paper's "Ngram" baseline and Quasar).
    Ngram(NgramConfig),
    /// Layer-dropped model drafting (Table 5): variant name, e.g. "pruned75".
    Pruned(String),
}

/// Engine configuration: the method axes of the paper's tables.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Verifier weight variant: `fp32` ("BF16" baseline) or `w8a8` (Quasar).
    pub verifier: String,
    pub drafter: DrafterKind,
    /// Batch bucket to serve at (must exist in the manifest: 1 or 4).
    pub batch: usize,
    /// Speculation depth cap (<= model gamma_max).
    pub gamma: usize,
    /// Per-class adaptive draft depth (`coordinator::gamma`): the engine
    /// resolves each row's effective gamma from its class's
    /// accepted-per-draft EWMA (accumulated across requests and turns) and
    /// seeds fresh drafters from the class prior. `false` pins every draft
    /// at the configured `gamma` — truly fixed depth, the static A/B
    /// reference (`--adaptive-gamma off`) and the shape `--gamma` sweeps
    /// measure. Lossless either way: depth moves drafted-but-rejected
    /// cost, never committed tokens.
    pub adaptive_gamma: bool,
    pub seed: u64,
    /// Admission ordering for queued requests (see `coordinator::scheduler`).
    pub policy: SchedPolicy,
    /// Elastic step planning (`coordinator::plan`): shrink/split each step
    /// to the cheapest exported buckets. `false` pins the monolithic
    /// configured-bucket call per step (pre-planner behavior, for
    /// equivalence tests and A/B benches).
    pub elastic: bool,
    /// Adaptive-precision policy (`coordinator::governor`): per-class
    /// demotion of the quantized verifier to the reference variant, driven
    /// by sampled shadow audits. Default: disabled (zero overhead).
    pub governor: GovernorConfig,
    /// Shared-prefix KV reuse (`coordinator::prefixcache`): admission
    /// longest-prefix-matches the prompt against cached committed prefixes
    /// and prefills only the suffix. Lossless by construction (segments are
    /// keyed by the variant that produced them), so the default is enabled.
    pub prefix: PrefixCacheConfig,
    /// Page-table batch rows over the shared pool (module docs): admission
    /// references cached pages instead of copying them, scatter writes only
    /// newly-advanced positions, finish snapshots by refcount. Bit-identical
    /// output either way; `false` keeps the copy-based slab rows as the A/B
    /// reference.
    pub paged_rows: bool,
    /// Chunked admission prefill (module docs): admission leases the KV row
    /// and splices the cached prefix immediately, then feeds the prompt
    /// suffix in planner-packed chunks that ride spare decode/verify slots
    /// instead of preempting the running batch with a dedicated prefill
    /// call. Bit-identical output either way; `false` keeps the monolithic
    /// admission-time prefill as the A/B reference
    /// (the `--no-chunked-prefill` bench path).
    pub chunked_prefill: bool,
    /// This engine's index within a replica fleet (`coordinator::cluster`).
    /// Purely identity: threads through stats and strides request ids.
    pub replica: usize,
    /// Fleet size this engine is a member of. Request ids are strided so
    /// every replica mints globally-unique ids (`replica + 1`, step
    /// `replicas`): the dispatcher can route a cancel by `(id - 1) %
    /// replicas` without a shared id allocator. The single-engine default
    /// (`replica: 0, replicas: 1`) yields ids 1, 2, 3, … — bit-identical
    /// to the pre-cluster engine.
    pub replicas: usize,
    /// Flight recorder (`crate::trace`): per-request span events drained by
    /// `{"cmd":"trace"}`. Default off — the off path is a single atomic
    /// branch per record site, no allocation.
    pub trace: bool,
}

impl EngineConfig {
    /// The paper's three methods, by name.
    pub fn vanilla(batch: usize) -> Self {
        EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Vanilla,
            batch,
            gamma: 0,
            adaptive_gamma: true,
            seed: 0,
            policy: SchedPolicy::Fifo,
            elastic: true,
            governor: GovernorConfig::default(),
            prefix: PrefixCacheConfig::default(),
            paged_rows: true,
            chunked_prefill: true,
            replica: 0,
            replicas: 1,
            trace: false,
        }
    }

    pub fn ngram(batch: usize, gamma: usize) -> Self {
        EngineConfig {
            verifier: "fp32".into(),
            drafter: DrafterKind::Ngram(NgramConfig { gamma, ..Default::default() }),
            batch,
            gamma,
            adaptive_gamma: true,
            seed: 0,
            policy: SchedPolicy::Fifo,
            elastic: true,
            governor: GovernorConfig::default(),
            prefix: PrefixCacheConfig::default(),
            paged_rows: true,
            chunked_prefill: true,
            replica: 0,
            replicas: 1,
            trace: false,
        }
    }

    pub fn quasar(batch: usize, gamma: usize) -> Self {
        EngineConfig {
            verifier: "w8a8".into(),
            ..Self::ngram(batch, gamma)
        }
    }

    pub fn method_name(&self) -> String {
        match (&self.drafter, self.verifier.as_str()) {
            (DrafterKind::Vanilla, _) => "vanilla".into(),
            (DrafterKind::Ngram(_), "w8a8") => "quasar".into(),
            (DrafterKind::Ngram(_), _) => "ngram".into(),
            (DrafterKind::Pruned(v), _) => format!("draft-{v}"),
        }
    }
}

/// Map a call-log function kind onto the trace wire code.
fn trace_func(k: FnKind) -> u8 {
    match k {
        FnKind::Decode => FUNC_DECODE,
        FnKind::Verify => FUNC_VERIFY,
        FnKind::Prefill => FUNC_PREFILL,
        FnKind::Audit => FUNC_AUDIT,
    }
}

/// One executable verifier weight variant: its name plus the exported
/// bucket lists the planner may pick from. Slot 0 is the configured primary
/// variant; slot 1 (when the governor is active) the reference variant.
struct VariantSlot {
    name: String,
    verify_buckets: Vec<usize>,
    decode_buckets: Vec<usize>,
}

impl VariantSlot {
    fn load(model: &ModelRuntime, name: &str, drafter: &DrafterKind) -> Result<Self> {
        let verify_buckets = model.entry.buckets(name, "verify");
        let decode_buckets = model.entry.buckets(name, "decode");
        if verify_buckets.is_empty() && !matches!(drafter, DrafterKind::Vanilla) {
            bail!("no verify buckets exported for variant '{name}'");
        }
        // Admission always prefills through the single-row bucket.
        model.entry.artifact(name, "prefill", 1)?;
        Ok(VariantSlot {
            name: name.to_string(),
            verify_buckets,
            decode_buckets,
        })
    }
}

/// The engine itself. See module docs.
pub struct Engine {
    model: Rc<ModelRuntime>,
    pub cfg: EngineConfig,
    mcfg: ModelCfg,
    /// Batch rows: page-tables over the pool, or copy-based slabs (see
    /// `EngineConfig::paged_rows`).
    rows: RowStore,
    /// Slot storage; a request keeps its slot index for its lifetime.
    states: Vec<Option<RequestState>>,
    /// Admission queue between submitters and the batch group.
    sched: Scheduler,
    rng: Pcg,
    next_id: u64,
    pub metrics: Metrics,
    pub call_log: CallLog,
    completions: Vec<Completion>,
    /// Cost model the step planner minimizes over (manifest device constants
    /// + this model's architecture).
    perf: PerfModel,
    /// Executable verifier variants: `[primary]`, or `[primary, reference]`
    /// when the fidelity governor is active. `SubBatch::variant` and
    /// `PlanRow::variant` index into this.
    variants: Vec<VariantSlot>,
    /// Adaptive-precision state machine (inert when disabled).
    governor: Governor,
    /// Per-class draft-depth controller (`coordinator::gamma`): resolves
    /// each row's effective gamma at draft time and seeds fresh drafters
    /// from the class prior. Always records (the stats are free and feed
    /// `{"cmd":"stats"}`); only clamps when `cfg.adaptive_gamma`.
    gamma: GammaController,
    /// Shared-prefix KV reuse across admissions (inert when disabled) —
    /// and, under `paged_rows`, the page allocator the batch rows live in.
    prefix_cache: PrefixCache,
    /// High-water mark of resident KV bytes (pool + slab), for the A/B
    /// bench comparison across row backends.
    kv_peak_bytes: usize,
    /// Pooled single-row prefill scratch: zeroed and reused per admission
    /// instead of allocating a fresh `[L, 1, H, S, hd]` pair each time.
    prefill_k: Tensor<f32>,
    prefill_v: Tensor<f32>,
    /// Flight-recorder handle (`crate::trace`); a single-branch no-op when
    /// `cfg.trace` is off. The router replaces it at spawn so all replicas
    /// of a cluster share one recorder.
    trace: TraceHandle,
}

impl Engine {
    pub fn new(model: Rc<ModelRuntime>, cfg: EngineConfig) -> Result<Self> {
        let mcfg = model.cfg().clone();
        if cfg.gamma + 1 > mcfg.verify_len() && !matches!(cfg.drafter, DrafterKind::Vanilla) {
            bail!("gamma {} exceeds exported verify chunk {}", cfg.gamma, mcfg.verify_len());
        }
        // Validate the configured bucket exists up front.
        model.entry.artifact(&cfg.verifier, "prefill", cfg.batch)?;
        let mut variants = vec![VariantSlot::load(&model, &cfg.verifier, &cfg.drafter)?];
        // The governor only matters when the reference really is a second
        // variant; a governed fp32 engine stays single-variant and inert.
        if cfg.governor.enabled && cfg.governor.reference != cfg.verifier {
            variants.push(VariantSlot::load(&model, &cfg.governor.reference, &cfg.drafter)?);
        }
        let rows = if cfg.paged_rows {
            RowStore::Paged(PagedGroup::new(
                cfg.batch, cfg.prefix.page_tokens, mcfg.max_seq,
            ))
        } else {
            RowStore::Copy(BatchGroup::new(
                mcfg.n_layers, cfg.batch, mcfg.n_heads, mcfg.max_seq, mcfg.head_dim,
            ))
        };
        let perf = PerfModel::new(model.cost_model().clone(), mcfg.clone());
        let (prefill_k, prefill_v) = model.empty_cache(mcfg.n_layers, 1);
        let governor = Governor::new(cfg.governor.clone(), cfg.seed ^ 0x4649_4445);
        let gamma = GammaController::new(GammaConfig {
            enabled: cfg.adaptive_gamma,
            ..GammaConfig::default()
        });
        let prefix_cache = PrefixCache::new(cfg.prefix.clone());
        // Direct-embedding users (benches, tests) get a private recorder
        // when tracing is on; the router replaces it at spawn so a cluster's
        // replicas share one. Off stays a plain disabled handle — no
        // allocation at all.
        let trace = if cfg.trace {
            TraceHandle::new(
                std::sync::Arc::new(FlightRecorder::new(true)),
                cfg.replica as u32,
            )
        } else {
            TraceHandle::disabled()
        };
        let mut sched = Scheduler::new(cfg.policy);
        sched.set_trace(trace.clone());
        Ok(Engine {
            model,
            mcfg,
            rows,
            states: Vec::new(),
            sched,
            rng: Pcg::seeded(cfg.seed ^ 0x5145_5341),
            // Fleet-unique id lane: replica r of N mints r+1, r+1+N, … —
            // the default (0 of 1) is the classic 1, 2, 3, … sequence.
            next_id: 1 + cfg.replica as u64,
            metrics: Metrics::new(),
            call_log: CallLog::default(),
            completions: Vec::new(),
            perf,
            variants,
            governor,
            gamma,
            prefix_cache,
            kv_peak_bytes: 0,
            prefill_k,
            prefill_v,
            trace,
            cfg,
        })
    }

    /// Replace the flight-recorder handle (the router wires a shared
    /// recorder at spawn, before any submission). Keeps the scheduler's
    /// handle in sync.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.sched.set_trace(trace.clone());
        self.trace = trace;
    }

    /// The engine's flight-recorder handle (for export surfaces).
    pub fn trace_handle(&self) -> &TraceHandle {
        &self.trace
    }

    /// Every bucket the step planner may execute at (stats publishing).
    pub fn plan_buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .variants
            .iter()
            .flat_map(|v| v.verify_buckets.iter().chain(v.decode_buckets.iter()))
            .copied()
            .chain(std::iter::once(self.cfg.batch))
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    /// Every weight variant the engine may execute (stats publishing).
    pub fn variant_names(&self) -> Vec<String> {
        self.variants.iter().map(|v| v.name.clone()).collect()
    }

    /// The precision-policy state machine (read-only view for stats/tests).
    pub fn governor(&self) -> &Governor {
        &self.governor
    }

    /// Mutable governor access: lets tests and operational tooling force a
    /// class's state (e.g. pre-demote a class known to be degraded).
    pub fn governor_mut(&mut self) -> &mut Governor {
        &mut self.governor
    }

    /// The draft-depth controller (read-only view for stats/tests).
    pub fn gamma_ctl(&self) -> &GammaController {
        &self.gamma
    }

    /// Mutable depth-controller access: lets tests and operational tooling
    /// pre-seed a class's acceptance prior.
    pub fn gamma_ctl_mut(&mut self) -> &mut GammaController {
        &mut self.gamma
    }

    /// The shared-prefix KV cache (read-only view for stats/tests).
    pub fn prefix_cache(&self) -> &PrefixCache {
        &self.prefix_cache
    }

    /// True when two precision variants are in play (governor active).
    fn governed(&self) -> bool {
        self.variants.len() > 1
    }

    /// Variant-slot index `class`'s calls execute at, per the governor.
    fn route_slot(&self, class: &str) -> usize {
        match self.governor.resolve(class) {
            Route::Primary => 0,
            Route::Reference => 1.min(self.variants.len() - 1),
        }
    }

    pub fn model(&self) -> &Rc<ModelRuntime> {
        &self.model
    }

    pub fn eos_id(&self) -> i32 {
        EOS_ID // tokenizer contract constants live in `crate::tokenizer`
    }

    fn make_drafter(&mut self) -> Result<Box<dyn Drafter>> {
        Ok(match &self.cfg.drafter {
            DrafterKind::Vanilla => Box::new(VanillaDrafter),
            DrafterKind::Ngram(c) => {
                // The engine-level switch overrides the per-drafter flag:
                // `adaptive_gamma: false` means a *truly* fixed depth —
                // no intra-request EWMA either — so `--gamma` sweeps and
                // the static A/B measure the depth they asked for.
                let mut c = *c;
                c.adaptive = self.cfg.adaptive_gamma;
                Box::new(NgramDrafter::new(c))
            }
            DrafterKind::Pruned(variant) => Box::new(PrunedDrafter::new(
                Rc::clone(&self.model),
                variant,
                self.rng.next_u64(),
            )?),
        })
    }

    /// Queue a request. A prompt longer than the context cap (`max_seq - 2`,
    /// leaving room for at least one generated token plus the decode
    /// write margin) is cut to it — recorded in the completion's
    /// [`SpecStats::prompt_truncated`] and the `prompt_truncated` counter
    /// rather than silently dropped. The cap is deliberately *not* the
    /// prefill window: a suffix longer than one window is fed in multiple
    /// chunks, and a warm request's post-splice suffix is shorter still —
    /// gating admission on the raw prompt length would refuse work the
    /// cache has already mostly paid for.
    pub fn submit(&mut self, prompt: Vec<i32>, params: GenParams, task: &str) -> u64 {
        self.submit_at(prompt, params, task, Instant::now())
    }

    /// [`submit`](Self::submit) with an explicit submission instant — the
    /// router passes the moment the client handed over the request, so the
    /// channel hop is attributed to the completion's `dispatch_s` stage
    /// (and the deadline clock starts when the client thinks it did).
    pub fn submit_at(
        &mut self,
        mut prompt: Vec<i32>,
        params: GenParams,
        task: &str,
        sent_at: Instant,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += self.cfg.replicas.max(1) as u64;
        let cap = self.mcfg.max_seq.saturating_sub(2);
        let truncated = prompt.len() > cap;
        prompt.truncate(cap);
        if truncated {
            self.metrics.inc(names::PROMPT_TRUNCATED, 1);
        }
        if prompt.is_empty() {
            prompt.push(BOS_ID);
        }
        self.sched.push(
            Request::new(id, prompt, params)
                .with_task(task)
                .with_truncated(truncated)
                .with_submitted_at(sent_at),
        );
        self.metrics.inc("requests_submitted", 1);
        self.metrics
            .set_gauge(names::QUEUE_DEPTH, self.sched.depth() as i64);
        id
    }

    /// Number of requests not yet completed.
    pub fn in_flight(&self) -> usize {
        self.sched.depth() + self.rows.active_rows().len()
    }

    /// Requests waiting in the scheduler (not yet holding a KV row).
    pub fn queue_depth(&self) -> usize {
        self.sched.depth()
    }

    /// Requests currently holding a KV row.
    pub fn active_count(&self) -> usize {
        self.rows.active_rows().len()
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Abort a request wherever it lives. A queued request is dropped before
    /// it costs a prefill; a running one releases its KV row via
    /// [`BatchGroup::leave`]. Either way a [`FinishReason::Cancelled`]
    /// completion is emitted so the submitter's reply channel resolves.
    /// Returns `false` when the id is unknown (already completed).
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        if let Some(req) = self.sched.cancel(id) {
            self.finish_unadmitted(req);
            return Ok(true);
        }
        for (row, slot) in self.rows.active_rows() {
            if self.states[slot].as_ref().map(|st| st.req.id) == Some(id) {
                self.cancel_row(row, slot)?;
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Release a running request's KV row and finish it as `Cancelled`
    /// (shared by explicit cancel and deadline expiry).
    fn cancel_row(&mut self, row: usize, slot: usize) -> Result<()> {
        self.rows.leave(&mut self.prefix_cache, row)?;
        let mut st = self.states[slot].take().expect("leased slot has state");
        st.finished = Some(FinishReason::Cancelled);
        self.trace.record(st.req.id, EventKind::Cancelled);
        self.finish_to_completion(st);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Admission: prefill into a single-row cache, splice into the group.
    // ------------------------------------------------------------------

    fn admit(&mut self) -> Result<()> {
        let now = Instant::now();
        for req in self.sched.take_expired(now) {
            self.finish_unadmitted(req);
        }
        // Rows already decoding when this admission pass starts: a
        // monolithic prefill executed now stalls them (that is what the
        // `decode_stall_steps` counter tallies; the chunked path never
        // prefills here, so it never trips this).
        let decode_active = self
            .rows
            .active_rows()
            .iter()
            .filter(|&&(_, slot)| {
                self.states[slot]
                    .as_ref()
                    .is_some_and(|st| st.prefilling.is_none())
            })
            .count();
        let mut prefill_calls = 0usize;
        let mut admitted = false;
        while self.rows.free_rows() > 0 {
            let Some(req) = self.sched.pop() else { break };
            admitted = true;
            let sched_delay = now.duration_since(req.submitted_at).as_secs_f64();
            self.metrics.observe(names::SCHED_DELAY_S, sched_delay);
            let mut drafter = self.make_drafter()?;
            drafter.begin(&req.prompt)?;
            // Warm-start the drafter's intra-request depth EWMA from the
            // class's cross-request prior: a second turn (or a template
            // sibling) drafts at the learned depth on its first step
            // instead of relearning from the cold-start constant.
            if let Some(prior) = self.gamma.prior(&req.task) {
                drafter.seed_depth_prior(prior);
            }
            let rng = self.rng.fork(req.params.seed.unwrap_or(req.id));
            let mut st = RequestState::new(req, drafter, rng);
            st.sched_delay_s = sched_delay;
            st.admitted_at = Some(now);

            let p = self.mcfg.prefill_len;
            let len = st.req.prompt.len();

            // Prefill at the precision the governor resolved for this
            // request's class: a demoted class gets full-precision KV from
            // its very first position, so its stream is bit-exact reference
            // output end to end. The prefix cache is keyed by the same
            // variant, so reuse never crosses a precision boundary.
            let variant = self.variants[self.route_slot(&st.req.task)].name.clone();
            st.admit_variant = variant.clone();

            // Longest-prefix reuse, capped only so at least one suffix token
            // remains — the last prompt position's logits must come from a
            // chunk this request executes. A hit past `max_seq - prefill_len`
            // no longer caps the reuse: the chunk windows below clamp their
            // write start and re-feed the (identical) overlap instead.
            let hit_cap = len - 1;
            let lease = if self.cfg.prefix.enabled {
                self.prefix_cache.lookup(&variant, &st.req.prompt[..hit_cap])
            } else {
                None
            };
            // Pooled prefill scratch: zero in place instead of allocating a
            // fresh single-row cache pair per admission, then splice the
            // matched prefix's KV over positions `0..hit`. The lease only
            // needs to pin the segment for the duration of the copy, so it
            // is released immediately — before any fallible call could
            // propagate an error past it and leak the refcount.
            self.prefill_k.zero();
            self.prefill_v.zero();
            let splice_t0 = Instant::now();
            let hit = match lease {
                Some(l) => {
                    let spliced = self
                        .prefix_cache
                        .splice(&l, &mut self.prefill_k, &mut self.prefill_v);
                    let n = l.len();
                    self.prefix_cache.release(l);
                    spliced?;
                    // Hit/miss/token tallies live in the cache itself (one
                    // source of truth, published as gauges below); only the
                    // modeled saving is priced here, where both lengths are
                    // known. Net of the per-page splice traffic that
                    // realized the hit — `ceil(n/page_tokens)` pool pages
                    // read + written, not a max_seq row.
                    let gross = self
                        .perf
                        .prefill_saved_s(&variant, self.mcfg.n_layers, len, len - n);
                    let splice_s = self.perf.splice_time(
                        self.mcfg.n_layers, n, self.cfg.prefix.page_tokens,
                    );
                    self.metrics
                        .observe(names::PREFILL_SAVED_S, (gross - splice_s).max(0.0));
                    n
                }
                None => 0,
            };
            if hit > 0 {
                st.splice_s = splice_t0.elapsed().as_secs_f64();
            }

            st.prefix_hit = hit > 0;
            self.trace
                .record(st.req.id, EventKind::Admitted { hit_tokens: hit as u32 });

            if self.cfg.chunked_prefill {
                // Resumable admission: lease the row and install the spliced
                // prefix now; the prompt suffix is fed in planner-packed
                // chunks riding subsequent steps (`exec_sub_batch`'s rider
                // leg). No model call runs here, so admission never preempts
                // the decoding batch with a dedicated prefill.
                st.cached = hit;
                st.prefilling = Some(PrefillProgress { hit, consumed: 0 });
                let slot = self.free_slot();
                match &mut self.rows {
                    RowStore::Copy(g) => {
                        // Row 0 of the prefill scratch holds the spliced
                        // prefix; the length-bounded join zeroes the rest.
                        g.join_prefix_from_row(
                            slot, &self.prefill_k, &self.prefill_v, 0, hit,
                        )?;
                    }
                    RowStore::Paged(g) => {
                        if hit > 0 {
                            // Full pages of the hit install by refcount bump
                            // off the cached run; only the partial tail page
                            // is copied out of the splice scratch.
                            let rp = self.prefix_cache.lease_row_pages(
                                &variant, &st.req.prompt[..hit],
                                &self.prefill_k, &self.prefill_v, 0,
                            )?;
                            if rp.shared > 0 {
                                let saved = self.perf.kv_move_time(
                                    self.mcfg.n_layers,
                                    rp.shared,
                                    self.cfg.prefix.page_tokens.max(1),
                                );
                                self.metrics.observe(names::KV_COPY_SAVED_S, saved);
                            }
                            g.join_pages(slot, rp.pages, hit)?;
                        } else {
                            g.join_pages(slot, Vec::new(), 0)?;
                        }
                    }
                }
                self.states[slot] = Some(st);
                continue;
            }

            // Monolithic admission (`--no-chunked-prefill`, the A/B
            // reference): prefill the whole suffix here, in as many
            // prefill-window chunks as it needs. Each chunk's write window
            // `[w, w + prefill_len)` must stay inside the cache row, so once
            // the consumed prefix passes `max_seq - prefill_len` the window
            // start clamps back and the overlap re-feeds prompt tokens whose
            // KV the cache already holds — a bit-identical rewrite (same
            // tokens, same causal prefix) with the new tail landing
            // in-bounds.
            let mut consumed = hit;
            let mut last_w = 0usize;
            let mut out_opt = None;
            while consumed < len {
                let w = consumed.min(self.mcfg.max_seq.saturating_sub(p));
                let end = len.min(w + p);
                let mut toks = vec![0i32; p];
                toks[..end - w].copy_from_slice(&st.req.prompt[w..end]);
                let t0 = Instant::now();
                let out = match &out_opt {
                    // Later chunks read (and extend) the cache the previous
                    // chunk advanced.
                    Some(prev) => self.model.run_chunk(
                        &variant, "prefill", 1, &toks, &prev.k, &prev.v, &[w as i32],
                    ),
                    None => self.model.run_chunk(
                        &variant, "prefill", 1, &toks,
                        &self.prefill_k, &self.prefill_v, &[w as i32],
                    ),
                }
                .context("prefill")?;
                let wall = t0.elapsed().as_secs_f64();
                self.metrics.observe("prefill_s", wall);
                self.metrics.inc(names::PREFILL_CHUNKS, 1);
                self.trace.record(
                    st.req.id,
                    EventKind::PrefillChunk { mode: PrefillMode::Dedicated },
                );
                prefill_calls += 1;
                self.call_log.record(CallRecord {
                    variant: variant.clone(),
                    fn_kind: FnKind::Prefill,
                    batch: 1,
                    n_layers: self.mcfg.n_layers,
                    active_rows: 1,
                    tokens_used: end - consumed,
                    chunk_len: p,
                    useful_tokens: end - consumed,
                    wall_s: wall,
                });
                if let Some(prev) = out_opt.take() {
                    self.model.return_scratch(&variant, prev.k, prev.v);
                }
                consumed = end;
                last_w = w;
                out_opt = Some(out);
            }
            let out = out_opt.expect("hit < len leaves at least one suffix token");

            // First generated token comes straight from the prefill logits
            // (chunk position `(len - 1) - last_w` is prompt position
            // `len - 1`).
            let first = {
                let row = out.logits.row(&[0, (len - 1) - last_w]);
                crate::spec::sample_logits(row, st.req.params.temp, &mut st.rng)
            };
            st.cached = len;
            st.committed.push(first);
            st.generated = 1;
            st.stats.steps += 1;
            st.stats.tokens_out += 1;
            st.first_token_at = Some(Instant::now());
            st.drafter.observe_commit(&[first])?;
            let cost = st.drafter.take_cost();
            self.call_log.add_draft_cost(&cost);
            st.draft_cost.merge(&cost);
            Self::check_finish_with(self.mcfg.max_seq, &mut st);

            // Feed the cache forward: `out` now holds committed KV for the
            // whole prompt (`0..hit` spliced, `hit..len` just written), so
            // future admissions sharing this prefix skip that much prefill.
            if self.cfg.prefix.enabled {
                self.prefix_cache.insert(&variant, &st.req.prompt, &out.k, &out.v);
            }

            // Park the state in a slot and lease a cache row. Only the
            // prompt's `cached` positions are valid KV.
            let slot = self.free_slot();
            if st.is_active() {
                match &mut self.rows {
                    RowStore::Copy(g) => {
                        // Row-addressed join: row 0 of the prefill output is
                        // the assembled prefix (spliced pages + suffix chunk
                        // writes). The length-bounded join zeroes the rest
                        // of the row instead of preserving the chunk's
                        // past-the-prompt garbage.
                        g.join_prefix_from_row(slot, &out.k, &out.v, 0, st.cached)?;
                    }
                    RowStore::Paged(g) => {
                        // Build the row's page table off the pool: the
                        // `insert` above ran first, so every full page of
                        // the prompt — warm hit or cold miss — is installed
                        // by refcount bump; only the partial tail (the
                        // private growth frontier) is copied from the
                        // prefill output.
                        let rp = self.prefix_cache.lease_row_pages(
                            &variant, &st.req.prompt, &out.k, &out.v, 0,
                        )?;
                        if rp.shared > 0 {
                            let saved = self.perf.kv_move_time(
                                self.mcfg.n_layers,
                                rp.shared,
                                self.cfg.prefix.page_tokens.max(1),
                            );
                            self.metrics.observe(names::KV_COPY_SAVED_S, saved);
                        }
                        g.join_pages(slot, rp.pages, st.cached)?;
                    }
                }
                self.states[slot] = Some(st);
            } else {
                self.finish_to_completion(st);
            }
            // Recycle the advanced single-row cache as b1 step scratch.
            self.model.return_scratch(&variant, out.k, out.v);
        }
        if decode_active > 0 && prefill_calls > 0 {
            self.metrics.inc(names::DECODE_STALL_STEPS, 1);
        }
        if self.cfg.prefix.enabled && admitted {
            // Published wholesale from the cache's own counters — the one
            // source of truth — rather than tallied a second time inline.
            // Gated on state movement: admissions here, mid-stream
            // snapshots in the commit path; the steady-state decode loop
            // skips the snapshot entirely.
            self.publish_prefix_gauges();
            self.publish_kv_gauges();
        }
        self.metrics
            .set_gauge(names::QUEUE_DEPTH, self.sched.depth() as i64);
        Ok(())
    }

    /// Publish the prefix cache's own counters wholesale as gauges (one
    /// source of truth; the router's stats block reads these back).
    fn publish_prefix_gauges(&self) {
        let ps = self.prefix_cache.stats();
        self.metrics.set_gauge(names::PREFIX_HITS, ps.hits as i64);
        self.metrics.set_gauge(names::PREFIX_MISSES, ps.misses as i64);
        self.metrics
            .set_gauge(names::PREFIX_HIT_TOKENS, ps.hit_tokens as i64);
        self.metrics
            .set_gauge(names::PREFIX_EVICTIONS, ps.evictions as i64);
        self.metrics
            .set_gauge(names::PREFIX_RESIDENT_BYTES, ps.resident_bytes as i64);
        self.metrics
            .set_gauge(names::PREFIX_SEGMENTS, ps.segments as i64);
        self.metrics
            .set_gauge(names::PREFIX_RESIDENT_PAGES, ps.resident_pages as i64);
        self.metrics
            .set_gauge(names::PREFIX_PAGE_REFS, ps.page_refs as i64);
        self.metrics
            .set_gauge(names::PREFIX_COPIED_PAGES, ps.copied_pages as i64);
        self.metrics.set_gauge(
            names::PREFIX_MID_STREAM_HIT_TOKENS,
            ps.mid_stream_hit_tokens as i64,
        );
    }

    /// Bytes of KV resident right now: the page pool (cached runs + live
    /// row pages) plus, under the copy-based backend, the group's whole
    /// slab — the honest apples-to-apples figure the A/B bench compares.
    pub fn kv_resident_bytes(&self) -> usize {
        let pool = self.prefix_cache.stats().resident_bytes;
        match &self.rows {
            RowStore::Copy(g) => {
                pool + 2 * g.k.data.len() * std::mem::size_of::<f32>()
            }
            RowStore::Paged(_) => pool,
        }
    }

    /// Publish the KV residency/row-page gauges and advance the peak.
    fn publish_kv_gauges(&mut self) {
        let resident = self.kv_resident_bytes();
        self.kv_peak_bytes = self.kv_peak_bytes.max(resident);
        let ps = self.prefix_cache.stats();
        self.metrics
            .set_gauge(names::KV_RESIDENT_BYTES, resident as i64);
        self.metrics
            .set_gauge(names::KV_RESIDENT_PEAK_BYTES, self.kv_peak_bytes as i64);
        self.metrics
            .set_gauge(names::KV_ROW_PAGE_REFS, ps.row_page_refs as i64);
        self.metrics
            .set_gauge(names::KV_ROW_SHARED_PAGES, ps.row_shared_pages as i64);
        self.metrics
            .set_gauge(names::KV_ROW_COPIED_PAGES, ps.row_copied_pages as i64);
        self.metrics
            .set_gauge(names::KV_ROW_TAIL_COPIES, ps.row_tail_copies as i64);
    }

    /// Boot warm-up: pre-populate the prefix cache from template prompts
    /// before the first client (the `workload` layer's shared-prefix
    /// templates). Each template is prefilled whole at its class's
    /// governor-resolved variant and snapshotted — exactly the KV a cold
    /// admission of that template would have committed, so warmed hits
    /// stay bit-identical by the same causality argument as normal reuse.
    /// Lookup counters are untouched (warm-up is not traffic), so serving
    /// hit rates stay honest. Returns how many templates were prefilled.
    pub fn warm_prefix(&mut self, templates: &[(Vec<i32>, String)]) -> Result<usize> {
        if !self.cfg.prefix.enabled {
            return Ok(0);
        }
        let p = self.mcfg.prefill_len;
        let mut cached = 0usize;
        for (ids, task) in templates {
            let mut prompt = ids.clone();
            prompt.truncate(p);
            if prompt.len() < self.cfg.prefix.min_prefix.max(1) {
                continue;
            }
            let variant = self.variants[self.route_slot(task)].name.clone();
            self.prefill_k.zero();
            self.prefill_v.zero();
            let mut toks = vec![0i32; p];
            toks[..prompt.len()].copy_from_slice(&prompt);
            let t0 = Instant::now();
            let out = self
                .model
                .run_chunk(
                    &variant, "prefill", 1, &toks,
                    &self.prefill_k, &self.prefill_v, &[0],
                )
                .context("warm-up prefill")?;
            let wall = t0.elapsed().as_secs_f64();
            self.metrics.observe("prefill_s", wall);
            self.call_log.record(CallRecord {
                variant: variant.clone(),
                fn_kind: FnKind::Prefill,
                batch: 1,
                n_layers: self.mcfg.n_layers,
                active_rows: 1,
                tokens_used: prompt.len(),
                chunk_len: p,
                useful_tokens: prompt.len(),
                wall_s: wall,
            });
            self.prefix_cache.insert(&variant, &prompt, &out.k, &out.v);
            self.model.return_scratch(&variant, out.k, out.v);
            cached += 1;
        }
        self.publish_prefix_gauges();
        self.publish_kv_gauges();
        Ok(cached)
    }

    /// Finish a request that never reached a KV row (blown deadline or
    /// cancellation while queued): empty output, `Cancelled` finish.
    fn finish_unadmitted(&mut self, req: Request) {
        let now = Instant::now();
        let latency = now.duration_since(req.submitted_at).as_secs_f64();
        // `requests_completed` counts every terminal outcome;
        // `requests_cancelled` is the subset that was aborted.
        self.metrics.inc("requests_completed", 1);
        self.metrics.inc("requests_cancelled", 1);
        self.trace.record(req.id, EventKind::Cancelled);
        // Never admitted: the whole latency is dispatch + queue time.
        let stages = StageBreakdown {
            dispatch_s: req.enqueued_at.duration_since(req.submitted_at).as_secs_f64(),
            queue_s: now.duration_since(req.enqueued_at).as_secs_f64(),
            ..StageBreakdown::default()
        };
        self.completions.push(Completion {
            id: req.id,
            task: req.task.clone(),
            prompt_len: req.prompt.len(),
            tokens: Vec::new(),
            finish: FinishReason::Cancelled,
            stats: SpecStats {
                prompt_truncated: req.prompt_truncated as u64,
                ..SpecStats::default()
            },
            draft_cost: DraftCost::default(),
            sched_delay_s: latency,
            latency_s: latency,
            ttft_s: latency,
            stages,
            finished_at: now,
        });
    }

    /// Cancel any *running* request whose deadline has passed, releasing its
    /// KV row for waiting work.
    fn expire_active(&mut self) -> Result<()> {
        let now = Instant::now();
        for (row, slot) in self.rows.active_rows() {
            let blown = self.states[slot]
                .as_ref()
                .and_then(|st| st.req.deadline_at())
                .is_some_and(|d| now >= d);
            if blown {
                self.cancel_row(row, slot)?;
            }
        }
        Ok(())
    }

    fn free_slot(&mut self) -> usize {
        if let Some(i) = self.states.iter().position(|s| s.is_none()) {
            i
        } else {
            self.states.push(None);
            self.states.len() - 1
        }
    }

    // ------------------------------------------------------------------
    // One decoding step over the whole group.
    // ------------------------------------------------------------------

    /// Returns `false` when the engine is idle (nothing pending or active).
    pub fn step(&mut self) -> Result<bool> {
        self.governor.begin_step(); // drives re-promotion probe scheduling
        self.expire_active()?;
        self.admit()?;
        let active = self.rows.active_rows();
        if active.is_empty() {
            return Ok(!self.sched.is_empty());
        }
        self.metrics
            .observe(names::BATCH_OCCUPANCY, active.len() as f64);

        // Partition the leased rows: rows whose admission prefill is still
        // in flight advance by one planner-packed chunk this step (the
        // rider leg below); only fully-prefilled rows draft and decode.
        let mut decode_active: Vec<(usize, usize)> = Vec::with_capacity(active.len());
        let mut prefill_rows: Vec<(usize, usize)> = Vec::new();
        for &(row, slot) in &active {
            let st = self.states[slot].as_ref().expect("leased slot has state");
            if st.prefilling.is_some() {
                prefill_rows.push((row, slot));
            } else {
                decode_active.push((row, slot));
            }
        }
        self.metrics
            .set_gauge(names::PREFILL_INFLIGHT_ROWS, prefill_rows.len() as i64);

        // ---- draft per active row ------------------------------------
        let gamma_cap = self.cfg.gamma.min(self.mcfg.gamma_max);
        let mut drafts: Vec<(usize, usize, Draft)> = Vec::with_capacity(decode_active.len());
        for &(row, slot) in &decode_active {
            let st = self.states[slot].as_mut().expect("leased slot has state");
            // Class-resolved depth: the controller clamps the configured
            // cap by the class's accepted-per-draft EWMA (full cap when
            // static or unseen), then the row's KV room clamps again.
            let g_class = self.gamma.resolve(&st.req.task, gamma_cap);
            // Keep a margin: the chunk writes `chunk_len` positions.
            let room = self
                .mcfg
                .max_seq
                .saturating_sub(st.cached + 2);
            let g_cap = g_class.min(room);
            let draft = if g_cap == 0 {
                Draft::empty()
            } else {
                st.drafter.draft(g_cap, st.req.params.temp)?
            };
            let cost = st.drafter.take_cost();
            self.call_log.add_draft_cost(&cost);
            st.draft_cost.merge(&cost);
            drafts.push((row, slot, draft));
        }

        // ---- plan the step ---------------------------------------------
        // Resolve each row's precision once, before planning: the variant
        // the plan prices is the variant the sub-batch executes.
        let plan_rows: Vec<PlanRow> = drafts
            .iter()
            .map(|&(_, slot, ref d)| {
                let st = self.states[slot].as_ref().expect("leased slot has state");
                PlanRow::new(d.len(), self.route_slot(&st.req.task))
            })
            .collect();
        // Prefilling rows enter the plan as pending chunks, pinned to their
        // admission variant (their KV must stay single-precision; a
        // mid-prefill governor flip would otherwise mix histories).
        let pending: Vec<PrefillPending> = prefill_rows
            .iter()
            .map(|&(_, slot)| {
                let st = self.states[slot].as_ref().expect("leased slot has state");
                let vi = self
                    .variants
                    .iter()
                    .position(|v| v.name == st.admit_variant)
                    .unwrap_or(0);
                PrefillPending {
                    remaining: st.req.prompt.len() - st.cached,
                    variant: vi,
                }
            })
            .collect();
        let plan = {
            let variant_ctxs: Vec<VariantCtx> = self
                .variants
                .iter()
                .map(|v| VariantCtx {
                    name: &v.name,
                    verify_buckets: &v.verify_buckets,
                    decode_buckets: &v.decode_buckets,
                })
                .collect();
            let ctx = PlanCtx {
                perf: &self.perf,
                variants: &variant_ctxs,
                n_layers: self.mcfg.n_layers,
                full_bucket: self.cfg.batch,
                verify_chunk: self.mcfg.verify_len(),
                elastic: self.cfg.elastic,
            };
            // A step of nothing but prefilling rows has no decode/verify
            // sub-batches to plan; riders then all run as dedicated calls.
            let mut plan = if plan_rows.is_empty() {
                StepPlan { sub_batches: Vec::new(), modeled_s: 0.0, monolithic_s: 0.0 }
            } else {
                plan_step(&ctx, &plan_rows)?
            };
            // Load-adaptive chunk sizing: when the admission queue has
            // backed up past the batch, a dedicated prefill chunk gives up
            // the full exported window and reroutes through the single-row
            // verify program instead — a much shorter chunk, so the step's
            // time bound (and every live row's TPOT) stays smooth while the
            // queue drains. Rides are unaffected (they were already capped
            // at the hosting sub-batch's chunk).
            let shed_load = self.sched.depth() > self.cfg.batch;
            pack_prefill_riders(&ctx, &mut plan, &pending, self.mcfg.prefill_len, shed_load);
            plan
        };
        self.observe_plan(&plan);
        self.trace.record(
            0,
            EventKind::Plan { subbatches: plan.sub_batches.len() as u32 },
        );
        // A dedicated admission chunk is any sub-batch carrying riders but
        // no committed rows, whatever program it executes through (the
        // full-window prefill artifact, or the verify artifact under shed).
        let dedicated =
            |sb: &SubBatch| sb.rows.is_empty() && !sb.riders.is_empty();
        if !plan_rows.is_empty() && plan.sub_batches.iter().any(dedicated) {
            // Spare capacity couldn't absorb every pending chunk: this step
            // ran a dedicated prefill call alongside live decode rows.
            self.metrics.inc(names::DECODE_STALL_STEPS, 1);
        }
        let shed_chunks = plan
            .sub_batches
            .iter()
            .filter(|sb| dedicated(sb) && sb.fn_kind != FnKind::Prefill)
            .count();
        if shed_chunks > 0 {
            self.metrics.inc(names::PREFILL_SHED_CHUNKS, shed_chunks as u64);
        }

        // ---- execute + commit each sub-batch ---------------------------
        let t0 = Instant::now();
        for sb in &plan.sub_batches {
            self.exec_sub_batch(sb, &mut drafts, &prefill_rows)?;
        }
        self.publish_kv_gauges();
        self.metrics.observe("step_s", t0.elapsed().as_secs_f64());
        Ok(true)
    }

    fn observe_plan(&self, plan: &StepPlan) {
        self.metrics
            .observe(names::SUBBATCHES_PER_STEP, plan.sub_batches.len() as f64);
        self.metrics
            .observe(names::PLANNED_SAVINGS_S, plan.monolithic_s - plan.modeled_s);
    }

    /// Run one planned sub-batch: gather its leased KV rows into a pooled
    /// bucket-shaped scratch cache, execute the chunk at the sub-batch's
    /// variant (shadow re-verifying at the other precision when the
    /// governor samples an audit or a probe is due), scatter the advanced
    /// rows back, and commit each row's verification outcome. Consumes the
    /// sub-batch's entries of `drafts` (each draft index belongs to exactly
    /// one sub-batch of a plan). Prefill riders occupy the scratch rows
    /// after `sb.rows` (`pending_rows` maps their pending index to a
    /// (row, slot) pair) and advance their admission prefill by one chunk.
    fn exec_sub_batch(
        &mut self,
        sb: &SubBatch,
        drafts: &mut [(usize, usize, Draft)],
        pending_rows: &[(usize, usize)],
    ) -> Result<()> {
        let (bucket, chunk) = (sb.bucket, sb.chunk);
        let variant = self.variants[sb.variant].name.clone();
        let row_map: Vec<usize> = sb.rows.iter().map(|&di| drafts[di].0).collect();
        // Each row paired with its committed length: gather moves only
        // valid positions, scatter only newly-advanced ones. Rider rows
        // follow the committed rows in scratch order.
        let mut row_lens: Vec<(usize, usize)> = sb
            .rows
            .iter()
            .map(|&di| {
                let (row, slot, _) = drafts[di];
                let st = self.states[slot].as_ref().expect("leased slot has state");
                (row, st.cached)
            })
            .collect();
        for r in &sb.riders {
            let (row, slot) = pending_rows[r.pending];
            let st = self.states[slot].as_ref().expect("leased slot has state");
            row_lens.push((row, st.cached));
        }

        // Identity fast path (copy-based rows only): when this sub-batch
        // executes at the full group bucket and covers *every active row*
        // in group-row order (i.e. it is the whole step's plan — always
        // true for the single-variant monolithic elastic=false shape), run
        // directly on the group cache and adopt the returned tensors — the
        // seed engine's zero-copy behavior. Adopt writes the chunk's
        // speculative output into unleased trailing rows too, which is fine
        // (join splices over them; `note_written` below keeps leave's
        // bounded zeroing honest); the all-active-rows requirement is what
        // matters: a governed step can put the remaining *leased* rows in
        // another variant's sub-batch, and adopting a whole chunk output
        // over rows this call didn't carry would overwrite their KV with
        // garbage. Page-table rows have no monolithic cache to run on, so
        // they always take the gather/scatter leg.
        let identity = matches!(self.rows, RowStore::Copy(_))
            && sb.riders.is_empty()
            && bucket == self.rows.batch()
            && row_map.len() == drafts.len()
            && row_map.iter().enumerate().all(|(i, &r)| i == r);

        // ---- gather ----------------------------------------------------
        let (sk, sv) = if identity {
            (None, None)
        } else {
            let (mut sk, mut sv) = self.model.take_scratch(&variant, self.mcfg.n_layers, bucket);
            match &self.rows {
                RowStore::Copy(g) => g.gather_rows(&row_lens, &mut sk, &mut sv)?,
                RowStore::Paged(g) => {
                    g.gather_rows(&self.prefix_cache, &row_lens, &mut sk, &mut sv)?
                }
            }
            (Some(sk), Some(sv))
        };

        // ---- assemble the sub-batch token block ------------------------
        let mut tokens = vec![0i32; bucket * chunk];
        let mut pos = vec![0i32; bucket];
        for (i, &di) in sb.rows.iter().enumerate() {
            let (_, slot, ref draft) = drafts[di];
            let st = self.states[slot].as_ref().expect("leased slot has state");
            tokens[i * chunk] = st.last_token();
            for (j, &t) in draft.tokens.iter().enumerate().take(chunk - 1) {
                tokens[i * chunk + 1 + j] = t;
            }
            pos[i] = st.cached as i32;
        }
        // Rider rows feed prompt tokens for the window `[w, w + chunk)`.
        // The start clamps to keep the chunk's writes inside the cache row;
        // a clamped window's overlap `[w, cached)` re-feeds prompt tokens
        // whose KV the row already holds — a bit-identical rewrite — and
        // only `[cached, cached + take)` is new. Positions past the prompt
        // are padding whose KV is never committed.
        let mut rider_w = vec![0usize; sb.riders.len()];
        for (ri, r) in sb.riders.iter().enumerate() {
            let (_, slot) = pending_rows[r.pending];
            let st = self.states[slot].as_ref().expect("leased slot has state");
            let w = st.cached.min(self.mcfg.max_seq.saturating_sub(chunk));
            rider_w[ri] = w;
            let i = sb.rows.len() + ri;
            for j in 0..chunk {
                let pi = w + j;
                if pi < st.req.prompt.len() {
                    tokens[i * chunk + j] = st.req.prompt[pi];
                }
            }
            pos[i] = w as i32;
        }

        // ---- execute ---------------------------------------------------
        let t0 = Instant::now();
        let (k_in, v_in) = match (&sk, &sv) {
            (Some(k), Some(v)) => (k, v),
            _ => match &self.rows {
                RowStore::Copy(g) => (&g.k, &g.v),
                RowStore::Paged(_) => unreachable!("identity fast path is copy-only"),
            },
        };
        let out = self
            .model
            .run_chunk(
                &variant,
                sb.fn_kind.name(),
                bucket,
                &tokens,
                k_in,
                v_in,
                &pos,
            )
            .with_context(|| format!("{} sub-batch b{bucket}", sb.fn_kind.name()))?;
        let wall = t0.elapsed().as_secs_f64();
        self.call_log.record(CallRecord {
            variant: variant.clone(),
            fn_kind: sb.fn_kind,
            batch: bucket,
            n_layers: self.mcfg.n_layers,
            active_rows: sb.rows.len() + sb.riders.len(),
            tokens_used: sb.tokens_used,
            chunk_len: chunk,
            useful_tokens: sb.useful_tokens,
            wall_s: wall,
        });
        self.trace.record(
            0,
            EventKind::ChunkExec {
                variant: self.trace.intern(&variant),
                func: trace_func(sb.fn_kind),
                bucket: bucket as u16,
                wall_us: (wall * 1e6) as u32,
            },
        );
        self.metrics.observe(
            &names::bucket_occupancy(bucket),
            (sb.rows.len() + sb.riders.len()) as f64,
        );
        self.metrics.inc(&names::bucket_calls(bucket), 1);
        self.metrics.inc(&names::variant_calls(&variant), 1);
        self.metrics.observe(
            names::CHUNK_EFFICIENCY,
            sb.useful_tokens as f64 / (bucket * chunk) as f64,
        );
        self.metrics.inc(names::USEFUL_POSITIONS, sb.useful_tokens as u64);
        self.metrics
            .inc(names::EXECUTED_POSITIONS, (bucket * chunk) as u64);

        // ---- fidelity governor: sampled shadow verification ------------
        // Decide whether this sub-batch gets a shadow call at the *other*
        // precision: primary sub-batches are audited at the sampled rate,
        // reference sub-batches are probed when a demoted class is due.
        // The shadow reads the same pre-advance KV (`k_in`/`v_in` are still
        // the inputs here — the primary's advanced cache lives in `out`)
        // and its own advanced cache is discarded, so audits never touch
        // committed state.
        let shadow_slot: Option<usize> = if !self.governed() || sb.rows.is_empty() {
            // Dedicated prefill sub-batches carry no committed rows, so
            // there is nothing for a shadow call to compare against.
            None
        } else if sb.variant == 0 {
            self.metrics.inc(names::GOVERNOR_ELIGIBLE, 1);
            self.governor.should_audit().then_some(1)
        } else {
            let due = sb.rows.iter().any(|&di| {
                let (_, slot, _) = drafts[di];
                let st = self.states[slot].as_ref().expect("leased slot has state");
                self.governor.probe_due(&st.req.task)
            });
            due.then_some(0)
        };
        let audit_logits: Option<Tensor<f32>> = match shadow_slot {
            None => None,
            Some(si) => {
                let sname = self.variants[si].name.clone();
                // The shadow prefers the primary call's exact shape (it can
                // then reuse the already-assembled inputs); when the shadow
                // variant doesn't export it — bucket sets may differ across
                // variants — fall back to the smallest bucket it *does*
                // export that fits these rows, so a demoted class whose
                // reference calls shrink below the quantized variant's
                // bucket set can still be probed (and re-promoted).
                let shape_ok = |b: usize| {
                    self.model
                        .entry
                        .artifact(&sname, sb.fn_kind.name(), b)
                        .map(|a| a.chunk_len == chunk)
                        .unwrap_or(false)
                };
                let shadow_bucket = if shape_ok(bucket) {
                    Some(bucket)
                } else {
                    let bl = match sb.fn_kind {
                        FnKind::Decode => &self.variants[si].decode_buckets,
                        _ => &self.variants[si].verify_buckets,
                    };
                    super::plan::best_bucket(bl, sb.rows.len())
                        .filter(|&b| b >= sb.rows.len() && shape_ok(b))
                };
                match shadow_bucket {
                    None => {
                        // Nothing the shadow variant exports can carry these
                        // rows; skip. A skipped *probe* still consumes its
                        // schedule slot — otherwise the due classes would
                        // re-attempt (and re-lookup) on every reference
                        // sub-batch.
                        self.metrics.inc(names::GOVERNOR_AUDIT_SKIPPED, 1);
                        if si == 0 {
                            for &di in &sb.rows {
                                let (_, slot, _) = drafts[di];
                                let class = self.states[slot]
                                    .as_ref()
                                    .expect("leased slot has state")
                                    .req
                                    .task
                                    .clone();
                                // only the classes whose probe this *was*; a
                                // co-located demoted class that wasn't due
                                // yet keeps its own (earlier) schedule
                                if self.governor.probe_due(&class) {
                                    self.governor.defer_probe(&class);
                                }
                            }
                        }
                        None
                    }
                    Some(ab) => {
                        let t0 = Instant::now();
                        let aout = if ab == bucket {
                            self.model
                                .run_chunk(
                                    &sname, sb.fn_kind.name(), bucket, &tokens, k_in, v_in,
                                    &pos,
                                )
                                .with_context(|| format!("governor audit b{bucket}"))?
                        } else {
                            // Re-gather the same pre-advance rows (the
                            // primary's scatter/adopt hasn't happened yet)
                            // into the shadow variant's own bucket shape;
                            // row order matches the primary call, so logits
                            // row `i` compares one-to-one below.
                            let n = sb.rows.len();
                            let (mut ak, mut av) =
                                self.model.take_scratch(&sname, self.mcfg.n_layers, ab);
                            match &self.rows {
                                RowStore::Copy(g) => {
                                    g.gather_rows(&row_lens, &mut ak, &mut av)?
                                }
                                RowStore::Paged(g) => g.gather_rows(
                                    &self.prefix_cache, &row_lens, &mut ak, &mut av,
                                )?,
                            }
                            let mut atokens = vec![0i32; ab * chunk];
                            atokens[..n * chunk].copy_from_slice(&tokens[..n * chunk]);
                            let mut apos = vec![0i32; ab];
                            apos[..n].copy_from_slice(&pos[..n]);
                            let aout = self
                                .model
                                .run_chunk(
                                    &sname, sb.fn_kind.name(), ab, &atokens, &ak, &av, &apos,
                                )
                                .with_context(|| format!("governor audit b{ab}"))?;
                            self.model.return_scratch(&sname, ak, av);
                            aout
                        };
                        let wall = t0.elapsed().as_secs_f64();
                        self.call_log.record(CallRecord {
                            variant: sname.clone(),
                            fn_kind: FnKind::Audit,
                            batch: ab,
                            n_layers: self.mcfg.n_layers,
                            active_rows: sb.rows.len(),
                            tokens_used: sb.tokens_used,
                            chunk_len: chunk,
                            useful_tokens: sb.useful_tokens,
                            wall_s: wall,
                        });
                        // Sampled audits (primary sub-batch) and scheduled
                        // probes (reference sub-batch) are tallied
                        // separately: audits/eligible is the sampled rate,
                        // probes follow their own per-class cadence.
                        if sb.variant == 0 {
                            self.metrics.inc(names::GOVERNOR_AUDITS, 1);
                        } else {
                            self.metrics.inc(names::GOVERNOR_PROBES, 1);
                        }
                        self.trace.record(0, EventKind::Audit);
                        self.metrics.inc(&names::variant_calls(&sname), 1);
                        self.model.return_scratch(&sname, aout.k, aout.v);
                        Some(aout.logits)
                    }
                }
            }
        };

        // ---- scatter / adopt the advanced rows -------------------------
        // The chunk wrote positions `[cached, cached + chunk)` per carried
        // row; everything below was already committed before the call.
        if let (Some(sk), Some(sv)) = (sk, sv) {
            // Per scratch row, the first position past this call's committed
            // write: the full speculative window for decode/verify rows, but
            // only the rider's `take` — the window tail past the prompt is
            // padding garbage that must never land in a row.
            let max_seq = self.mcfg.max_seq;
            let write_end = move |i: usize, c: usize| {
                if i < sb.rows.len() {
                    (c + chunk).min(max_seq)
                } else {
                    c + sb.riders[i - sb.rows.len()].take
                }
            };
            match &mut self.rows {
                RowStore::Copy(g) => {
                    // The slab backend re-writes the whole valid extent:
                    // scratch `[0, cached + chunk)` is bit-identical to the
                    // row's committed prefix plus the chunk's advance.
                    let write_back: Vec<(usize, usize)> = row_lens
                        .iter()
                        .enumerate()
                        .map(|(i, &(r, c))| (r, write_end(i, c)))
                        .collect();
                    g.scatter_rows(&write_back, &out.k, &out.v)?;
                }
                RowStore::Paged(g) => {
                    // Delta-only write-back: just the advanced range lands
                    // in private frontier pages; committed pages are
                    // immutable and never touched. The committed prefix the
                    // slab backend would have re-copied is booked as saved.
                    let advances: Vec<(usize, usize, usize)> = row_lens
                        .iter()
                        .enumerate()
                        .map(|(i, &(r, c))| (r, c, write_end(i, c)))
                        .collect();
                    g.scatter_advance(&mut self.prefix_cache, &advances, &out.k, &out.v)?;
                    let page = self.cfg.prefix.page_tokens.max(1);
                    let saved: f64 = row_lens
                        .iter()
                        .map(|&(_, c)| {
                            self.perf.splice_time(self.mcfg.n_layers, c, page)
                        })
                        .sum();
                    if saved > 0.0 {
                        self.metrics.observe(names::KV_COPY_SAVED_S, saved);
                    }
                }
            }
            self.model.return_scratch(&variant, sk, sv);
            self.model.return_scratch(&variant, out.k, out.v);
        } else {
            // identity fast path: the advanced cache *is* the group cache
            // (run() already validated its dims against the bucket shape)
            let RowStore::Copy(g) = &mut self.rows else {
                unreachable!("identity fast path is copy-only");
            };
            g.k = out.k;
            g.v = out.v;
            // The adopted chunk output wrote `[pos, pos + chunk)` into
            // *every* bucket row — unleased rows ran at pos 0 — so record
            // each row's high-water mark for leave's bounded zeroing.
            for r in 0..g.batch {
                let wrote = row_lens
                    .iter()
                    .find(|&&(rr, _)| rr == r)
                    .map(|&(_, c)| c + chunk)
                    .unwrap_or(chunk);
                g.note_written(r, wrote.min(self.mcfg.max_seq));
            }
        }

        self.trace.record(0, EventKind::Scatter);

        // ---- commit per row --------------------------------------------
        // Per-class audit accumulator for this shadow call: however many
        // rows a class had in the sub-batch, it contributes ONE sample to
        // the governor — a single shadow execution's rows are correlated
        // evidence and must not fill the `min_audits` hysteresis window by
        // themselves. (class, agreeing positions, verified positions,
        // accept-delta sum, rows)
        let mut audit_acc: Vec<(String, usize, usize, i64, u32)> = Vec::new();
        let mut snapshotted = false;
        for (i, &di) in sb.rows.iter().enumerate() {
            let (row, slot, _) = drafts[di];
            let draft = std::mem::take(&mut drafts[di].2);
            let st = self.states[slot].as_mut().expect("leased slot has state");
            // Variant-history tracking for mid-stream snapshots: a row that
            // ever executes at a second precision has mixed-variant KV and
            // must never be cached.
            if st.admit_variant != variant {
                st.kv_mixed = true;
            }
            let logits = &out.logits;
            // Clone the request RNG *before* the committed verification
            // consumes it, so a shadow verification replays the same
            // stochastic accept/resample choices against the other
            // variant's logits (apples-to-apples acceptance delta).
            let mut shadow_rng = audit_logits.as_ref().map(|_| st.rng.clone());
            let outcome = verify_draft(
                &draft,
                |j| logits.row(&[i, j]),
                st.req.params.temp,
                &mut st.rng,
            );

            // On a reference sub-batch the shadow ran because *some* class
            // was probe-due; only rows whose class is itself due contribute
            // (the flush below reschedules it) — co-located demoted classes
            // keep their own probe cadence.
            let row_records = audit_logits.is_some()
                && (sb.variant == 0 || self.governor.probe_due(&st.req.task));
            if let (true, Some(al), Some(srng)) =
                (row_records, &audit_logits, shadow_rng.as_mut())
            {
                // Top-1 agreement over this row's verified positions (the
                // paper's §4.5 "does quantization flip the top-1" criterion,
                // measured online) plus the acceptance-length delta.
                let positions = draft.len().min(chunk - 1) + 1;
                let agree = (0..positions)
                    .filter(|&j| {
                        crate::spec::argmax(logits.row(&[i, j]))
                            == crate::spec::argmax(al.row(&[i, j]))
                    })
                    .count();
                let ref_outcome = verify_draft(
                    &draft,
                    |j| al.row(&[i, j]),
                    st.req.params.temp,
                    srng,
                );
                // Delta is always quantized − reference, whichever side the
                // shadow ran on this sub-batch.
                let (q_acc, f_acc) = if sb.variant == 0 {
                    (outcome.accepted, ref_outcome.accepted)
                } else {
                    (ref_outcome.accepted, outcome.accepted)
                };
                let delta = q_acc as i64 - f_acc as i64;
                let class = &st.req.task;
                match audit_acc.iter_mut().find(|e| e.0 == *class) {
                    Some(e) => {
                        e.1 += agree;
                        e.2 += positions;
                        e.3 += delta;
                        e.4 += 1;
                    }
                    None => audit_acc.push((class.clone(), agree, positions, delta, 1)),
                }
            }

            let mut commit: Vec<i32> =
                draft.tokens[..outcome.accepted].to_vec();
            commit.push(outcome.next_token);
            // Clamp to the generation budget.
            let budget = st.req.params.max_new - st.generated;
            commit.truncate(budget);
            // Cut at <eos> (keep it).
            if st.req.params.stop_at_eos {
                crate::spec::truncate_at_eos(&mut commit);
            }
            let n_commit = commit.len();
            let accepted_kept = n_commit.saturating_sub(1).min(outcome.accepted);

            st.committed.extend_from_slice(&commit);
            st.cached += n_commit; // KV for these positions was just written
            if let RowStore::Paged(g) = &mut self.rows {
                // Advance the row's committed length over pages the
                // scatter already populated; rejected speculative tail
                // positions stay unreachable garbage in the frontier page.
                g.set_len(row, st.cached)?;
            }
            st.generated += n_commit;
            st.stats.steps += 1;
            st.stats.tokens_out += n_commit as u64;
            st.stats.drafted += draft.len() as u64;
            st.stats.accepted += accepted_kept as u64;
            self.trace.record(
                st.req.id,
                EventKind::Commit { accepted: accepted_kept as u32 },
            );
            if draft.is_empty() {
                st.stats.draft_misses += 1;
            }
            st.drafter.observe_commit(&commit)?;
            st.drafter.observe_outcome(draft.len(), outcome.accepted);
            // Feed the class controller the same outcome the drafter sees:
            // the depth prior survives this request and seeds the class's
            // next admission. Recorded even in static mode — the per-class
            // acceptance stats flow to `{"cmd":"stats"}` either way; only
            // `resolve` (above) acts on them.
            self.gamma.record(&st.req.task, draft.len(), outcome.accepted);

            Self::check_finish_with(self.mcfg.max_seq, st);
            if !st.is_active() {
                // Mid-stream snapshot: before the row's KV is freed, extend
                // the request's cached run with *full pages* of its
                // generated continuation, so a multi-turn resubmit
                // (prompt ++ answer ++ follow-up) hits past the prompt.
                // Only single-variant rows qualify (see `kv_mixed`), only
                // positions with committed KV (`0..cached`) are cacheable,
                // and partial tail pages are left to the next admission's
                // prompt snapshot — full pages keep the pool churn-free.
                if self.cfg.prefix.enabled
                    && self.cfg.prefix.mid_stream
                    && !st.kv_mixed
                    && st.finished != Some(FinishReason::Cancelled)
                {
                    match &self.rows {
                        RowStore::Copy(g) => {
                            // The slab backend copies pages into the pool,
                            // so only full pages are worth the churn.
                            let page = self.cfg.prefix.page_tokens.max(1);
                            let key_len = (st.cached / page) * page;
                            if key_len > st.req.prompt.len() {
                                self.prefix_cache.insert_from_row(
                                    &variant,
                                    &st.committed[..key_len],
                                    &g.k,
                                    &g.v,
                                    row,
                                    Some(st.req.prompt.len()),
                                );
                                snapshotted = true;
                            }
                        }
                        RowStore::Paged(g) => {
                            // Zero-copy snapshot: the run *references* the
                            // row's own pages — partial tail included,
                            // since the run key's length bounds what a
                            // future splice reads (garbage past `cached`
                            // in the tail page is never served).
                            if st.cached > st.req.prompt.len() {
                                let key = &st.committed[..st.cached];
                                let covered = self
                                    .prefix_cache
                                    .find(&variant, key)
                                    .is_some_and(|(_, m)| m >= st.cached);
                                let pages =
                                    g.row_pages(row).expect("leased row has pages");
                                self.prefix_cache.insert_pages(
                                    &variant,
                                    key,
                                    pages,
                                    Some(st.req.prompt.len()),
                                );
                                if !covered {
                                    let page = self.cfg.prefix.page_tokens.max(1);
                                    let saved = self.perf.kv_move_time(
                                        self.mcfg.n_layers,
                                        st.cached.div_ceil(page),
                                        page,
                                    );
                                    self.metrics.observe(names::KV_COPY_SAVED_S, saved);
                                }
                                snapshotted = true;
                            }
                        }
                    }
                }
                self.rows.leave(&mut self.prefix_cache, row)?;
                let st = self.states[slot].take().unwrap();
                self.finish_to_completion(st);
            }
        }

        // ---- advance prefill riders ------------------------------------
        // Each rider consumed one chunk of its admission prefill: commit
        // the newly-covered positions, and once the prompt completes,
        // sample the first token from this chunk's logits (position
        // `(len - 1) - w` of the rider's scratch row is prompt position
        // `len - 1`) — the same draw, from the same per-request RNG, over
        // the same logits the monolithic admission prefill produces.
        for (ri, r) in sb.riders.iter().enumerate() {
            let (row, slot) = pending_rows[r.pending];
            let w = rider_w[ri];
            let st = self.states[slot].as_mut().expect("leased slot has state");
            let prog = st.prefilling.as_mut().expect("rider row is prefilling");
            prog.consumed += r.take;
            st.cached += r.take;
            if let RowStore::Paged(g) = &mut self.rows {
                g.set_len(row, st.cached)?;
            }
            self.metrics.inc(names::PREFILL_CHUNKS, 1);
            // How this chunk executed: riding a spare slot of a live
            // decode/verify sub-batch, as a dedicated prefill call, or shed
            // to the shorter verify program under queue pressure.
            let mode = if !sb.rows.is_empty() {
                PrefillMode::Ridden
            } else if sb.fn_kind == FnKind::Prefill {
                PrefillMode::Dedicated
            } else {
                PrefillMode::Shed
            };
            self.trace
                .record(st.req.id, EventKind::PrefillChunk { mode });
            if r.saved_s > 0.0 {
                self.metrics.observe(names::PREFILL_STALL_SAVED_S, r.saved_s);
            }
            let len = st.req.prompt.len();
            if st.cached < len {
                continue; // more chunks to come on later steps
            }

            // Prompt complete: first token, then the row decodes normally.
            let scratch_row = sb.rows.len() + ri;
            let first = {
                let lrow = out.logits.row(&[scratch_row, (len - 1) - w]);
                crate::spec::sample_logits(lrow, st.req.params.temp, &mut st.rng)
            };
            st.prefilling = None;
            st.committed.push(first);
            st.generated = 1;
            st.stats.steps += 1;
            st.stats.tokens_out += 1;
            st.first_token_at = Some(Instant::now());
            st.drafter.observe_commit(&[first])?;
            let cost = st.drafter.take_cost();
            self.call_log.add_draft_cost(&cost);
            st.draft_cost.merge(&cost);
            Self::check_finish_with(self.mcfg.max_seq, st);

            // Feed the cache forward, as monolithic admission does once its
            // prefill lands: future admissions sharing this prefix skip
            // that much work.
            if self.cfg.prefix.enabled {
                match &self.rows {
                    RowStore::Copy(g) => {
                        // The slab row holds the whole prompt's KV; copy it
                        // into the pool under the full-prompt key.
                        self.prefix_cache.insert_from_row(
                            &variant, &st.req.prompt, &g.k, &g.v, row, None,
                        );
                        snapshotted = true;
                    }
                    RowStore::Paged(g) => {
                        // Reference the row's own pages — but only *full*
                        // ones: the partial tail page is this live row's
                        // private growth frontier, and sharing it would make
                        // the row's next `write_row_page` hard-error.
                        let page = self.cfg.prefix.page_tokens.max(1);
                        let key_len = (len / page) * page;
                        if key_len > 0 {
                            let pages = g.row_pages(row).expect("leased row has pages");
                            self.prefix_cache.insert_pages(
                                &variant, &st.req.prompt[..key_len], pages, None,
                            );
                            snapshotted = true;
                        }
                    }
                }
            }
            if !st.is_active() {
                self.rows.leave(&mut self.prefix_cache, row)?;
                let st = self.states[slot].take().unwrap();
                self.finish_to_completion(st);
            }
        }
        if snapshotted {
            self.publish_prefix_gauges();
            self.publish_kv_gauges();
        }

        // ---- flush audit samples: one per (class, shadow call) ---------
        for (class, agree, positions, delta_sum, rows) in audit_acc {
            let agreement = agree as f64 / positions.max(1) as f64;
            let delta = delta_sum as f64 / rows.max(1) as f64;
            self.metrics.observe(names::GOVERNOR_AGREEMENT, agreement);
            self.metrics.observe(names::GOVERNOR_ACCEPT_DELTA, delta);
            match self.governor.record_audit(&class, agreement, delta) {
                Some(Transition::Demoted) => {
                    self.metrics.inc(names::GOVERNOR_DEMOTIONS, 1);
                    self.trace.record(0, EventKind::Demote);
                }
                Some(Transition::Promoted) => {
                    self.metrics.inc(names::GOVERNOR_PROMOTIONS, 1);
                    self.trace.record(0, EventKind::Promote);
                }
                None => {}
            }
        }
        Ok(())
    }

    fn check_finish_with(max_seq: usize, st: &mut RequestState) {
        if st.finished.is_some() {
            return;
        }
        if st.req.params.stop_at_eos && st.committed.last() == Some(&EOS_ID) {
            st.finished = Some(FinishReason::Eos);
        } else if st.generated >= st.req.params.max_new {
            st.finished = Some(FinishReason::MaxNewTokens);
        } else if st.cached + 2 >= max_seq {
            st.finished = Some(FinishReason::ContextFull);
        }
    }

    fn finish_to_completion(&mut self, st: RequestState) {
        let now = Instant::now();
        let latency = now.duration_since(st.req.submitted_at).as_secs_f64();
        let ttft = st
            .first_token_at
            .map(|t| t.duration_since(st.req.submitted_at).as_secs_f64())
            .unwrap_or(latency);
        self.metrics.inc("requests_completed", 1);
        self.metrics.inc("tokens_generated", st.generated as u64);
        if st.finished == Some(FinishReason::Cancelled) {
            self.metrics.inc("requests_cancelled", 1);
        }
        self.metrics.observe("request_latency_s", latency);
        self.metrics.observe("ttft_s", ttft);
        // Warm/cold split, keyed on whether admission matched a cached
        // prefix: chunked prefill's whole point is that warm requests admit
        // (and reach their first token) earlier, and the aggregate TTFT
        // histogram averages that signal away.
        let tpot = (latency - ttft).max(0.0) / st.generated.saturating_sub(1).max(1) as f64;
        if st.prefix_hit {
            self.metrics.observe(names::TTFT_WARM_S, ttft);
            self.metrics.observe(names::TPOT_WARM_S, tpot);
        } else {
            self.metrics.observe(names::TTFT_COLD_S, ttft);
            self.metrics.observe(names::TPOT_COLD_S, tpot);
        }
        // Stage attribution: the stages partition `[submitted_at, now]`
        // exactly (`dispatch + queue + splice + prefill + decode = latency`;
        // the router adds `emit_s` — and the same amount to `latency_s` —
        // at delivery). `prefill_s` nets out the measured splice; the clamp
        // only matters when the splice measurably exceeded admission→first
        // token, which float rounding can produce on instant requests.
        let admitted = st.admitted_at.unwrap_or(now);
        let first = st.first_token_at.unwrap_or(now);
        let dispatch_s = st.req.enqueued_at.duration_since(st.req.submitted_at).as_secs_f64();
        let queue_s = admitted.duration_since(st.req.enqueued_at).as_secs_f64();
        let raw_prefill = first.duration_since(admitted).as_secs_f64();
        let splice_s = st.splice_s.min(raw_prefill);
        let stages = StageBreakdown {
            dispatch_s,
            queue_s,
            splice_s,
            prefill_s: (raw_prefill - splice_s).max(0.0),
            decode_s: now.duration_since(first).as_secs_f64(),
            emit_s: 0.0,
        };
        self.completions.push(Completion {
            id: st.req.id,
            task: st.req.task.clone(),
            prompt_len: st.req.prompt.len(),
            tokens: st.committed[st.req.prompt.len()..].to_vec(),
            finish: st.finished.unwrap_or(FinishReason::MaxNewTokens),
            stats: st.stats.clone(),
            draft_cost: st.draft_cost,
            sched_delay_s: st.sched_delay_s,
            latency_s: latency,
            ttft_s: ttft,
            stages,
            finished_at: now,
        });
    }

    /// Drive until every submitted request completes; returns completions in
    /// finish order.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.in_flight() > 0 {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    /// Convenience for benches: submit-then-drain.
    pub fn generate(
        &mut self,
        prompts: Vec<(Vec<i32>, GenParams, String)>,
    ) -> Result<Vec<Completion>> {
        for (p, params, task) in prompts {
            self.submit(p, params, &task);
        }
        self.run_to_completion()
    }
}

//! Request router: the thread-safe front door.
//!
//! The `Engine` is single-threaded around the PJRT client (and `!Send` by
//! construction), so the router owns it on a dedicated thread and exposes a
//! channel-based handle: submissions in, completions out, with bounded
//! admission (backpressure) and graceful shutdown. The TCP server and the
//! benches both talk to this handle.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::engine::{Engine, EngineConfig};
use super::request::{Completion, GenParams};

enum Msg {
    Submit { prompt: Vec<i32>, params: GenParams, task: String, reply: Sender<u64> },
    Shutdown,
}

/// Handle to an engine running on its own thread.
pub struct EngineHandle {
    tx: Sender<Msg>,
    completions: Receiver<Completion>,
    join: Option<JoinHandle<Result<()>>>,
    /// Soft cap on in-flight submissions (admission control).
    max_queue: usize,
    queued: std::cell::Cell<usize>,
}

impl EngineHandle {
    /// Spawn the engine thread. `artifacts` is the manifest root; engine
    /// construction happens on the thread (the PJRT client is not `Send`).
    pub fn spawn(artifacts: PathBuf, model: String, cfg: EngineConfig,
                 max_queue: usize) -> Result<Self> {
        let (tx, rx) = channel::<Msg>();
        let (done_tx, done_rx) = channel::<Completion>();
        let join = std::thread::Builder::new()
            .name("quasar-engine".into())
            .spawn(move || -> Result<()> {
                let rt = std::rc::Rc::new(crate::runtime::XlaRuntime::cpu()?);
                let manifest = crate::runtime::Manifest::load(&artifacts)?;
                let mr = std::rc::Rc::new(crate::runtime::ModelRuntime::load(
                    rt, &manifest, &model,
                )?);
                let mut engine = Engine::new(mr, cfg)?;
                loop {
                    // Drain control messages without blocking the decode loop.
                    let mut shutdown = false;
                    loop {
                        match rx.try_recv() {
                            Ok(Msg::Submit { prompt, params, task, reply }) => {
                                let id = engine.submit(prompt, params, &task);
                                let _ = reply.send(id);
                            }
                            Ok(Msg::Shutdown) | Err(TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                            Err(TryRecvError::Empty) => break,
                        }
                    }
                    if shutdown && engine.in_flight() == 0 {
                        return Ok(());
                    }
                    if engine.in_flight() > 0 {
                        engine.step()?;
                        for c in engine.take_completions() {
                            let _ = done_tx.send(c);
                        }
                    } else {
                        // Idle: block briefly for the next submission.
                        match rx.recv_timeout(Duration::from_millis(5)) {
                            Ok(Msg::Submit { prompt, params, task, reply }) => {
                                let id = engine.submit(prompt, params, &task);
                                let _ = reply.send(id);
                            }
                            Ok(Msg::Shutdown) => return Ok(()),
                            Err(_) => {}
                        }
                    }
                }
            })?;
        Ok(EngineHandle {
            tx,
            completions: done_rx,
            join: Some(join),
            max_queue,
            queued: std::cell::Cell::new(0),
        })
    }

    /// Submit; `Err` when the admission queue is full (backpressure) or the
    /// engine thread is gone.
    pub fn submit(&self, prompt: Vec<i32>, params: GenParams, task: &str) -> Result<u64> {
        if self.queued.get() >= self.max_queue {
            return Err(anyhow!("admission queue full ({} in flight)", self.queued.get()));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Submit { prompt, params, task:
                task.to_string(), reply: reply_tx })
            .map_err(|_| anyhow!("engine thread terminated"))?;
        let id = reply_rx
            .recv_timeout(Duration::from_secs(10))
            .map_err(|_| anyhow!("engine did not ack submission"))?;
        self.queued.set(self.queued.get() + 1);
        Ok(id)
    }

    /// Non-blocking poll for a finished request.
    pub fn try_next_completion(&self) -> Option<Completion> {
        match self.completions.try_recv() {
            Ok(c) => {
                self.queued.set(self.queued.get().saturating_sub(1));
                Some(c)
            }
            Err(_) => None,
        }
    }

    /// Blocking wait (with timeout) for a finished request.
    pub fn next_completion(&self, timeout: Duration) -> Option<Completion> {
        match self.completions.recv_timeout(timeout) {
            Ok(c) => {
                self.queued.set(self.queued.get().saturating_sub(1));
                Some(c)
            }
            Err(_) => None,
        }
    }

    pub fn in_flight(&self) -> usize {
        self.queued.get()
    }

    /// Graceful shutdown: drain in-flight work, then join.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

//! Request router: the thread-safe front door to **one engine replica**.
//!
//! The `Engine` is single-threaded around the PJRT client (and `!Send` by
//! construction), so the router owns it on a dedicated thread and exposes
//! [`EngineHandle`], which is `Sync`: any number of submitter threads share
//! one handle directly — no outer mutex, and nothing is ever locked across
//! generation.
//!
//! In the two-tier topology (`coordinator::cluster`) this layer is the
//! *bottom* tier: the dispatch plane owns N `EngineHandle`s — one per
//! replica, each with its own engine thread, scheduler, governor, and paged
//! KV pool — and routes every submit/cancel above them. Nothing here knows
//! about the fleet beyond two identity threads: `EngineConfig::replica`
//! lands in [`RouterStats`]/[`StatsSnapshot`] so a per-replica breakdown
//! can say who is who, and engine-thread *construction* is serialized
//! process-wide (see `spawn`) because PJRT client creation is the one
//! non-reentrant step of boot. Steady-state replicas never share state —
//! cross-replica aggregation happens entirely in the cluster layer by
//! reading each replica's lock-free stats block.
//!
//! Delivery is *correlated*: every submission gets a private reply channel,
//! and the engine thread routes each [`Completion`] to the channel keyed by
//! its request id. A submitter blocks only on its own [`Ticket`], so slow
//! requests never steal another connection's completion. The handle also
//! carries a cancellation path (drops queued requests, frees running ones'
//! KV rows) and a lock-free [`RouterStats`] block (queue depth, batch
//! occupancy, scheduling delay) that the server's `stats` endpoint reads
//! without disturbing the engine.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::MetricsDump;
use crate::trace::{EventKind, FlightRecorder, TraceHandle};
use crate::util::hist::Histogram;
use crate::util::json::Json;

use super::engine::{Engine, EngineConfig};
use super::request::{Completion, FinishReason, GenParams};

enum Msg {
    Submit {
        prompt: Vec<i32>,
        params: GenParams,
        task: String,
        /// Wall-clock instant the submitter called [`EngineHandle::submit`];
        /// the engine anchors the request's `submitted_at` here so the stage
        /// breakdown's `dispatch_s` covers channel + handoff time.
        sent_at: Instant,
        ack: Sender<u64>,
        done: Sender<Completion>,
    },
    /// Snapshot the engine's full metrics registry (counters, gauges, and
    /// raw histograms) for Prometheus exposition and fleet-level merging.
    Scrape {
        ack: Sender<MetricsDump>,
    },
    Cancel {
        id: u64,
    },
    /// Boot warm-up: prefill + cache these `(template ids, task)` pairs in
    /// the prefix cache before traffic (see [`Engine::warm_prefix`]).
    Warm {
        templates: Vec<(Vec<i32>, String)>,
        ack: Sender<usize>,
    },
    Shutdown,
}

/// Per-bucket execution tally the elastic step planner produces (one entry
/// per bucket the engine has executed at least one call at).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BucketStat {
    pub bucket: usize,
    /// Calls executed at this bucket.
    pub calls: u64,
    /// Mean rows actually carried per call at this bucket.
    pub mean_rows: f64,
}

/// Per-variant execution tally (decode/verify/audit chunk calls that
/// streamed this variant's weights).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VariantCalls {
    pub variant: String,
    pub calls: u64,
}

/// Point-in-time view of the fidelity governor (see
/// `coordinator::governor`): how often the quantized verifier is being
/// audited, how well it agrees with the reference, and how many classes
/// have been demoted/re-promoted.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GovernorSnapshot {
    /// Sampled shadow audits of primary-variant sub-batches.
    pub audits: u64,
    /// Scheduled re-promotion probes of demoted (reference) sub-batches.
    pub probes: u64,
    /// audits / eligible primary sub-batches (0 when nothing was eligible;
    /// probes follow their own cadence and are excluded from this rate).
    pub audit_rate: f64,
    /// Mean per-row top-1 agreement over audited positions.
    pub top1_agreement: f64,
    /// Mean acceptance-length delta, quantized − reference (negative =
    /// quantization costs accepted tokens).
    pub accept_delta: f64,
    pub demotions: u64,
    pub promotions: u64,
}

/// One class's entry in the per-class draft-depth controller view (see
/// `coordinator::gamma`): the accepted-per-draft EWMA that sets the class's
/// speculation depth, plus lifetime draft/accept tallies.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GammaClassStat {
    /// Request class (the client task tag; `<overflow>` folds excess tags).
    pub class: String,
    /// Accepted-per-draft EWMA driving `resolve`.
    pub accept_ewma: f64,
    /// Drafting steps observed.
    pub steps: u64,
    /// Lifetime drafted tokens.
    pub drafted: u64,
    /// Lifetime accepted tokens.
    pub accepted: u64,
}

/// Point-in-time view of the shared-prefix KV cache (see
/// `coordinator::prefixcache`): how much admission prefill is being served
/// from cached committed prefixes, and what that working set costs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixSnapshot {
    /// Admissions that matched a cached prefix (suffix-only prefill).
    pub hits: u64,
    /// Admissions with no usable cached prefix.
    pub misses: u64,
    /// hits / (hits + misses); 0 before any admission.
    pub hit_rate: f64,
    /// Prompt tokens served from cached KV instead of prefill.
    pub hit_tokens: u64,
    /// Subset of `hit_tokens` served by runs extended with generated
    /// continuations (mid-stream snapshots): depth multi-turn resubmits
    /// gained past their original prompts.
    pub mid_stream_hit_tokens: u64,
    /// Bytes of KV pages resident in the cache's pool.
    pub resident_bytes: u64,
    /// Pages resident in the cache's pool.
    pub resident_pages: u64,
    /// Run→page references per resident page: 1.0 = no sharing, higher =
    /// one physical page backing several cached prefixes.
    pub page_share_ratio: f64,
    /// Page-runs (cached prefixes) resident in the cache.
    pub segments: u64,
    /// Runs evicted by the byte-budget LRU so far.
    pub evictions: u64,
    /// Modeled prefill seconds saved by suffix-only admission (sum of the
    /// `prefill_saved_s` histogram).
    pub prefill_saved_s: f64,
}

/// Point-in-time view of KV residency and the page-table row backend (see
/// `coordinator::kv`): what the serving working set costs and how much of
/// it is shared by reference instead of copied.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvSnapshot {
    /// Whether batch rows are page-tables over the shared pool (vs the
    /// copy-based slab reference).
    pub paged_rows: bool,
    /// Bytes of KV resident (pool pages; plus the whole batch slab under
    /// the copy-based backend).
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` — the A/B comparison figure.
    pub resident_peak_bytes: u64,
    /// Page references held by live batch rows.
    pub row_page_refs: u64,
    /// Row page-table entries installed by refcount bump (zero-copy).
    pub row_shared_pages: u64,
    /// Full pages copied building row page-tables (0 on a warmed run).
    pub row_copied_pages: u64,
    /// Partial tail pages copied building row page-tables.
    pub row_tail_copies: u64,
    /// Modeled seconds of KV copies the page-table backend avoided by
    /// referencing pages instead of moving them (sum of the
    /// `kv_copy_saved_s` histogram): admission splices, committed prefixes
    /// delta-only scatters skipped, by-reference finish snapshots.
    pub copy_saved_s: f64,
}

/// Point-in-time view of chunked admission prefill (see
/// `coordinator::engine`): how much prompt ingestion rode spare
/// decode/verify slots instead of stalling decode, and what first-token /
/// per-token latency looks like split by prefix-cache warmth.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefillSnapshot {
    /// Prefill chunks executed (riders, dedicated fallbacks, and
    /// monolithic admission windows all count).
    pub chunks: u64,
    /// Rows currently admitted but still prefilling chunk-by-chunk.
    pub inflight_rows: u64,
    /// Steps in which a dedicated prefill call ran while decode rows were
    /// active — the stall chunked prefill exists to remove.
    pub decode_stall_steps: u64,
    /// Modeled seconds of stall avoided by chunks that rode spare slots
    /// (sum of the `prefill_stall_saved_s` histogram).
    pub stall_saved_s: f64,
    /// TTFT percentiles; warm = admission hit the prefix cache.
    pub ttft_warm_p50_s: f64,
    pub ttft_warm_p99_s: f64,
    pub ttft_cold_p50_s: f64,
    pub ttft_cold_p99_s: f64,
    /// Per-token decode latency percentiles on the same warm/cold split.
    pub tpot_warm_p50_s: f64,
    pub tpot_warm_p99_s: f64,
    pub tpot_cold_p50_s: f64,
    pub tpot_cold_p99_s: f64,
}

/// Provenance echo of the serving configuration, published once at spawn
/// and carried through `stats` so an operator (or a benchmark harness) can
/// tell *what* produced a stats block without cross-referencing the launch
/// command line. The cluster layer patches `dispatch` with its policy name;
/// a bare engine reports `"none"`.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigEcho {
    /// Verifier variant the engine was configured with (`fp32`, `w8a8`, …).
    pub method: String,
    pub batch: usize,
    pub replicas: usize,
    /// Cluster dispatch policy name; `"none"` outside a cluster.
    pub dispatch: String,
    pub paged_rows: bool,
    pub chunked_prefill: bool,
    /// Whether the per-class draft-depth controller adapts gamma (off =
    /// static depth, the A/B reference; see `coordinator::gamma`).
    pub adaptive_gamma: bool,
    /// Whether the flight recorder is armed (see [`crate::trace`]).
    pub trace: bool,
}

impl Default for ConfigEcho {
    fn default() -> Self {
        ConfigEcho {
            method: String::new(),
            batch: 0,
            replicas: 1,
            dispatch: "none".to_string(),
            paged_rows: false,
            chunked_prefill: false,
            adaptive_gamma: false,
            trace: false,
        }
    }
}

impl ConfigEcho {
    fn from_cfg(cfg: &EngineConfig) -> Self {
        ConfigEcho {
            method: cfg.verifier.clone(),
            batch: cfg.batch,
            replicas: cfg.replicas,
            dispatch: "none".to_string(),
            paged_rows: cfg.paged_rows,
            chunked_prefill: cfg.chunked_prefill,
            adaptive_gamma: cfg.adaptive_gamma,
            trace: cfg.trace,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(self.method.clone())),
            ("batch", Json::num(self.batch as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("dispatch", Json::str(self.dispatch.clone())),
            ("paged_rows", Json::Bool(self.paged_rows)),
            ("chunked_prefill", Json::Bool(self.chunked_prefill)),
            ("adaptive_gamma", Json::Bool(self.adaptive_gamma)),
            ("trace", Json::Bool(self.trace)),
        ])
    }
}

/// Lock-free counters the engine thread publishes after every step and any
/// thread may read at any time (the server's `stats` endpoint). The
/// per-bucket tallies are the one mutex-guarded piece; they are written only
/// by the engine thread and read only by `stats`, never on the request path.
#[derive(Default)]
pub struct RouterStats {
    /// Which fleet replica this stats block belongs to (`EngineConfig::
    /// replica`; 0 for a bare single engine). Set once at spawn.
    pub replica: AtomicUsize,
    /// Submitted but not yet completed (queued + running).
    pub in_flight: AtomicUsize,
    /// Requests waiting in the scheduler.
    pub queue_depth: AtomicUsize,
    /// Requests currently holding a KV row.
    pub active_rows: AtomicUsize,
    /// Batch bucket the engine serves at (capacity of the group).
    pub batch: AtomicUsize,
    /// Decode/verify steps taken so far.
    pub steps: AtomicU64,
    /// Mean active rows per step, fixed-point x1000.
    pub occupancy_milli: AtomicU64,
    /// Mean scheduling delay, microseconds.
    pub sched_delay_us: AtomicU64,
    /// Useful/executed positions over all decode/verify calls (ratio of
    /// sums, not a mean of per-call ratios), fixed-point x1000.
    pub chunk_eff_milli: AtomicU64,
    /// Mean sub-batches per step, fixed-point x1000.
    pub subbatches_milli: AtomicU64,
    pub completed: AtomicU64,
    pub cancelled: AtomicU64,
    /// Fidelity-governor counters published by the engine thread.
    pub gov_audits: AtomicU64,
    pub gov_probes: AtomicU64,
    pub gov_eligible: AtomicU64,
    /// Mean audited top-1 agreement, fixed-point x1000.
    pub gov_agreement_milli: AtomicU64,
    /// Mean acceptance-length delta (quantized − reference), signed
    /// fixed-point x1000.
    pub gov_delta_milli: AtomicI64,
    pub gov_demotions: AtomicU64,
    pub gov_promotions: AtomicU64,
    /// Prefix-cache counters published by the engine thread.
    pub prefix_hits: AtomicU64,
    pub prefix_misses: AtomicU64,
    pub prefix_hit_tokens: AtomicU64,
    pub prefix_mid_stream_hit_tokens: AtomicU64,
    pub prefix_resident_bytes: AtomicU64,
    pub prefix_resident_pages: AtomicU64,
    pub prefix_page_refs: AtomicU64,
    pub prefix_segments: AtomicU64,
    pub prefix_evictions: AtomicU64,
    /// Modeled prefill seconds saved by suffix-only admission, microseconds.
    pub prefix_prefill_saved_us: AtomicU64,
    /// KV residency / page-table-row counters published by the engine
    /// thread (`paged_rows` is 0/1, set once at spawn).
    pub kv_paged_rows: AtomicUsize,
    pub kv_resident_bytes: AtomicU64,
    pub kv_resident_peak_bytes: AtomicU64,
    pub kv_row_page_refs: AtomicU64,
    pub kv_row_shared_pages: AtomicU64,
    pub kv_row_copied_pages: AtomicU64,
    pub kv_row_tail_copies: AtomicU64,
    /// Modeled seconds of KV copies the paged backend avoided, microseconds.
    pub kv_copy_saved_us: AtomicU64,
    /// Submitted prompts cut to the context cap.
    pub prompt_truncated: AtomicU64,
    /// Chunked-admission prefill counters published by the engine thread.
    pub prefill_chunks: AtomicU64,
    pub prefill_inflight_rows: AtomicUsize,
    pub decode_stall_steps: AtomicU64,
    /// Modeled stall seconds riding chunks avoided, microseconds.
    pub prefill_stall_saved_us: AtomicU64,
    /// Warm/cold first-token and per-token latency percentiles,
    /// microseconds (warm = admission hit the prefix cache).
    pub ttft_warm_p50_us: AtomicU64,
    pub ttft_warm_p99_us: AtomicU64,
    pub ttft_cold_p50_us: AtomicU64,
    pub ttft_cold_p99_us: AtomicU64,
    pub tpot_warm_p50_us: AtomicU64,
    pub tpot_warm_p99_us: AtomicU64,
    pub tpot_cold_p50_us: AtomicU64,
    pub tpot_cold_p99_us: AtomicU64,
    /// Per-class draft-depth controller view published by the engine
    /// thread (keyed by class; written only between steps, read by `stats`).
    pub gamma: Mutex<BTreeMap<String, GammaClassStat>>,
    /// Per-bucket occupancy/calls published by the engine thread.
    pub buckets: Mutex<std::collections::BTreeMap<usize, BucketStat>>,
    /// Per-variant chunk-call tallies published by the engine thread.
    pub variants: Mutex<std::collections::BTreeMap<String, u64>>,
    /// Full latency histograms published by the engine thread alongside the
    /// scalar p50/p99 pairs above. The cluster layer merges these bucket-wise
    /// so fleet percentiles come from the combined distribution instead of a
    /// max-fold over replica percentiles.
    pub hists: Mutex<BTreeMap<String, Histogram>>,
    /// When the engine thread was spawned (drives `uptime_s`).
    pub start: OnceLock<Instant>,
    /// Serving-config echo, set once at spawn.
    pub config: OnceLock<ConfigEcho>,
}

/// Point-in-time view of [`RouterStats`].
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// Fleet replica index this snapshot describes (0 for a bare engine;
    /// fleet-aggregated snapshots keep 0 and list per-replica snapshots
    /// alongside — see `coordinator::cluster`).
    pub replica: usize,
    pub in_flight: usize,
    pub queue_depth: usize,
    pub active_rows: usize,
    pub batch: usize,
    pub steps: u64,
    /// Mean active rows per decode/verify step (1.0 = no batching benefit).
    pub batch_occupancy: f64,
    /// Mean seconds a request queued before admission.
    pub sched_delay_s: f64,
    /// Useful/executed positions over all decode/verify calls.
    pub chunk_efficiency: f64,
    /// Mean sub-batches the planner executed per step.
    pub subbatches_per_step: f64,
    pub completed: u64,
    pub cancelled: u64,
    /// Per-bucket execution tallies, ascending by bucket.
    pub buckets: Vec<BucketStat>,
    /// Per-variant chunk-call tallies, ascending by variant name.
    pub variants: Vec<VariantCalls>,
    /// Adaptive-precision governor view (all-zero when disabled).
    pub governor: GovernorSnapshot,
    /// Per-class draft-depth controller view, ascending by class (empty
    /// until a class has recorded a drafting step; populated in static
    /// mode too — only `resolve` is gated on `adaptive_gamma`).
    pub gamma: Vec<GammaClassStat>,
    /// Shared-prefix KV cache view (all-zero when disabled).
    pub prefix: PrefixSnapshot,
    /// KV residency / page-table-row view.
    pub kv: KvSnapshot,
    /// Chunked admission-prefill view (warm/cold latency split included).
    pub prefill: PrefillSnapshot,
    /// Submitted prompts cut to the context cap.
    pub prompt_truncated: u64,
    /// Full latency histograms backing the scalar percentiles in `prefill`
    /// (keyed by metric name). Carried so cluster aggregation can merge
    /// distributions bucket-wise; not serialized into the stats JSON.
    pub hists: BTreeMap<String, Histogram>,
    /// Seconds since the engine thread spawned.
    pub uptime_s: f64,
    /// Serving-config echo (what produced this snapshot).
    pub config: ConfigEcho,
}

impl StatsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("uptime_s", Json::num(self.uptime_s)),
            ("version", Json::str(env!("CARGO_PKG_VERSION"))),
            ("config", self.config.to_json()),
            ("replica", Json::num(self.replica as f64)),
            ("in_flight", Json::num(self.in_flight as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("active_rows", Json::num(self.active_rows as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("batch_occupancy", Json::num(self.batch_occupancy)),
            ("sched_delay_s", Json::num(self.sched_delay_s)),
            ("chunk_efficiency", Json::num(self.chunk_efficiency)),
            ("subbatches_per_step", Json::num(self.subbatches_per_step)),
            ("completed", Json::num(self.completed as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            (
                "buckets",
                Json::arr(
                    self.buckets
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("bucket", Json::num(b.bucket as f64)),
                                ("calls", Json::num(b.calls as f64)),
                                ("mean_rows", Json::num(b.mean_rows)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "variants",
                Json::arr(
                    self.variants
                        .iter()
                        .map(|v| {
                            Json::obj(vec![
                                ("variant", Json::str(v.variant.clone())),
                                ("calls", Json::num(v.calls as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "governor",
                Json::obj(vec![
                    ("audits", Json::num(self.governor.audits as f64)),
                    ("probes", Json::num(self.governor.probes as f64)),
                    ("audit_rate", Json::num(self.governor.audit_rate)),
                    ("top1_agreement", Json::num(self.governor.top1_agreement)),
                    ("accept_delta", Json::num(self.governor.accept_delta)),
                    ("demotions", Json::num(self.governor.demotions as f64)),
                    ("promotions", Json::num(self.governor.promotions as f64)),
                ]),
            ),
            (
                "gamma",
                Json::obj(vec![
                    (
                        "classes",
                        Json::arr(
                            self.gamma
                                .iter()
                                .map(|c| {
                                    Json::obj(vec![
                                        ("class", Json::str(c.class.clone())),
                                        ("accept_ewma", Json::num(c.accept_ewma)),
                                        ("steps", Json::num(c.steps as f64)),
                                        ("drafted", Json::num(c.drafted as f64)),
                                        ("accepted", Json::num(c.accepted as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "steps",
                        Json::num(self.gamma.iter().map(|c| c.steps).sum::<u64>() as f64),
                    ),
                    (
                        "drafted",
                        Json::num(self.gamma.iter().map(|c| c.drafted).sum::<u64>() as f64),
                    ),
                    (
                        "accepted",
                        Json::num(self.gamma.iter().map(|c| c.accepted).sum::<u64>() as f64),
                    ),
                ]),
            ),
            (
                "prefix",
                Json::obj(vec![
                    ("hits", Json::num(self.prefix.hits as f64)),
                    ("misses", Json::num(self.prefix.misses as f64)),
                    ("hit_rate", Json::num(self.prefix.hit_rate)),
                    ("hit_tokens", Json::num(self.prefix.hit_tokens as f64)),
                    (
                        "mid_stream_hit_tokens",
                        Json::num(self.prefix.mid_stream_hit_tokens as f64),
                    ),
                    ("resident_bytes", Json::num(self.prefix.resident_bytes as f64)),
                    ("resident_pages", Json::num(self.prefix.resident_pages as f64)),
                    ("page_share_ratio", Json::num(self.prefix.page_share_ratio)),
                    ("segments", Json::num(self.prefix.segments as f64)),
                    ("evictions", Json::num(self.prefix.evictions as f64)),
                    ("prefill_saved_s", Json::num(self.prefix.prefill_saved_s)),
                ]),
            ),
            (
                "kv",
                Json::obj(vec![
                    ("paged_rows", Json::Bool(self.kv.paged_rows)),
                    ("resident_bytes", Json::num(self.kv.resident_bytes as f64)),
                    (
                        "resident_peak_bytes",
                        Json::num(self.kv.resident_peak_bytes as f64),
                    ),
                    ("row_page_refs", Json::num(self.kv.row_page_refs as f64)),
                    ("row_shared_pages", Json::num(self.kv.row_shared_pages as f64)),
                    ("row_copied_pages", Json::num(self.kv.row_copied_pages as f64)),
                    ("row_tail_copies", Json::num(self.kv.row_tail_copies as f64)),
                    ("copy_saved_s", Json::num(self.kv.copy_saved_s)),
                ]),
            ),
            (
                "prefill",
                Json::obj(vec![
                    ("chunks", Json::num(self.prefill.chunks as f64)),
                    ("inflight_rows", Json::num(self.prefill.inflight_rows as f64)),
                    (
                        "decode_stall_steps",
                        Json::num(self.prefill.decode_stall_steps as f64),
                    ),
                    ("stall_saved_s", Json::num(self.prefill.stall_saved_s)),
                    ("ttft_warm_p50_s", Json::num(self.prefill.ttft_warm_p50_s)),
                    ("ttft_warm_p99_s", Json::num(self.prefill.ttft_warm_p99_s)),
                    ("ttft_cold_p50_s", Json::num(self.prefill.ttft_cold_p50_s)),
                    ("ttft_cold_p99_s", Json::num(self.prefill.ttft_cold_p99_s)),
                    ("tpot_warm_p50_s", Json::num(self.prefill.tpot_warm_p50_s)),
                    ("tpot_warm_p99_s", Json::num(self.prefill.tpot_warm_p99_s)),
                    ("tpot_cold_p50_s", Json::num(self.prefill.tpot_cold_p50_s)),
                    ("tpot_cold_p99_s", Json::num(self.prefill.tpot_cold_p99_s)),
                ]),
            ),
            ("prompt_truncated", Json::num(self.prompt_truncated as f64)),
        ])
    }
}

/// One submitted request's private completion channel. Dropping the ticket
/// abandons delivery only — the engine still finishes the request; call
/// [`EngineHandle::cancel`] to abort the work itself.
pub struct Ticket {
    pub id: u64,
    rx: Receiver<Completion>,
}

impl Ticket {
    /// Block (with timeout) for this request's completion.
    pub fn wait(&self, timeout: Duration) -> Option<Completion> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Non-blocking poll for this request's completion.
    pub fn try_wait(&self) -> Option<Completion> {
        self.rx.try_recv().ok()
    }
}

/// Handle to an engine running on its own thread. `Sync`: share it behind an
/// `Arc` and submit from any number of threads concurrently.
pub struct EngineHandle {
    /// The mutex guards only the channel enqueue (microseconds); generation
    /// never runs under any handle lock.
    tx: Mutex<Sender<Msg>>,
    stats: Arc<RouterStats>,
    join: Option<JoinHandle<Result<()>>>,
    /// Soft cap on in-flight submissions (admission control).
    max_queue: usize,
    /// Flight recorder the engine thread writes span events into (disarmed
    /// unless `EngineConfig::trace`). A cluster passes one shared recorder
    /// to every replica so the fleet exports a single merged trace.
    recorder: Arc<FlightRecorder>,
}

/// Serializes engine-thread *construction* across the process. PJRT client
/// creation and artifact loading are the one stretch of an engine's life
/// that is not obviously reentrant (the CPU plugin registers process-global
/// state on first touch); with N replicas booting concurrently that stretch
/// would race. Held only during boot — steady-state replicas share nothing.
static BOOT_LOCK: Mutex<()> = Mutex::new(());

impl EngineHandle {
    /// Spawn the engine thread. `artifacts` is the manifest root; engine
    /// construction happens on the thread (the PJRT client is not `Send`)
    /// and is serialized process-wide by [`BOOT_LOCK`] so a replica fleet
    /// can spawn its engines from a loop without racing PJRT init.
    pub fn spawn(artifacts: PathBuf, model: String, cfg: EngineConfig,
                 max_queue: usize) -> Result<Self> {
        let recorder = Arc::new(FlightRecorder::new(cfg.trace));
        Self::spawn_with_recorder(artifacts, model, cfg, max_queue, recorder)
    }

    /// Spawn with an externally-owned flight recorder (the cluster layer
    /// hands every replica the same one so span events from the whole fleet
    /// land in a single trace, on one timebase).
    pub fn spawn_with_recorder(
        artifacts: PathBuf,
        model: String,
        cfg: EngineConfig,
        max_queue: usize,
        recorder: Arc<FlightRecorder>,
    ) -> Result<Self> {
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(RouterStats::default());
        let _ = stats.start.set(Instant::now());
        let _ = stats.config.set(ConfigEcho::from_cfg(&cfg));
        let tstats = Arc::clone(&stats);
        let trec = Arc::clone(&recorder);
        let thread_name = format!("quasar-engine-{}", cfg.replica);
        let join = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || -> Result<()> {
                let mut engine = {
                    let _boot = BOOT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
                    let rt = std::rc::Rc::new(crate::runtime::XlaRuntime::cpu()?);
                    let manifest = crate::runtime::Manifest::load(&artifacts)?;
                    let mr = std::rc::Rc::new(crate::runtime::ModelRuntime::load(
                        rt, &manifest, &model,
                    )?);
                    Engine::new(mr, cfg)?
                };
                // Replace the engine's private recorder with the handle's
                // shared one before any request can be submitted.
                engine.set_trace(TraceHandle::new(trec, engine.cfg.replica as u32));
                tstats.replica.store(engine.cfg.replica, Ordering::Relaxed);
                tstats.batch.store(engine.cfg.batch, Ordering::Relaxed);
                tstats
                    .kv_paged_rows
                    .store(engine.cfg.paged_rows as usize, Ordering::Relaxed);
                let mut routes: HashMap<u64, Sender<Completion>> = HashMap::new();
                let mut shutdown = false;
                loop {
                    // Drain control messages without blocking the decode loop.
                    loop {
                        match rx.try_recv() {
                            Ok(msg) => {
                                shutdown |=
                                    handle_msg(&mut engine, msg, &mut routes, &tstats);
                            }
                            Err(TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                            Err(TryRecvError::Empty) => break,
                        }
                    }
                    // Cancellations emit completions without a step; publish
                    // so the stats block never shows a stale active_rows.
                    route_completions(&mut engine, &mut routes, &tstats);
                    publish_stats(&engine, &tstats);
                    if shutdown && engine.in_flight() == 0 {
                        return Ok(());
                    }
                    if engine.in_flight() > 0 {
                        engine.step()?;
                        route_completions(&mut engine, &mut routes, &tstats);
                        publish_stats(&engine, &tstats);
                    } else {
                        // Idle: block briefly for the next submission.
                        match rx.recv_timeout(Duration::from_millis(5)) {
                            Ok(msg) => {
                                shutdown |=
                                    handle_msg(&mut engine, msg, &mut routes, &tstats);
                                route_completions(&mut engine, &mut routes, &tstats);
                            }
                            Err(_) => {}
                        }
                    }
                }
            })?;
        Ok(EngineHandle {
            tx: Mutex::new(tx),
            stats,
            join: Some(join),
            max_queue,
            recorder,
        })
    }

    /// The flight recorder shared with the engine thread (disarmed unless
    /// `EngineConfig::trace`).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Drain the flight recorder and render the Chrome trace-event JSON
    /// (openable in Perfetto / `chrome://tracing`). With tracing off this
    /// returns a valid document with an empty event list.
    pub fn trace_json(&self) -> Json {
        self.recorder.chrome_trace_json()
    }

    /// Snapshot the engine's full metrics registry (counters, gauges, raw
    /// histograms). Round-trips through the engine thread, so it reflects a
    /// consistent point between steps; use [`MetricsDump::to_prometheus`]
    /// for text exposition.
    pub fn metrics_dump(&self) -> Result<MetricsDump> {
        let (ack_tx, ack_rx) = channel();
        self.send(Msg::Scrape { ack: ack_tx })?;
        ack_rx
            .recv_timeout(Duration::from_secs(10))
            .map_err(|_| anyhow!("engine did not answer metrics scrape"))
    }

    fn send(&self, msg: Msg) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(msg)
            .map_err(|_| anyhow!("engine thread terminated"))
    }

    /// Submit; `Err` when the admission queue is full (backpressure) or the
    /// engine thread is gone. The returned [`Ticket`] is this request's
    /// private completion channel.
    pub fn submit(&self, prompt: Vec<i32>, params: GenParams, task: &str) -> Result<Ticket> {
        let in_flight = self.stats.in_flight.load(Ordering::SeqCst);
        if in_flight >= self.max_queue {
            return Err(anyhow!("admission queue full ({in_flight} in flight)"));
        }
        let (ack_tx, ack_rx) = channel();
        let (done_tx, done_rx) = channel();
        self.send(Msg::Submit {
            prompt,
            params,
            task: task.to_string(),
            sent_at: Instant::now(),
            ack: ack_tx,
            done: done_tx,
        })?;
        let id = ack_rx
            .recv_timeout(Duration::from_secs(10))
            .map_err(|_| anyhow!("engine did not ack submission"))?;
        Ok(Ticket { id, rx: done_rx })
    }

    /// Ask the engine to abort a request (queued or running). The request's
    /// ticket resolves with a `Cancelled` completion; unknown ids are a
    /// no-op (the request already completed).
    pub fn cancel(&self, id: u64) -> Result<()> {
        self.send(Msg::Cancel { id })
    }

    /// Boot warm-up: block until the engine has prefilled and cached these
    /// `(template ids, task)` pairs in its prefix cache (see
    /// [`Engine::warm_prefix`]). Call before the first client so the first
    /// request of each template family already hits. Returns how many
    /// templates were cached (0 when the cache is disabled).
    pub fn warm_prefix(&self, templates: Vec<(Vec<i32>, String)>) -> Result<usize> {
        let (ack_tx, ack_rx) = channel();
        self.send(Msg::Warm { templates, ack: ack_tx })?;
        ack_rx
            .recv_timeout(Duration::from_secs(120))
            .map_err(|_| anyhow!("engine did not ack prefix warm-up"))
    }

    /// Submitted-but-not-completed count (queued + running).
    pub fn in_flight(&self) -> usize {
        self.stats.in_flight.load(Ordering::SeqCst)
    }

    /// Snapshot the engine-published serving stats (never blocks on the
    /// engine).
    pub fn stats(&self) -> StatsSnapshot {
        let s = &self.stats;
        StatsSnapshot {
            replica: s.replica.load(Ordering::Relaxed),
            in_flight: s.in_flight.load(Ordering::Relaxed),
            queue_depth: s.queue_depth.load(Ordering::Relaxed),
            active_rows: s.active_rows.load(Ordering::Relaxed),
            batch: s.batch.load(Ordering::Relaxed),
            steps: s.steps.load(Ordering::Relaxed),
            batch_occupancy: s.occupancy_milli.load(Ordering::Relaxed) as f64 / 1e3,
            sched_delay_s: s.sched_delay_us.load(Ordering::Relaxed) as f64 / 1e6,
            chunk_efficiency: s.chunk_eff_milli.load(Ordering::Relaxed) as f64 / 1e3,
            subbatches_per_step: s.subbatches_milli.load(Ordering::Relaxed) as f64 / 1e3,
            completed: s.completed.load(Ordering::Relaxed),
            cancelled: s.cancelled.load(Ordering::Relaxed),
            buckets: s.buckets.lock().unwrap().values().copied().collect(),
            variants: s
                .variants
                .lock()
                .unwrap()
                .iter()
                .map(|(variant, &calls)| VariantCalls { variant: variant.clone(), calls })
                .collect(),
            governor: {
                let audits = s.gov_audits.load(Ordering::Relaxed);
                let eligible = s.gov_eligible.load(Ordering::Relaxed);
                GovernorSnapshot {
                    audits,
                    probes: s.gov_probes.load(Ordering::Relaxed),
                    audit_rate: if eligible == 0 {
                        0.0
                    } else {
                        audits as f64 / eligible as f64
                    },
                    top1_agreement: s.gov_agreement_milli.load(Ordering::Relaxed) as f64 / 1e3,
                    accept_delta: s.gov_delta_milli.load(Ordering::Relaxed) as f64 / 1e3,
                    demotions: s.gov_demotions.load(Ordering::Relaxed),
                    promotions: s.gov_promotions.load(Ordering::Relaxed),
                }
            },
            gamma: s.gamma.lock().unwrap().values().cloned().collect(),
            prefix: {
                let hits = s.prefix_hits.load(Ordering::Relaxed);
                let misses = s.prefix_misses.load(Ordering::Relaxed);
                let pages = s.prefix_resident_pages.load(Ordering::Relaxed);
                let refs = s.prefix_page_refs.load(Ordering::Relaxed);
                PrefixSnapshot {
                    hits,
                    misses,
                    hit_rate: if hits + misses == 0 {
                        0.0
                    } else {
                        hits as f64 / (hits + misses) as f64
                    },
                    hit_tokens: s.prefix_hit_tokens.load(Ordering::Relaxed),
                    mid_stream_hit_tokens: s
                        .prefix_mid_stream_hit_tokens
                        .load(Ordering::Relaxed),
                    resident_bytes: s.prefix_resident_bytes.load(Ordering::Relaxed),
                    resident_pages: pages,
                    page_share_ratio: if pages == 0 {
                        0.0
                    } else {
                        refs as f64 / pages as f64
                    },
                    segments: s.prefix_segments.load(Ordering::Relaxed),
                    evictions: s.prefix_evictions.load(Ordering::Relaxed),
                    prefill_saved_s: s.prefix_prefill_saved_us.load(Ordering::Relaxed)
                        as f64
                        / 1e6,
                }
            },
            kv: KvSnapshot {
                paged_rows: s.kv_paged_rows.load(Ordering::Relaxed) != 0,
                resident_bytes: s.kv_resident_bytes.load(Ordering::Relaxed),
                resident_peak_bytes: s.kv_resident_peak_bytes.load(Ordering::Relaxed),
                row_page_refs: s.kv_row_page_refs.load(Ordering::Relaxed),
                row_shared_pages: s.kv_row_shared_pages.load(Ordering::Relaxed),
                row_copied_pages: s.kv_row_copied_pages.load(Ordering::Relaxed),
                row_tail_copies: s.kv_row_tail_copies.load(Ordering::Relaxed),
                copy_saved_s: s.kv_copy_saved_us.load(Ordering::Relaxed) as f64 / 1e6,
            },
            prefill: {
                let us = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64 / 1e6;
                PrefillSnapshot {
                    chunks: s.prefill_chunks.load(Ordering::Relaxed),
                    inflight_rows: s.prefill_inflight_rows.load(Ordering::Relaxed) as u64,
                    decode_stall_steps: s.decode_stall_steps.load(Ordering::Relaxed),
                    stall_saved_s: us(&s.prefill_stall_saved_us),
                    ttft_warm_p50_s: us(&s.ttft_warm_p50_us),
                    ttft_warm_p99_s: us(&s.ttft_warm_p99_us),
                    ttft_cold_p50_s: us(&s.ttft_cold_p50_us),
                    ttft_cold_p99_s: us(&s.ttft_cold_p99_us),
                    tpot_warm_p50_s: us(&s.tpot_warm_p50_us),
                    tpot_warm_p99_s: us(&s.tpot_warm_p99_us),
                    tpot_cold_p50_s: us(&s.tpot_cold_p50_us),
                    tpot_cold_p99_s: us(&s.tpot_cold_p99_us),
                }
            },
            prompt_truncated: s.prompt_truncated.load(Ordering::Relaxed),
            hists: s.hists.lock().unwrap().clone(),
            uptime_s: s
                .start
                .get()
                .map(|t| t.elapsed().as_secs_f64())
                .unwrap_or(0.0),
            config: s.config.get().cloned().unwrap_or_default(),
        }
    }

    /// Graceful shutdown: drain in-flight work, then join.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Engine-thread message handler; returns `true` on shutdown. Submissions
/// bump `in_flight` here (engine side) so the count can never underflow
/// against completion routing.
fn handle_msg(
    engine: &mut Engine,
    msg: Msg,
    routes: &mut HashMap<u64, Sender<Completion>>,
    stats: &RouterStats,
) -> bool {
    match msg {
        Msg::Submit { prompt, params, task, sent_at, ack, done } => {
            let id = engine.submit_at(prompt, params, &task, sent_at);
            routes.insert(id, done);
            stats.in_flight.fetch_add(1, Ordering::SeqCst);
            let _ = ack.send(id);
            false
        }
        Msg::Scrape { ack } => {
            let _ = ack.send(engine.metrics.export());
            false
        }
        Msg::Cancel { id } => {
            // Unknown id == already completed; nothing to do.
            let _ = engine.cancel(id);
            false
        }
        Msg::Warm { templates, ack } => {
            match engine.warm_prefix(&templates) {
                Ok(n) => {
                    let _ = ack.send(n);
                }
                Err(e) => {
                    eprintln!("[engine] prefix warm-up failed: {e:#}");
                    let _ = ack.send(0);
                }
            }
            false
        }
        Msg::Shutdown => true,
    }
}

/// Deliver every finished completion to its submitter's private channel.
/// Emission time (engine finish → here) lands in `stages.emit_s` and is
/// folded into `latency_s`, so the stage breakdown partitions the full
/// observed latency.
fn route_completions(
    engine: &mut Engine,
    routes: &mut HashMap<u64, Sender<Completion>>,
    stats: &RouterStats,
) {
    for mut c in engine.take_completions() {
        let emit = Instant::now().duration_since(c.finished_at).as_secs_f64();
        c.stages.emit_s = emit;
        c.latency_s += emit;
        engine.trace_handle().record(c.id, EventKind::Finished);
        let _ = stats
            .in_flight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                Some(v.saturating_sub(1))
            });
        stats.completed.fetch_add(1, Ordering::Relaxed);
        if c.finish == FinishReason::Cancelled {
            stats.cancelled.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(tx) = routes.remove(&c.id) {
            // The receiver may be gone (submitter timed out); dropping the
            // completion is then correct.
            let _ = tx.send(c);
        }
    }
}

/// Publish queue/occupancy gauges from the engine's metrics registry into
/// the atomically-readable stats block.
fn publish_stats(engine: &Engine, stats: &RouterStats) {
    stats
        .queue_depth
        .store(engine.queue_depth(), Ordering::Relaxed);
    stats
        .active_rows
        .store(engine.active_count(), Ordering::Relaxed);
    if let Some(h) = engine.metrics.hist(crate::metrics::names::BATCH_OCCUPANCY) {
        stats.steps.store(h.count(), Ordering::Relaxed);
        stats
            .occupancy_milli
            .store((h.mean() * 1e3) as u64, Ordering::Relaxed);
    }
    if let Some(h) = engine.metrics.hist(crate::metrics::names::SCHED_DELAY_S) {
        stats
            .sched_delay_us
            .store((h.mean() * 1e6) as u64, Ordering::Relaxed);
    }
    // Ratio of position-count sums, matching `CallLog::chunk_efficiency`
    // (a mean of per-call ratios would overweight small calls).
    let executed = engine.metrics.counter(crate::metrics::names::EXECUTED_POSITIONS);
    if executed > 0 {
        let useful = engine.metrics.counter(crate::metrics::names::USEFUL_POSITIONS);
        stats
            .chunk_eff_milli
            .store(useful * 1000 / executed, Ordering::Relaxed);
    }
    if let Some(h) = engine.metrics.hist(crate::metrics::names::SUBBATCHES_PER_STEP) {
        stats
            .subbatches_milli
            .store((h.mean() * 1e3) as u64, Ordering::Relaxed);
    }
    let mut buckets = stats.buckets.lock().unwrap();
    for bucket in engine.plan_buckets() {
        let calls = engine
            .metrics
            .counter(&crate::metrics::names::bucket_calls(bucket));
        if calls == 0 {
            continue;
        }
        let mean_rows = engine
            .metrics
            .hist(&crate::metrics::names::bucket_occupancy(bucket))
            .map(|h| h.mean())
            .unwrap_or(0.0);
        buckets.insert(bucket, BucketStat { bucket, calls, mean_rows });
    }
    drop(buckets);
    let mut variants = stats.variants.lock().unwrap();
    for variant in engine.variant_names() {
        let calls = engine
            .metrics
            .counter(&crate::metrics::names::variant_calls(&variant));
        if calls > 0 {
            variants.insert(variant, calls);
        }
    }
    drop(variants);
    stats.gov_audits.store(
        engine.metrics.counter(crate::metrics::names::GOVERNOR_AUDITS),
        Ordering::Relaxed,
    );
    stats.gov_probes.store(
        engine.metrics.counter(crate::metrics::names::GOVERNOR_PROBES),
        Ordering::Relaxed,
    );
    stats.gov_eligible.store(
        engine.metrics.counter(crate::metrics::names::GOVERNOR_ELIGIBLE),
        Ordering::Relaxed,
    );
    if let Some(h) = engine.metrics.hist(crate::metrics::names::GOVERNOR_AGREEMENT) {
        stats
            .gov_agreement_milli
            .store((h.mean() * 1e3) as u64, Ordering::Relaxed);
    }
    if let Some(h) = engine.metrics.hist(crate::metrics::names::GOVERNOR_ACCEPT_DELTA) {
        stats
            .gov_delta_milli
            .store((h.mean() * 1e3) as i64, Ordering::Relaxed);
    }
    // Modeled-savings histograms publish as their running sums.
    if let Some(h) = engine.metrics.hist(crate::metrics::names::PREFILL_SAVED_S) {
        stats
            .prefix_prefill_saved_us
            .store((h.sum() * 1e6) as u64, Ordering::Relaxed);
    }
    if let Some(h) = engine.metrics.hist(crate::metrics::names::KV_COPY_SAVED_S) {
        stats
            .kv_copy_saved_us
            .store((h.sum() * 1e6) as u64, Ordering::Relaxed);
    }
    // The prefix block is gauges end to end: the engine publishes the
    // cache's own (monotonic) counters wholesale after each admission pass.
    let m = &engine.metrics;
    for (dst, name) in [
        (&stats.prefix_hits, crate::metrics::names::PREFIX_HITS),
        (&stats.prefix_misses, crate::metrics::names::PREFIX_MISSES),
        (&stats.prefix_hit_tokens, crate::metrics::names::PREFIX_HIT_TOKENS),
        (
            &stats.prefix_mid_stream_hit_tokens,
            crate::metrics::names::PREFIX_MID_STREAM_HIT_TOKENS,
        ),
        (&stats.prefix_evictions, crate::metrics::names::PREFIX_EVICTIONS),
        (
            &stats.prefix_resident_bytes,
            crate::metrics::names::PREFIX_RESIDENT_BYTES,
        ),
        (
            &stats.prefix_resident_pages,
            crate::metrics::names::PREFIX_RESIDENT_PAGES,
        ),
        (&stats.prefix_page_refs, crate::metrics::names::PREFIX_PAGE_REFS),
        (&stats.prefix_segments, crate::metrics::names::PREFIX_SEGMENTS),
        (&stats.kv_resident_bytes, crate::metrics::names::KV_RESIDENT_BYTES),
        (
            &stats.kv_resident_peak_bytes,
            crate::metrics::names::KV_RESIDENT_PEAK_BYTES,
        ),
        (&stats.kv_row_page_refs, crate::metrics::names::KV_ROW_PAGE_REFS),
        (
            &stats.kv_row_shared_pages,
            crate::metrics::names::KV_ROW_SHARED_PAGES,
        ),
        (
            &stats.kv_row_copied_pages,
            crate::metrics::names::KV_ROW_COPIED_PAGES,
        ),
        (
            &stats.kv_row_tail_copies,
            crate::metrics::names::KV_ROW_TAIL_COPIES,
        ),
    ] {
        dst.store(m.gauge(name).max(0) as u64, Ordering::Relaxed);
    }
    stats.prompt_truncated.store(
        m.counter(crate::metrics::names::PROMPT_TRUNCATED),
        Ordering::Relaxed,
    );
    // Chunked admission-prefill counters (zero in monolithic mode except
    // `prefill_chunks`, which also counts monolithic admission windows).
    stats.prefill_chunks.store(
        m.counter(crate::metrics::names::PREFILL_CHUNKS),
        Ordering::Relaxed,
    );
    stats.decode_stall_steps.store(
        m.counter(crate::metrics::names::DECODE_STALL_STEPS),
        Ordering::Relaxed,
    );
    stats.prefill_inflight_rows.store(
        m.gauge(crate::metrics::names::PREFILL_INFLIGHT_ROWS).max(0) as usize,
        Ordering::Relaxed,
    );
    if let Some(h) = m.hist(crate::metrics::names::PREFILL_STALL_SAVED_S) {
        stats
            .prefill_stall_saved_us
            .store((h.sum() * 1e6) as u64, Ordering::Relaxed);
    }
    // Warm/cold latency split: publish p50/p99 pairs per histogram, and
    // carry the raw histograms so the cluster layer can merge distributions
    // bucket-wise instead of folding replica percentiles.
    let mut hists = stats.hists.lock().unwrap();
    for (name, p50_dst, p99_dst) in [
        (
            crate::metrics::names::TTFT_WARM_S,
            &stats.ttft_warm_p50_us,
            &stats.ttft_warm_p99_us,
        ),
        (
            crate::metrics::names::TTFT_COLD_S,
            &stats.ttft_cold_p50_us,
            &stats.ttft_cold_p99_us,
        ),
        (
            crate::metrics::names::TPOT_WARM_S,
            &stats.tpot_warm_p50_us,
            &stats.tpot_warm_p99_us,
        ),
        (
            crate::metrics::names::TPOT_COLD_S,
            &stats.tpot_cold_p50_us,
            &stats.tpot_cold_p99_us,
        ),
    ] {
        if let Some(h) = m.hist(name) {
            p50_dst.store((h.p50() * 1e6) as u64, Ordering::Relaxed);
            p99_dst.store((h.p99() * 1e6) as u64, Ordering::Relaxed);
            hists.insert(name.to_string(), h);
        }
    }
    drop(hists);
    // Per-class draft-depth view comes from the controller itself (like the
    // governor's transition counts below): its EWMAs live outside the
    // metrics registry.
    let mut gamma = stats.gamma.lock().unwrap();
    for (class, st) in engine.gamma_ctl().classes() {
        gamma.insert(
            class.clone(),
            GammaClassStat {
                class: class.clone(),
                accept_ewma: st.accept_ewma,
                steps: st.steps,
                drafted: st.drafted,
                accepted: st.accepted,
            },
        );
    }
    drop(gamma);
    // Transition counts come from the governor itself (not the metrics
    // registry): transitions forced outside the engine's audit loop — e.g.
    // operational pre-demotion via `Engine::governor_mut` — must still be
    // visible on the stats endpoint.
    stats
        .gov_demotions
        .store(engine.governor().demotions, Ordering::Relaxed);
    stats
        .gov_promotions
        .store(engine.governor().promotions, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_is_shareable_across_threads() {
        // The whole point of the refactor: the handle needs no outer mutex.
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<EngineHandle>();
        assert_sync_send::<RouterStats>();
        assert_sync_send::<StatsSnapshot>();
    }

    #[test]
    fn stats_snapshot_serializes_every_field() {
        let s = StatsSnapshot {
            replica: 2,
            in_flight: 3,
            queue_depth: 2,
            active_rows: 1,
            batch: 4,
            steps: 10,
            batch_occupancy: 2.5,
            sched_delay_s: 0.012,
            chunk_efficiency: 0.75,
            subbatches_per_step: 1.25,
            completed: 7,
            cancelled: 1,
            buckets: vec![
                BucketStat { bucket: 1, calls: 3, mean_rows: 1.0 },
                BucketStat { bucket: 4, calls: 7, mean_rows: 3.2 },
            ],
            variants: vec![
                VariantCalls { variant: "fp32".into(), calls: 2 },
                VariantCalls { variant: "w8a8".into(), calls: 8 },
            ],
            governor: GovernorSnapshot {
                audits: 5,
                probes: 2,
                audit_rate: 0.625,
                top1_agreement: 0.999,
                accept_delta: -0.25,
                demotions: 1,
                promotions: 1,
            },
            gamma: vec![
                GammaClassStat {
                    class: "chat".into(),
                    accept_ewma: 3.5,
                    steps: 40,
                    drafted: 200,
                    accepted: 140,
                },
                GammaClassStat {
                    class: "code".into(),
                    accept_ewma: 1.25,
                    steps: 10,
                    drafted: 50,
                    accepted: 10,
                },
            ],
            prefix: PrefixSnapshot {
                hits: 6,
                misses: 2,
                hit_rate: 0.75,
                hit_tokens: 480,
                mid_stream_hit_tokens: 96,
                resident_bytes: 1 << 20,
                resident_pages: 64,
                page_share_ratio: 1.5,
                segments: 5,
                evictions: 3,
                prefill_saved_s: 0.125,
            },
            kv: KvSnapshot {
                paged_rows: true,
                resident_bytes: 3 << 20,
                resident_peak_bytes: 4 << 20,
                row_page_refs: 12,
                row_shared_pages: 9,
                row_copied_pages: 0,
                row_tail_copies: 4,
                copy_saved_s: 0.5,
            },
            prefill: PrefillSnapshot {
                chunks: 11,
                inflight_rows: 2,
                decode_stall_steps: 3,
                stall_saved_s: 0.0625,
                ttft_warm_p50_s: 0.010,
                ttft_warm_p99_s: 0.020,
                ttft_cold_p50_s: 0.030,
                ttft_cold_p99_s: 0.040,
                tpot_warm_p50_s: 0.001,
                tpot_warm_p99_s: 0.002,
                tpot_cold_p50_s: 0.003,
                tpot_cold_p99_s: 0.004,
            },
            prompt_truncated: 2,
            hists: BTreeMap::new(),
            uptime_s: 12.5,
            config: ConfigEcho {
                method: "w8a8".into(),
                batch: 4,
                replicas: 2,
                dispatch: "locality".into(),
                paged_rows: true,
                chunked_prefill: true,
                adaptive_gamma: true,
                trace: true,
            },
        };
        let j = s.to_json();
        assert!((j.get("uptime_s").unwrap().as_f64().unwrap() - 12.5).abs() < 1e-9);
        assert_eq!(
            j.get("version").unwrap().as_str().unwrap(),
            env!("CARGO_PKG_VERSION")
        );
        let cfg = j.get("config").unwrap();
        assert_eq!(cfg.get("method").unwrap().as_str().unwrap(), "w8a8");
        assert_eq!(cfg.get("batch").unwrap().as_i64().unwrap(), 4);
        assert_eq!(cfg.get("replicas").unwrap().as_i64().unwrap(), 2);
        assert_eq!(cfg.get("dispatch").unwrap().as_str().unwrap(), "locality");
        assert!(cfg.get("paged_rows").unwrap().as_bool().unwrap());
        assert!(cfg.get("chunked_prefill").unwrap().as_bool().unwrap());
        assert!(cfg.get("adaptive_gamma").unwrap().as_bool().unwrap());
        assert!(cfg.get("trace").unwrap().as_bool().unwrap());
        assert_eq!(j.get("replica").unwrap().as_i64().unwrap(), 2);
        assert_eq!(j.get("queue_depth").unwrap().as_i64().unwrap(), 2);
        assert_eq!(j.get("batch").unwrap().as_i64().unwrap(), 4);
        assert!((j.get("batch_occupancy").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert!((j.get("sched_delay_s").unwrap().as_f64().unwrap() - 0.012).abs() < 1e-9);
        assert!((j.get("chunk_efficiency").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-9);
        assert!(
            (j.get("subbatches_per_step").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-9
        );
        assert_eq!(j.get("cancelled").unwrap().as_i64().unwrap(), 1);
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[1].get("bucket").unwrap().as_i64().unwrap(), 4);
        assert_eq!(buckets[1].get("calls").unwrap().as_i64().unwrap(), 7);
        assert!((buckets[1].get("mean_rows").unwrap().as_f64().unwrap() - 3.2).abs() < 1e-9);
        let variants = j.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 2);
        assert_eq!(variants[1].get("variant").unwrap().as_str().unwrap(), "w8a8");
        assert_eq!(variants[1].get("calls").unwrap().as_i64().unwrap(), 8);
        let gov = j.get("governor").unwrap();
        assert_eq!(gov.get("audits").unwrap().as_i64().unwrap(), 5);
        assert_eq!(gov.get("probes").unwrap().as_i64().unwrap(), 2);
        assert!((gov.get("audit_rate").unwrap().as_f64().unwrap() - 0.625).abs() < 1e-9);
        assert!((gov.get("top1_agreement").unwrap().as_f64().unwrap() - 0.999).abs() < 1e-9);
        assert!((gov.get("accept_delta").unwrap().as_f64().unwrap() + 0.25).abs() < 1e-9);
        assert_eq!(gov.get("demotions").unwrap().as_i64().unwrap(), 1);
        assert_eq!(gov.get("promotions").unwrap().as_i64().unwrap(), 1);
        let gamma = j.get("gamma").unwrap();
        let classes = gamma.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("class").unwrap().as_str().unwrap(), "chat");
        assert!(
            (classes[0].get("accept_ewma").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-9
        );
        assert_eq!(classes[0].get("steps").unwrap().as_i64().unwrap(), 40);
        assert_eq!(classes[1].get("class").unwrap().as_str().unwrap(), "code");
        assert_eq!(classes[1].get("drafted").unwrap().as_i64().unwrap(), 50);
        assert_eq!(classes[1].get("accepted").unwrap().as_i64().unwrap(), 10);
        assert_eq!(gamma.get("steps").unwrap().as_i64().unwrap(), 50);
        assert_eq!(gamma.get("drafted").unwrap().as_i64().unwrap(), 250);
        assert_eq!(gamma.get("accepted").unwrap().as_i64().unwrap(), 150);
        let prefix = j.get("prefix").unwrap();
        assert_eq!(prefix.get("hits").unwrap().as_i64().unwrap(), 6);
        assert_eq!(prefix.get("misses").unwrap().as_i64().unwrap(), 2);
        assert!((prefix.get("hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-9);
        assert_eq!(prefix.get("hit_tokens").unwrap().as_i64().unwrap(), 480);
        assert_eq!(
            prefix.get("mid_stream_hit_tokens").unwrap().as_i64().unwrap(),
            96
        );
        assert_eq!(
            prefix.get("resident_bytes").unwrap().as_i64().unwrap(),
            1 << 20
        );
        assert_eq!(prefix.get("resident_pages").unwrap().as_i64().unwrap(), 64);
        assert!(
            (prefix.get("page_share_ratio").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9
        );
        assert_eq!(prefix.get("segments").unwrap().as_i64().unwrap(), 5);
        assert_eq!(prefix.get("evictions").unwrap().as_i64().unwrap(), 3);
        assert!(
            (prefix.get("prefill_saved_s").unwrap().as_f64().unwrap() - 0.125).abs() < 1e-9
        );
        let kv = j.get("kv").unwrap();
        assert!(kv.get("paged_rows").unwrap().as_bool().unwrap());
        assert_eq!(kv.get("resident_bytes").unwrap().as_i64().unwrap(), 3 << 20);
        assert_eq!(
            kv.get("resident_peak_bytes").unwrap().as_i64().unwrap(),
            4 << 20
        );
        assert_eq!(kv.get("row_page_refs").unwrap().as_i64().unwrap(), 12);
        assert_eq!(kv.get("row_shared_pages").unwrap().as_i64().unwrap(), 9);
        assert_eq!(kv.get("row_copied_pages").unwrap().as_i64().unwrap(), 0);
        assert_eq!(kv.get("row_tail_copies").unwrap().as_i64().unwrap(), 4);
        assert!((kv.get("copy_saved_s").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
        let pf = j.get("prefill").unwrap();
        assert_eq!(pf.get("chunks").unwrap().as_i64().unwrap(), 11);
        assert_eq!(pf.get("inflight_rows").unwrap().as_i64().unwrap(), 2);
        assert_eq!(pf.get("decode_stall_steps").unwrap().as_i64().unwrap(), 3);
        assert!((pf.get("stall_saved_s").unwrap().as_f64().unwrap() - 0.0625).abs() < 1e-9);
        for (key, want) in [
            ("ttft_warm_p50_s", 0.010),
            ("ttft_warm_p99_s", 0.020),
            ("ttft_cold_p50_s", 0.030),
            ("ttft_cold_p99_s", 0.040),
            ("tpot_warm_p50_s", 0.001),
            ("tpot_warm_p99_s", 0.002),
            ("tpot_cold_p50_s", 0.003),
            ("tpot_cold_p99_s", 0.004),
        ] {
            assert!((pf.get(key).unwrap().as_f64().unwrap() - want).abs() < 1e-9, "{key}");
        }
        assert_eq!(j.get("prompt_truncated").unwrap().as_i64().unwrap(), 2);
    }
}

//! Request model: what enters the engine, its in-flight state, and the
//! completion record handed back (with the speculative bookkeeping the
//! paper's tables aggregate).

use std::time::{Duration, Instant};

use crate::metrics::SpecStats;
use crate::spec::drafter::{DraftCost, Drafter};

/// Scheduling class of a request. Lower sorts first under the scheduler's
/// `Priority` policy; `Ord` follows declaration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// Generation parameters for one request.
#[derive(Debug, Clone)]
pub struct GenParams {
    /// Sampling temperature; `0.0` = greedy (paper's T=0 setting).
    pub temp: f64,
    /// Maximum new tokens to generate.
    pub max_new: usize,
    /// Per-request sampling seed (forked from the engine seed when absent).
    pub seed: Option<u64>,
    /// Stop at `<eos>`.
    pub stop_at_eos: bool,
    /// Scheduling class under the scheduler's `Priority` policy.
    pub priority: Priority,
    /// Relative deadline from submission. An expired request is finished
    /// with [`FinishReason::Cancelled`]: queued ones before they cost a
    /// prefill, running ones at the next engine step (freeing the KV row).
    pub deadline: Option<Duration>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            temp: 0.0,
            max_new: 96,
            seed: None,
            stop_at_eos: true,
            priority: Priority::Normal,
            deadline: None,
        }
    }
}

/// An admitted request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    /// Task family tag (workload benches group metrics by it).
    pub task: String,
    /// The submitted prompt exceeded the context cap (`max_seq - 2`) and
    /// was cut to it; surfaced in the completion's [`SpecStats`] and a
    /// metrics counter so silently-shortened prompts are visible to
    /// callers.
    pub prompt_truncated: bool,
    /// When the client handed the request to the serving stack (captured at
    /// the handle boundary so queue time in the router channel is charged to
    /// `dispatch_s`, not lost).
    pub submitted_at: Instant,
    /// When the request entered the engine's admission queue.
    pub enqueued_at: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, params: GenParams) -> Self {
        let now = Instant::now();
        Request {
            id,
            prompt,
            params,
            task: String::new(),
            prompt_truncated: false,
            submitted_at: now,
            enqueued_at: now,
        }
    }

    pub fn with_task(mut self, task: &str) -> Self {
        self.task = task.to_string();
        self
    }

    /// Backdate the submission point to when the client actually sent the
    /// request (the deadline clock and `dispatch_s` both anchor on it).
    pub fn with_submitted_at(mut self, t: Instant) -> Self {
        self.submitted_at = t;
        self
    }

    pub fn with_truncated(mut self, truncated: bool) -> Self {
        self.prompt_truncated = truncated;
        self
    }

    /// Absolute deadline, when the request carries one.
    pub fn deadline_at(&self) -> Option<Instant> {
        self.params.deadline.map(|d| self.submitted_at + d)
    }
}

/// Why a request stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxNewTokens,
    ContextFull,
    /// Aborted before finishing: explicit cancel or blown deadline.
    Cancelled,
}

/// Progress of a chunked (resumable) admission prefill. While present on a
/// [`RequestState`], the row holds a KV slot whose positions `0..cached`
/// are committed (`cached = hit + consumed`) but has emitted no token yet:
/// the remaining prompt suffix `[hit + consumed, prompt.len())` is fed in
/// planner-packed chunks that ride spare decode/verify slots. The first
/// token samples from the chunk that covers the final prompt position —
/// bit-identical to the monolithic suffix prefill because attention is
/// causal and every chunk writes the same positions the one-shot chunk
/// would.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillProgress {
    /// Prompt tokens served from the prefix cache at admission.
    pub hit: usize,
    /// Suffix tokens prefilled by completed chunks so far.
    pub consumed: usize,
}

/// In-flight per-request state owned by the scheduler.
pub struct RequestState {
    pub req: Request,
    /// All committed tokens (prompt + generated).
    pub committed: Vec<i32>,
    /// KV coverage: positions `0..cached` hold committed tokens
    /// (invariant: `cached == committed.len() - 1` after prefill).
    pub cached: usize,
    pub generated: usize,
    pub drafter: Box<dyn Drafter>,
    pub rng: crate::util::rng::Pcg,
    pub stats: SpecStats,
    /// Accumulated drafter cost for *this* request (threaded into the
    /// completion; the call log keeps the engine-wide aggregate).
    pub draft_cost: DraftCost,
    /// Seconds spent queued in the scheduler before admission.
    pub sched_delay_s: f64,
    /// When the engine granted this request a KV row (stage-breakdown
    /// anchor; always measured — a couple of clock reads per request, not
    /// gated on tracing).
    pub admitted_at: Option<Instant>,
    /// Seconds spent splicing cached prefix pages at admission.
    pub splice_s: f64,
    pub first_token_at: Option<Instant>,
    pub finished: Option<FinishReason>,
    /// Weight variant the request's prefill ran at (set by the engine at
    /// admission). The prefix cache is keyed by it.
    pub admit_variant: String,
    /// A later step executed this row at a *different* variant (the
    /// fidelity governor demoted/promoted its class mid-generation), so
    /// the row's KV history mixes precisions. Mixed rows are never
    /// snapshotted mid-stream — a cached run must be bit-exact KV for its
    /// key at exactly one variant.
    pub kv_mixed: bool,
    /// `Some` while the row's admission prefill is still being fed in
    /// chunks (chunked admission only); `None` once the first token has
    /// sampled and the row decodes normally.
    pub prefilling: Option<PrefillProgress>,
    /// The admission lookup matched a cached prefix — keys the warm/cold
    /// TTFT/TPOT histogram split at completion.
    pub prefix_hit: bool,
}

impl RequestState {
    pub fn new(req: Request, drafter: Box<dyn Drafter>, rng: crate::util::rng::Pcg) -> Self {
        let committed = req.prompt.clone();
        let stats = SpecStats {
            prompt_truncated: req.prompt_truncated as u64,
            ..SpecStats::default()
        };
        RequestState {
            req,
            committed,
            cached: 0,
            generated: 0,
            drafter,
            rng,
            stats,
            draft_cost: DraftCost::default(),
            sched_delay_s: 0.0,
            admitted_at: None,
            splice_s: 0.0,
            first_token_at: None,
            finished: None,
            admit_variant: String::new(),
            kv_mixed: false,
            prefilling: None,
            prefix_hit: false,
        }
    }

    /// Tokens generated beyond the prompt.
    pub fn output_tokens(&self) -> &[i32] {
        &self.committed[self.req.prompt.len()..]
    }

    pub fn last_token(&self) -> i32 {
        *self.committed.last().expect("non-empty committed")
    }

    pub fn is_active(&self) -> bool {
        self.finished.is_none()
    }
}

/// Per-request wall-clock attribution: where the observed latency went.
/// The stages partition `[submitted_at, delivery]`, so they sum to the
/// reported `latency_s` exactly (up to float rounding):
///
/// * `dispatch_s` — client submit → engine admission queue (router channel
///   hop plus, under a cluster, the dispatch decision).
/// * `queue_s` — waiting in the scheduler for a KV row / window slot.
/// * `splice_s` — prefix-cache page splicing at admission.
/// * `prefill_s` — admission → first token, net of splice.
/// * `decode_s` — first token → engine-side finish.
/// * `emit_s` — engine finish → completion delivered to the waiter.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageBreakdown {
    pub queue_s: f64,
    pub dispatch_s: f64,
    pub splice_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub emit_s: f64,
}

impl StageBreakdown {
    /// Sum of every stage; equals the delivered `latency_s`.
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.dispatch_s + self.splice_s + self.prefill_s + self.decode_s
            + self.emit_s
    }
}

/// Completion record returned to the caller.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub task: String,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    pub stats: SpecStats,
    pub draft_cost: DraftCost,
    /// Seconds spent queued in the scheduler before admission.
    pub sched_delay_s: f64,
    /// Wall-clock seconds from submission to completion / to first token.
    /// The router adds the delivery hop (`stages.emit_s`) before handing
    /// the completion to the waiter, so this is submission → delivery.
    pub latency_s: f64,
    pub ttft_s: f64,
    /// Where `latency_s` went, stage by stage (always populated; opt-in on
    /// the wire via the request's `"stages": true` flag).
    pub stages: StageBreakdown,
    /// When the engine finished the request — the router derives `emit_s`
    /// from it at delivery.
    pub finished_at: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::VanillaDrafter;
    use crate::util::rng::Pcg;

    #[test]
    fn truncation_flag_flows_into_request_state_stats() {
        let req = Request::new(7, vec![1, 2], GenParams::default()).with_truncated(true);
        assert!(req.prompt_truncated);
        let st = RequestState::new(req, Box::new(VanillaDrafter), Pcg::seeded(0));
        assert_eq!(st.stats.prompt_truncated, 1);
        let clean = Request::new(8, vec![1, 2], GenParams::default());
        let st = RequestState::new(clean, Box::new(VanillaDrafter), Pcg::seeded(0));
        assert_eq!(st.stats.prompt_truncated, 0);
    }

    #[test]
    fn state_tracks_output_tokens() {
        let req = Request::new(1, vec![10, 11, 12], GenParams::default()).with_task("gsm8k");
        let mut st = RequestState::new(req, Box::new(VanillaDrafter), Pcg::seeded(0));
        assert_eq!(st.output_tokens(), &[] as &[i32]);
        assert_eq!(st.last_token(), 12);
        st.committed.extend_from_slice(&[13, 14]);
        st.generated = 2;
        assert_eq!(st.output_tokens(), &[13, 14]);
        assert!(st.is_active());
        st.finished = Some(FinishReason::Eos);
        assert!(!st.is_active());
    }

    #[test]
    fn default_params_are_greedy() {
        let p = GenParams::default();
        assert_eq!(p.temp, 0.0);
        assert!(p.stop_at_eos);
        assert_eq!(p.priority, Priority::Normal);
        assert!(p.deadline.is_none());
    }

    #[test]
    fn priority_orders_high_first() {
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
    }

    #[test]
    fn stage_breakdown_totals_every_stage() {
        let s = StageBreakdown {
            queue_s: 0.1,
            dispatch_s: 0.2,
            splice_s: 0.3,
            prefill_s: 0.4,
            decode_s: 0.5,
            emit_s: 0.6,
        };
        assert!((s.total_s() - 2.1).abs() < 1e-12);
        assert_eq!(StageBreakdown::default().total_s(), 0.0);
    }

    #[test]
    fn submitted_at_backdates_the_deadline_anchor() {
        let t0 = Instant::now() - Duration::from_millis(50);
        let mut params = GenParams::default();
        params.deadline = Some(Duration::from_millis(10));
        let req = Request::new(3, vec![1], params).with_submitted_at(t0);
        assert_eq!(req.submitted_at, t0);
        assert!(req.deadline_at().unwrap() < Instant::now(), "backdated deadline already blown");
        assert!(req.enqueued_at >= t0);
    }

    #[test]
    fn deadline_is_relative_to_submission() {
        let mut params = GenParams::default();
        params.deadline = Some(std::time::Duration::from_secs(5));
        let req = Request::new(1, vec![1], params);
        let d = req.deadline_at().unwrap();
        assert!(d > req.submitted_at);
        assert!(Request::new(2, vec![1], GenParams::default()).deadline_at().is_none());
    }
}

//! JSON-lines TCP serving front-end.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "question : ...", "max_new": 64, "temp": 0.0,
//!       "task": "gsm8k", "priority": "high", "deadline_ms": 2000}
//!   <- {"id": 3, "text": "answer : ...", "tokens": [..], "steps": n,
//!       "accept_len": 1.42, "latency_s": 0.41, "sched_delay_s": 0.02,
//!       "finish": "eos"}          (finish may also be "cancelled")
//!   -> {"cmd": "ping"}            <- {"ok": true}
//!   -> {"cmd": "stats"}           <- {"queue_depth": .., "batch_occupancy":
//!                                     .., "sched_delay_s": ..,
//!                                     "chunk_efficiency": ..,
//!                                     "subbatches_per_step": ..,
//!                                     "buckets": [{"bucket": 1, "calls":
//!                                     .., "mean_rows": ..}, ..],
//!                                     "variants": [{"variant": "w8a8",
//!                                     "calls": ..}, ..],
//!                                     "governor": {"audits": ..,
//!                                     "probes": .., "audit_rate": ..,
//!                                     "top1_agreement": .., "accept_delta":
//!                                     .., "demotions": .., "promotions":
//!                                     ..},
//!                                     "gamma": {"classes": [{"class": ..,
//!                                     "accept_ewma": .., "steps": ..,
//!                                     "drafted": .., "accepted": ..}, ..],
//!                                     "steps": .., "drafted": ..,
//!                                     "accepted": ..} — the per-class
//!                                     adaptive draft-depth controller
//!                                     (config echoes "adaptive_gamma"),
//!                                     "prefix": {"hits": .., "misses": ..,
//!                                     "hit_rate": .., "hit_tokens": ..,
//!                                     "mid_stream_hit_tokens": ..,
//!                                     "resident_bytes": ..,
//!                                     "resident_pages": ..,
//!                                     "page_share_ratio": ..,
//!                                     "segments": .., "evictions": ..},
//!                                     "prompt_truncated": ..,
//!                                     "replicas": [per-replica stats, ..],
//!                                     "dispatch": {"policy": ..,
//!                                     "steal_threshold": .., "steals": ..,
//!                                     "locality_hits": ..,
//!                                     "locality_misses": ..,
//!                                     "locality_hit_rate": ..,
//!                                     "dispatched": [..]}, ...}
//!   -> {.., "stages": true}       <- the response additionally carries
//!                                     {"stages": {"queue_s": ..,
//!                                     "dispatch_s": .., "splice_s": ..,
//!                                     "prefill_s": .., "decode_s": ..,
//!                                     "emit_s": ..}, "replica": ..,
//!                                     "stolen": ..} — a per-request stage
//!                                     breakdown summing to latency_s plus
//!                                     where dispatch landed it
//!   -> {"cmd": "trace"}           <- Chrome trace-event JSON: drains the
//!                                     flight recorder (see `crate::trace`;
//!                                     requires `EngineConfig::trace`). One
//!                                     track per replica, one async lane
//!                                     per request; open in Perfetto.
//!   -> {"cmd": "metrics"}         <- {"metrics": "..."} — Prometheus text
//!                                     exposition of the engine metrics
//!                                     registry (counters, gauges, and
//!                                     histograms with cumulative buckets);
//!                                     fleet-merged under a cluster
//!   -> {"cmd": "shutdown"}        <- {"ok": true}  (server exits)
//!
//! Threading model (two-tier): each connection is handled by a pool worker,
//! and workers share one [`ServeHandle`] directly — a bare
//! [`EngineHandle`] or (the serving default) a [`ClusterHandle`] fleet,
//! both `Sync`, so the request path takes no lock beyond the dispatcher's
//! brief locality-index probe. The cluster routes the request to one of its N
//! engine replicas (consistent-hash by prefix family, work-stealing
//! spillover under load; N = 1 collapses to exactly the old
//! single-`EngineHandle` behavior). The worker gets a private [`Ticket`]
//! from the chosen replica and blocks only on *its own* completion while
//! that replica's continuous batcher multiplexes every request dispatched
//! to it through one batched verification pass per step. Completions never
//! pass back through the dispatcher; cancels route by the id-stride rule to
//! the replica that minted the id. Timeouts cancel the request (freeing its
//! KV row) instead of abandoning it. The `stats` command reports the fleet
//! aggregate flat at the top level (same keys as a bare engine) plus
//! per-replica breakdown and dispatch counters.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{ClusterHandle, Completion, DispatchInfo, EngineHandle,
                         FinishReason, GenParams, Priority, StageBreakdown, Ticket};
use crate::metrics::MetricsDump;
use crate::tokenizer::{Tokenizer, BOS_ID, EOS_ID};
use crate::util::json::{parse, Json};

/// How long a connection waits for its own completion before cancelling.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(120);

/// The server's engine-facing handle: one bare engine or a replica fleet.
/// Both are `Sync` with the same submit/cancel surface; the bare variant
/// keeps the dispatch plane entirely out of the A/B control path (the
/// `--replicas 0` leg of the differential smoke), where a 1-replica
/// cluster is the dispatcher's own degenerate case.
pub enum ServeHandle {
    Engine(EngineHandle),
    Cluster(ClusterHandle),
}

impl From<EngineHandle> for ServeHandle {
    fn from(h: EngineHandle) -> Self {
        ServeHandle::Engine(h)
    }
}

impl From<ClusterHandle> for ServeHandle {
    fn from(h: ClusterHandle) -> Self {
        ServeHandle::Cluster(h)
    }
}

impl ServeHandle {
    pub fn submit(&self, prompt: Vec<i32>, params: GenParams, task: &str) -> Result<Ticket> {
        match self {
            ServeHandle::Engine(h) => h.submit(prompt, params, task),
            ServeHandle::Cluster(h) => h.submit(prompt, params, task),
        }
    }

    pub fn cancel(&self, id: u64) -> Result<()> {
        match self {
            ServeHandle::Engine(h) => h.cancel(id),
            ServeHandle::Cluster(h) => h.cancel(id),
        }
    }

    pub fn warm_prefix(&self, templates: Vec<(Vec<i32>, String)>) -> Result<usize> {
        match self {
            ServeHandle::Engine(h) => h.warm_prefix(templates),
            ServeHandle::Cluster(h) => h.warm_prefix(templates),
        }
    }

    /// [`ServeHandle::submit`], plus where the request landed. A bare
    /// engine always reports replica 0, never stolen.
    pub fn submit_dispatch(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
        task: &str,
    ) -> Result<(Ticket, DispatchInfo)> {
        match self {
            ServeHandle::Engine(h) => {
                Ok((h.submit(prompt, params, task)?, DispatchInfo::default()))
            }
            ServeHandle::Cluster(h) => h.submit_dispatch(prompt, params, task),
        }
    }

    /// `{"cmd":"stats"}` payload: flat engine keys for a bare engine, the
    /// same flat keys plus `replicas` + `dispatch` for a fleet.
    pub fn stats_json(&self) -> Json {
        match self {
            ServeHandle::Engine(h) => h.stats().to_json(),
            ServeHandle::Cluster(h) => h.cluster_stats().to_json(),
        }
    }

    /// `{"cmd":"trace"}` payload: drain the flight recorder into Chrome
    /// trace-event JSON (a valid empty document when tracing is off).
    pub fn trace_json(&self) -> Json {
        match self {
            ServeHandle::Engine(h) => h.trace_json(),
            ServeHandle::Cluster(h) => h.trace_json(),
        }
    }

    /// Full metrics-registry dump (fleet-merged under a cluster).
    pub fn metrics_dump(&self) -> Result<MetricsDump> {
        match self {
            ServeHandle::Engine(h) => h.metrics_dump(),
            ServeHandle::Cluster(h) => h.metrics_dump(),
        }
    }

    /// `{"cmd":"metrics"}` payload body: Prometheus text exposition.
    pub fn metrics_text(&self) -> Result<String> {
        Ok(self.metrics_dump()?.to_prometheus())
    }
}

/// Serve until a `shutdown` command arrives. Returns the number of requests
/// served.
pub fn serve(listener: TcpListener, handle: impl Into<ServeHandle>, tok: Tokenizer,
             n_conn_threads: usize) -> Result<u64> {
    let handle = handle.into();
    anyhow::ensure!(
        tok.matches_contract(),
        "tokenizer violates the special-token contract \
         (pad/bos/eos/unk = {}/{}/{}/{} expected {}/{}/{}/{})",
        tok.pad_id, tok.bos_id, tok.eos_id, tok.unk_id,
        crate::tokenizer::PAD_ID, BOS_ID, EOS_ID, crate::tokenizer::UNK_ID,
    );
    let handle = Arc::new(handle);
    let tok = Arc::new(tok);
    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(std::sync::atomic::AtomicU64::new(0));
    listener
        .set_nonblocking(true)
        .context("set_nonblocking on listener")?;
    let pool = crate::util::threads::ThreadPool::new(n_conn_threads);
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let handle = Arc::clone(&handle);
                let tok = Arc::clone(&tok);
                let stop = Arc::clone(&stop);
                let served = Arc::clone(&served);
                pool.submit(move || {
                    if let Err(e) = handle_conn(stream, &handle, &tok, &stop, &served) {
                        eprintln!("[server] connection error: {e:#}");
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accept"),
        }
    }
    Ok(served.load(Ordering::SeqCst))
}

fn handle_conn(stream: TcpStream, handle: &ServeHandle, tok: &Tokenizer,
               stop: &AtomicBool, served: &std::sync::atomic::AtomicU64) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match handle_line(&line, handle, tok, stop) {
            Ok(r) => {
                served.fetch_add(1, Ordering::Relaxed);
                r
            }
            Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
        };
        writeln!(writer, "{resp}")?;
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// A protocol control command (`{"cmd": ...}` lines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCmd {
    Ping,
    Stats,
    Trace,
    Metrics,
    Shutdown,
}

/// One parsed protocol line, before any engine interaction. Factored out of
/// the connection handler so the parser is pure (bytes in, value or error
/// out) — unit-testable and fuzzable (`rust/fuzz/fuzz_targets/
/// protocol_parse.rs`) without a socket or an engine.
#[derive(Debug)]
pub enum WireRequest {
    Command(WireCmd),
    Generate {
        prompt: String,
        params: GenParams,
        task: String,
        /// Client asked for the per-request stage breakdown in the reply.
        stages: bool,
    },
}

/// Parse one JSON-lines request. Total: any input (malformed JSON, wrong
/// types, huge/NaN numbers, unknown commands) returns `Err`, never panics —
/// the fuzz target's core invariant.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let req = parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
    if let Some(cmd) = req.opt("cmd") {
        return Ok(WireRequest::Command(match cmd.as_str()? {
            "ping" => WireCmd::Ping,
            "stats" => WireCmd::Stats,
            "trace" => WireCmd::Trace,
            "metrics" => WireCmd::Metrics,
            "shutdown" => WireCmd::Shutdown,
            other => anyhow::bail!("unknown cmd '{other}'"),
        }));
    }
    let prompt = req.get("prompt")?.as_str()?.to_string();
    let params = GenParams {
        temp: req.opt("temp").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0),
        max_new: req.opt("max_new").map(|v| v.as_usize()).transpose()?.unwrap_or(64),
        seed: req.opt("seed").map(|v| v.as_i64()).transpose()?.map(|s| s as u64),
        stop_at_eos: true,
        priority: match req.opt("priority").map(|v| v.as_str()).transpose()? {
            None => Priority::Normal,
            Some(s) => Priority::parse(s)
                .ok_or_else(|| anyhow!("unknown priority '{s}' (high|normal|low)"))?,
        },
        deadline: req
            .opt("deadline_ms")
            .map(|v| v.as_f64())
            .transpose()?
            // Clamp before Duration::from_secs_f64, which panics on
            // negative/inf/overflow input (NaN already maxes to 0). A year
            // is far past any deadline the scheduler can honor.
            .map(|ms| Duration::from_secs_f64(ms.max(0.0).min(86_400_000.0 * 365.0) / 1e3)),
    };
    let task = req
        .opt("task")
        .map(|v| v.as_str().map(String::from))
        .transpose()?
        .unwrap_or_default();
    let stages = req
        .opt("stages")
        .map(|v| v.as_bool())
        .transpose()?
        .unwrap_or(false);
    Ok(WireRequest::Generate { prompt, params, task, stages })
}

fn handle_line(line: &str, handle: &ServeHandle, tok: &Tokenizer,
               stop: &AtomicBool) -> Result<Json> {
    let (prompt_text, params, task, want_stages) = match parse_request(line)? {
        WireRequest::Command(WireCmd::Ping) => {
            return Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        WireRequest::Command(WireCmd::Stats) => return Ok(handle.stats_json()),
        WireRequest::Command(WireCmd::Trace) => return Ok(handle.trace_json()),
        WireRequest::Command(WireCmd::Metrics) => {
            return Ok(Json::obj(vec![(
                "metrics",
                Json::str(handle.metrics_text()?),
            )]))
        }
        WireRequest::Command(WireCmd::Shutdown) => {
            stop.store(true, Ordering::SeqCst);
            return Ok(Json::obj(vec![("ok", Json::Bool(true))]));
        }
        WireRequest::Generate { prompt, params, task, stages } => {
            (prompt, params, task, stages)
        }
    };
    let ids = tok.encode(&prompt_text, true);

    // Lock-free submit; this worker blocks only on its own ticket while the
    // engine multiplexes every connection's request in one batch.
    let (ticket, dispatch) = handle.submit_dispatch(ids, params, &task)?;
    let Some(completion) = ticket.wait(REQUEST_TIMEOUT) else {
        // Don't leak the KV row of a request nobody is waiting for.
        let _ = handle.cancel(ticket.id);
        anyhow::bail!("generation timed out");
    };
    let mut resp = completion_json(&completion, tok);
    if want_stages {
        if let Json::Obj(m) = &mut resp {
            m.insert("stages".into(), stages_json(&completion.stages));
            m.insert("replica".into(), Json::num(dispatch.replica as f64));
            m.insert("stolen".into(), Json::Bool(dispatch.stolen));
        }
    }
    Ok(resp)
}

/// Per-request stage breakdown for the wire: the six stages partition the
/// response's `latency_s` (see [`StageBreakdown`]).
pub fn stages_json(st: &StageBreakdown) -> Json {
    Json::obj(vec![
        ("queue_s", Json::num(st.queue_s)),
        ("dispatch_s", Json::num(st.dispatch_s)),
        ("splice_s", Json::num(st.splice_s)),
        ("prefill_s", Json::num(st.prefill_s)),
        ("decode_s", Json::num(st.decode_s)),
        ("emit_s", Json::num(st.emit_s)),
    ])
}

/// Serialize a completion for the wire (shared with the examples).
pub fn completion_json(c: &Completion, tok: &Tokenizer) -> Json {
    let finish = match c.finish {
        FinishReason::Eos => "eos",
        FinishReason::MaxNewTokens => "max_new",
        FinishReason::ContextFull => "context_full",
        FinishReason::Cancelled => "cancelled",
    };
    Json::obj(vec![
        ("id", Json::num(c.id as f64)),
        ("task", Json::str(c.task.clone())),
        ("text", Json::str(tok.decode(&c.tokens))),
        ("tokens", Json::arr(c.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
        ("finish", Json::str(finish)),
        ("steps", Json::num(c.stats.steps as f64)),
        ("accept_len", Json::num(c.stats.mean_acceptance_len())),
        ("accept_rate", Json::num(c.stats.acceptance_rate())),
        ("sched_delay_s", Json::num(c.sched_delay_s)),
        ("latency_s", Json::num(c.latency_s)),
        ("ttft_s", Json::num(c.ttft_s)),
    ])
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Client { stream: TcpStream::connect(addr).context("connect")? })
    }

    pub fn roundtrip(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.stream, "{req}")?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    pub fn generate(&mut self, prompt: &str, max_new: usize, temp: f64) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("max_new", Json::num(max_new as f64)),
            ("temp", Json::num(temp)),
        ]))
    }

    /// Snapshot the server's scheduler/batching stats.
    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj(vec![("cmd", Json::str("stats"))]))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.roundtrip(&Json::obj(vec![("cmd", Json::str("shutdown"))]))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_commands_and_generate() {
        assert!(matches!(
            parse_request(r#"{"cmd": "ping"}"#).unwrap(),
            WireRequest::Command(WireCmd::Ping)
        ));
        assert!(matches!(
            parse_request(r#"{"cmd": "stats"}"#).unwrap(),
            WireRequest::Command(WireCmd::Stats)
        ));
        let req = parse_request(
            r#"{"prompt": "question : x", "max_new": 8, "temp": 0.5,
               "task": "gsm8k", "priority": "high", "deadline_ms": 250,
               "stages": true, "seed": 7}"#,
        )
        .unwrap();
        match req {
            WireRequest::Generate { prompt, params, task, stages } => {
                assert_eq!(prompt, "question : x");
                assert_eq!(params.max_new, 8);
                assert_eq!(params.temp, 0.5);
                assert_eq!(params.seed, Some(7));
                assert_eq!(params.priority, Priority::High);
                assert_eq!(params.deadline, Some(Duration::from_millis(250)));
                assert!(params.stop_at_eos);
                assert_eq!(task, "gsm8k");
                assert!(stages);
            }
            other => panic!("expected Generate, got {other:?}"),
        }
    }

    #[test]
    fn parse_request_rejects_garbage_without_panicking() {
        for bad in [
            "",
            "not json",
            "{}",
            r#"{"cmd": "reboot"}"#,
            r#"{"prompt": 3}"#,
            r#"{"prompt": "x", "priority": "urgent"}"#,
            r#"{"prompt": "x", "max_new": "many"}"#,
            r#"{"cmd": ["stats"]}"#,
        ] {
            assert!(parse_request(bad).is_err(), "accepted {bad:?}");
        }
        // Negative/huge/non-finite deadlines clamp rather than panic
        // Duration::from_secs_f64 (its input domain excludes negatives,
        // infinities and anything past u64 seconds).
        for extreme in ["-50", "1e999", "1e308", "-1e999"] {
            let line = format!(r#"{{"prompt": "x", "deadline_ms": {extreme}}}"#);
            match parse_request(&line).unwrap() {
                WireRequest::Generate { params, .. } => {
                    assert!(params.deadline.is_some(), "deadline dropped for {extreme}");
                }
                other => panic!("expected Generate, got {other:?}"),
            }
        }
        match parse_request(r#"{"prompt": "x", "deadline_ms": -50}"#).unwrap() {
            WireRequest::Generate { params, .. } => {
                assert_eq!(params.deadline, Some(Duration::ZERO));
            }
            other => panic!("expected Generate, got {other:?}"),
        }
    }
}

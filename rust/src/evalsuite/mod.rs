//! Downstream-accuracy evaluation (Table 4's measurable analogue,
//! DESIGN.md §1): teacher-forced comparison of the BF16-stand-in and W8A8
//! verifiers on held-out per-task rows from `artifacts/evalset.json`.
//!
//! Reported per task family:
//!   * top-1 agreement between variants (does quantization flip the argmax —
//!     the paper's §4.5 "as long as the quantization does not flip the top-1
//!     prediction" criterion),
//!   * per-variant teacher-forced perplexity and the relative delta (the
//!     paper's accuracy-Δ column), and
//!   * mean KL(fp32 || w8a8) over next-token distributions (§3.4's
//!     "negligible KL divergence" claim).

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::runtime::{ModelRuntime, Tensor};
use crate::spec::softmax_t;
use crate::util::json::parse_file;

/// One teacher-forcing row.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub ids: Vec<i32>,
    pub len: usize,
}

/// Per-task accuracy comparison.
#[derive(Debug, Clone)]
pub struct TaskReport {
    pub task: String,
    pub positions: usize,
    pub top1_agreement: f64,
    pub ppl_fp32: f64,
    pub ppl_w8a8: f64,
    pub mean_kl: f64,
}

impl TaskReport {
    /// The paper's Δ column analogue: relative PPL degradation (%).
    pub fn ppl_delta_pct(&self) -> f64 {
        (self.ppl_w8a8 / self.ppl_fp32 - 1.0) * 100.0
    }
}

/// Load the eval set grouped by task. Degenerate rows — empty (`len == 0`
/// / no ids) or inconsistent (`len` exceeding the ids actually present) —
/// are skipped here so every downstream consumer can assume `len >= 1` and
/// `ids` covers it; `len - 1` on a zero-length row used to underflow and
/// panic in `forced_logits`.
pub fn load_evalset(path: &std::path::Path) -> Result<Vec<(String, Vec<EvalRow>)>> {
    let j = parse_file(path).context("loading evalset.json")?;
    let mut out = Vec::new();
    for (task, arr) in j.get("tasks")?.as_obj()? {
        let rows = arr
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(EvalRow {
                    ids: r.get("ids")?.as_i32_vec()?,
                    len: r.get("len")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>, crate::util::json::JsonError>>()?
            .into_iter()
            .filter(|r: &EvalRow| r.len >= 1 && r.ids.len() >= r.len)
            .collect();
        out.push((task.clone(), rows));
    }
    Ok(out)
}

/// Teacher-forced logits for a batch of rows under one variant: runs the
/// prefill artifact (positions 0..P-1) and returns `[rows][pos][vocab]`
/// logits for the valid positions of each row.
fn forced_logits(mr: &Rc<ModelRuntime>, variant: &str, rows: &[&EvalRow])
                 -> Result<Vec<Tensor<f32>>> {
    let cfg = mr.cfg().clone();
    let p = cfg.prefill_len;
    let buckets = mr.entry.buckets(variant, "prefill");
    let b = buckets.iter().copied().max().unwrap_or(1);
    let mut out = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(b) {
        let mut toks = vec![0i32; b * p];
        for (i, r) in chunk.iter().enumerate() {
            // last id is target-only; saturate so an empty row (filtered at
            // load, but defend anyway) contributes zero positions instead
            // of a usize underflow panic
            let n = r.len.saturating_sub(1).min(p);
            toks[i * p..i * p + n].copy_from_slice(&r.ids[..n]);
        }
        let (k, v) = mr.empty_cache(cfg.n_layers, b);
        let o = mr.run_chunk(variant, "prefill", b, &toks, &k, &v, &vec![0; b])?;
        for (i, _r) in chunk.iter().enumerate() {
            // slice row i logits [p, vocab]
            let mut t = Tensor::zeros(&[p, cfg.vocab_size]);
            for pos in 0..p {
                t.data[pos * cfg.vocab_size..(pos + 1) * cfg.vocab_size]
                    .copy_from_slice(o.logits.row(&[i, pos]));
            }
            out.push(t);
        }
    }
    Ok(out)
}

/// Run the full Table-4 comparison for one task's rows.
pub fn compare_task(mr: &Rc<ModelRuntime>, task: &str, rows: &[EvalRow],
                    max_rows: usize) -> Result<TaskReport> {
    let cfg = mr.cfg().clone();
    let use_rows: Vec<&EvalRow> = rows.iter().take(max_rows).collect();
    let lf = forced_logits(mr, "fp32", &use_rows)?;
    let lq = forced_logits(mr, "w8a8", &use_rows)?;

    let mut agree = 0usize;
    let mut total = 0usize;
    let mut nll_f = 0.0f64;
    let mut nll_q = 0.0f64;
    let mut kl_sum = 0.0f64;
    let mut pf = Vec::new();
    let mut pq = Vec::new();
    for ((row, f), q) in use_rows.iter().zip(&lf).zip(&lq) {
        let n = row.len.saturating_sub(1).min(cfg.prefill_len);
        for pos in 0..n {
            let target = row.ids[pos + 1] as usize;
            let rf = f.row(&[pos]);
            let rq = q.row(&[pos]);
            softmax_t(rf, 1.0, &mut pf);
            softmax_t(rq, 1.0, &mut pq);
            agree += usize::from(crate::spec::argmax(rf) == crate::spec::argmax(rq));
            nll_f += -(pf[target].max(1e-12) as f64).ln();
            nll_q += -(pq[target].max(1e-12) as f64).ln();
            kl_sum += pf
                .iter()
                .zip(&pq)
                .map(|(&a, &b)| {
                    let a = a.max(1e-12) as f64;
                    let b = b.max(1e-12) as f64;
                    a * (a / b).ln()
                })
                .sum::<f64>();
            total += 1;
        }
    }
    let totalf = total.max(1) as f64;
    Ok(TaskReport {
        task: task.to_string(),
        positions: total,
        top1_agreement: agree as f64 / totalf,
        ppl_fp32: (nll_f / totalf).exp(),
        ppl_w8a8: (nll_q / totalf).exp(),
        mean_kl: kl_sum / totalf,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn evalset_parses() {
        let j = parse(
            r#"{"tasks": {"gsm8k": [{"ids": [1,2,3,4], "len": 4}]}, "row_len": 3}"#,
        )
        .unwrap();
        std::fs::write("/tmp/quasar_evalset_test.json", j.to_string()).unwrap();
        let rows = load_evalset(std::path::Path::new("/tmp/quasar_evalset_test.json")).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, "gsm8k");
        assert_eq!(rows[0].1[0].ids, vec![1, 2, 3, 4]);
        assert_eq!(rows[0].1[0].len, 4);
    }

    #[test]
    fn empty_and_inconsistent_rows_are_skipped() {
        // Regression: a zero-length row made `(r.len - 1)` underflow and
        // panic downstream; rows whose `len` exceeds their ids would read
        // out of bounds. Both are dropped at load.
        let j = parse(
            r#"{"tasks": {"gsm8k": [
                 {"ids": [], "len": 0},
                 {"ids": [7], "len": 0},
                 {"ids": [1,2], "len": 5},
                 {"ids": [9], "len": 1},
                 {"ids": [1,2,3,4], "len": 4}
               ],
               "empty_task": [{"ids": [], "len": 0}]}}"#,
        )
        .unwrap();
        let path = std::path::Path::new("/tmp/quasar_evalset_empty_rows.json");
        std::fs::write(path, j.to_string()).unwrap();
        let tasks = load_evalset(path).unwrap();
        assert_eq!(tasks.len(), 2);
        let gsm = &tasks.iter().find(|(t, _)| t == "gsm8k").unwrap().1;
        assert_eq!(gsm.len(), 2, "only consistent non-empty rows survive");
        assert_eq!(gsm[0].ids, vec![9]);
        assert_eq!(gsm[1].ids, vec![1, 2, 3, 4]);
        let empty = &tasks.iter().find(|(t, _)| t == "empty_task").unwrap().1;
        assert!(empty.is_empty(), "a task of only empty rows loads as empty, not an error");
    }

    #[test]
    fn report_delta_formula() {
        let r = TaskReport {
            task: "t".into(), positions: 10, top1_agreement: 0.99,
            ppl_fp32: 2.0, ppl_w8a8: 2.06, mean_kl: 0.01,
        };
        assert!((r.ppl_delta_pct() - 3.0).abs() < 1e-9);
    }
}

//! Workload layer: the Spec-Bench stand-in (DESIGN.md §1).
//!
//! Prompts come from `artifacts/workloads.json` — held-out documents from
//! the same five task-family generators the model was trained on, exported
//! by `python/compile/aot.py` so the rust and python sides agree exactly on
//! the token distribution. This module samples per-task request sets and
//! synthesizes arrival processes for the serving benchmarks.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::GenParams;
use crate::util::json::{parse_file, Json};
use crate::util::rng::Pcg;

/// The paper's five task families (Table 1 columns).
pub const TASKS: [&str; 5] = ["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"];

/// One serving prompt with its reference completion.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub task: String,
    pub prompt: String,
    pub prompt_ids: Vec<i32>,
    pub reference_ids: Vec<i32>,
}

/// The full exported workload set.
#[derive(Debug, Clone)]
pub struct WorkloadSet {
    items: Vec<WorkItem>,
}

impl WorkloadSet {
    pub fn load(path: &Path) -> Result<Self> {
        let j = parse_file(path).context("loading workloads.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut items = Vec::new();
        for (task, arr) in j.get("tasks")?.as_obj()? {
            for it in arr.as_arr()? {
                items.push(WorkItem {
                    task: task.clone(),
                    prompt: it.get("prompt")?.as_str()?.to_string(),
                    prompt_ids: it.get("prompt_ids")?.as_i32_vec()?,
                    reference_ids: it.get("reference_ids")?.as_i32_vec()?,
                });
            }
        }
        Ok(WorkloadSet { items })
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn task_items(&self, task: &str) -> Vec<&WorkItem> {
        self.items.iter().filter(|i| i.task == task).collect()
    }

    /// Deterministically sample `n` prompts of one task.
    pub fn sample(&self, task: &str, n: usize, rng: &mut Pcg) -> Vec<WorkItem> {
        let pool = self.task_items(task);
        assert!(!pool.is_empty(), "no items for task {task}");
        (0..n)
            .map(|_| pool[rng.usize_below(pool.len())].clone())
            .collect()
    }

    /// A mixed-task batch in round-robin task order (the serving driver).
    pub fn mixed(&self, n: usize, rng: &mut Pcg) -> Vec<WorkItem> {
        (0..n)
            .map(|i| {
                let task = TASKS[i % TASKS.len()];
                let pool = self.task_items(task);
                pool[rng.usize_below(pool.len())].clone()
            })
            .collect()
    }
}

/// Open-loop Poisson arrival trace for the serving benchmark.
#[derive(Debug, Clone)]
pub struct ArrivalTrace {
    /// (arrival offset seconds, item index)
    pub arrivals: Vec<(f64, usize)>,
}

impl ArrivalTrace {
    pub fn poisson(n: usize, rate_per_s: f64, rng: &mut Pcg) -> Self {
        let mut t = 0.0;
        let arrivals = (0..n)
            .map(|i| {
                t += rng.exp(rate_per_s);
                (t, i)
            })
            .collect();
        ArrivalTrace { arrivals }
    }

    pub fn duration(&self) -> f64 {
        self.arrivals.last().map(|a| a.0).unwrap_or(0.0)
    }
}

/// Default generation params used by the benches (paper: greedy T=0 and
/// sampled T=1, ~64 new tokens per request on the scaled-down model).
pub fn bench_params(temp: f64, max_new: usize) -> GenParams {
    GenParams { temp, max_new, ..GenParams::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_json() -> Json {
        parse(
            r#"{"tasks": {
                "gsm8k": [
                  {"prompt":"question : a","prompt_ids":[1,10],"reference":"r","reference_ids":[11]},
                  {"prompt":"question : b","prompt_ids":[1,12],"reference":"r","reference_ids":[13]}
                ],
                "alpaca": [
                  {"prompt":"write","prompt_ids":[1,20],"reference":"r","reference_ids":[21]}
                ],
                "mtbench": [{"prompt":"m","prompt_ids":[1,30],"reference":"r","reference_ids":[31]}],
                "humaneval": [{"prompt":"h","prompt_ids":[1,40],"reference":"r","reference_ids":[41]}],
                "cnndm": [{"prompt":"c","prompt_ids":[1,50],"reference":"r","reference_ids":[51]}]
            }, "seed": 1}"#,
        )
        .unwrap()
    }

    #[test]
    fn loads_and_filters_by_task() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        assert_eq!(ws.len(), 6);
        assert_eq!(ws.task_items("gsm8k").len(), 2);
        assert_eq!(ws.task_items("alpaca").len(), 1);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let a: Vec<_> = ws.sample("gsm8k", 8, &mut Pcg::seeded(5))
            .iter().map(|i| i.prompt_ids.clone()).collect();
        let b: Vec<_> = ws.sample("gsm8k", 8, &mut Pcg::seeded(5))
            .iter().map(|i| i.prompt_ids.clone()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn mixed_covers_all_tasks() {
        let ws = WorkloadSet::from_json(&sample_json()).unwrap();
        let m = ws.mixed(10, &mut Pcg::seeded(1));
        for t in TASKS {
            assert!(m.iter().any(|i| i.task == t), "missing {t}");
        }
    }

    #[test]
    fn poisson_arrivals_monotone_with_correct_mean() {
        let mut rng = Pcg::seeded(2);
        let tr = ArrivalTrace::poisson(4000, 8.0, &mut rng);
        assert!(tr.arrivals.windows(2).all(|w| w[0].0 <= w[1].0));
        let mean_gap = tr.duration() / 4000.0;
        assert!((mean_gap - 0.125).abs() < 0.01, "mean gap {mean_gap}");
    }
}
